#!/usr/bin/env bash
# CI gate for the workspace: build, tests (default AND no-default
# features), formatting, lints, and (opt-in) the micro-bench perf diff.
#
#   scripts/ci.sh           # everything except benches (incl. daemon smoke)
#   scripts/ci.sh --fast    # build + tests + smoke only (skip fmt/clippy)
#   scripts/ci.sh --bench   # also run micro_hotpath and diff the
#                           # round_*/sketch_* notes against the committed
#                           # rust/BENCH_micro.json snapshot, plus the
#                           # daemon_stress throughput/tail-latency bench
#                           # and the shard_scale memory-budget bench
#                           # (its notes diffed vs rust/BENCH_shard.json)
#                           # and the sweep_transfer reuse bench (notes
#                           # diffed vs rust/BENCH_transfer.json)
#
# Tier-1 (enforced): cargo build --release && cargo test -q.
# The suite also runs with --no-default-features (the pure-host math
# core, no `xla` stub at all) so the feature seam cannot rot; the
# fault-injection suite runs explicitly so a filtered default run can
# never silently drop it; and the engine-coverage suites
# (strategy_conformance, engine_reuse, shard/sketch_conformance,
# sweep_cache) are gated warning-free.
# fmt/clippy run when the components are installed; a missing component
# is reported but does not fail the gate (offline toolchains may omit
# them), while an installed component failing DOES fail.
#
# Bench gate (--bench): speedup notes may not drop below 0.75x the
# committed value; dispatch-count notes may not grow past 1.25x.  Raw
# timing notes are machine-dependent and are NOT gated.  A snapshot
# carrying the `snapshot_bootstrap` marker (hand-seeded before the first
# bench run on a real machine) downgrades failures to warnings — commit
# a freshly generated rust/BENCH_micro.json to arm the gate.

set -euo pipefail
cd "$(dirname "$0")/.."

fast=0
bench=0
for arg in "$@"; do
    [[ "$arg" == "--fast" ]] && fast=1
    [[ "$arg" == "--bench" ]] && bench=1
done

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo test -q --no-default-features (pure-host math core) =="
cargo test -q --no-default-features

echo "== cargo test -q --test fault_injection (fault-tolerance suite) =="
cargo test -q --test fault_injection

echo "== cargo test -q --test shard_conformance (sharded-selection suite) =="
# explicit so a filtered default run can never silently drop it; the
# suite is feature-gated behind `xla` (engine/grads modules), so the
# --no-default-features pass above is where its absence is the contract:
# cargo skips the target entirely and the pure-host core still builds.
cargo test -q --test shard_conformance

echo "== cargo test -q --test sketch_conformance (sketched-selection suite) =="
cargo test -q --test sketch_conformance

echo "== cargo test -q --test sweep_cache (cross-arm SelectionCache suite) =="
cargo test -q --test sweep_cache

echo "== warnings gate: strategy_conformance + engine_reuse + shard_conformance + sketch_conformance + sweep_cache =="
# cargo replays cached warnings, so a --no-run rebuild of just the
# suites surfaces any warning attributed to their files; fail on match.
conf_warn=$(cargo test --test strategy_conformance --test engine_reuse --test shard_conformance --test sketch_conformance --test sweep_cache --no-run 2>&1 \
    | grep -E "^warning" -A 3 \
    | grep -E "tests/(strategy_conformance|engine_reuse|shard_conformance|sketch_conformance|sweep_cache)\.rs" || true)
if [[ -n "$conf_warn" ]]; then
    echo "$conf_warn"
    echo "ci: FAIL — warnings in the engine-coverage suites"
    exit 1
fi

echo "== daemon smoke: gradmatch serve --smoke=true (ephemeral socket) =="
# One real daemon+client round-trip: bind an ephemeral unix socket, ping,
# two deterministic selection rounds, stats, graceful shutdown.  The
# binary carries its own 45s watchdog (exit 3 when wedged); `timeout`
# adds a hard outer bound on toolchains that have it.
if command -v timeout >/dev/null 2>&1; then
    timeout --signal=TERM 60 target/release/gradmatch serve --smoke=true
else
    target/release/gradmatch serve --smoke=true
fi

if [[ "$bench" == "1" ]]; then
    echo "== bench gate: micro_hotpath vs committed rust/BENCH_micro.json =="
    # stash the committed snapshot BEFORE the bench overwrites the file
    old=$(git show HEAD:rust/BENCH_micro.json 2>/dev/null || true)
    cargo bench --bench micro_hotpath
    # extract "key value" pairs from the notes object of a BenchReport
    notes() { awk '/"notes": \{/{f=1;next} f&&/^  \}/{f=0} f{gsub(/[":,]/,""); if (NF>=2) print $1, $2}'; }
    if [[ -z "$old" ]]; then
        echo "ci: no committed BENCH_micro.json at HEAD — skipping perf diff"
    else
        bootstrap=0
        grep -q '"snapshot_bootstrap"' <<<"$old" && bootstrap=1
        fail=0
        while read -r key new; do
            oldv=$(notes <<<"$old" | awk -v k="$key" '$1==k{print $2; exit}')
            [[ -z "$oldv" || "$oldv" == "null" || "$new" == "null" ]] && continue
            case "$key" in
                *speedup*)
                    bad=$(awk -v n="$new" -v o="$oldv" 'BEGIN{print (n < 0.75*o) ? 1 : 0}')
                    kind="speedup regressed (new $new < 0.75 x old $oldv)" ;;
                round_dispatches_*)
                    bad=$(awk -v n="$new" -v o="$oldv" 'BEGIN{print (n > 1.25*o) ? 1 : 0}')
                    kind="dispatch count grew (new $new > 1.25 x old $oldv)" ;;
                *) continue ;;   # raw timings etc. are machine-dependent
            esac
            if [[ "$bad" == "1" ]]; then
                if [[ "$bootstrap" == "1" ]]; then
                    echo "ci: WARN (bootstrap snapshot) — $key: $kind"
                else
                    echo "ci: FAIL — $key: $kind"
                    fail=1
                fi
            fi
        done < <(notes < rust/BENCH_micro.json)
        if [[ "$fail" == "1" ]]; then
            echo "ci: FAIL — bench regression vs committed BENCH_micro.json"
            exit 1
        fi
        echo "ci: bench notes within tolerance"
        if [[ "$bootstrap" == "1" ]]; then
            # the bench just wrote a real snapshot over the hand-seeded
            # bootstrap; committing it drops the marker and arms the gate
            echo "ci: NOTE — committed snapshot is still the hand-seeded bootstrap;"
            echo "    commit the freshly written rust/BENCH_micro.json to arm the perf gate"
        fi
    fi
    echo "== daemon stress: rounds/sec + p99 + shed-rate =="
    cargo bench --bench daemon_stress
    echo "== shard scale: >=10x ground-vs-staged + flat-quality tolerance =="
    # hard checks live in the bench itself (exit 1 on failure); the
    # report lands in BENCH_shard.json next to the other two
    old_shard=$(git show HEAD:rust/BENCH_shard.json 2>/dev/null || true)
    cargo bench --bench shard_scale
    echo "== bench gate: shard_scale vs committed rust/BENCH_shard.json =="
    if [[ -z "$old_shard" ]]; then
        echo "ci: no committed BENCH_shard.json at HEAD — skipping shard notes diff"
    else
        sbootstrap=0
        grep -q '"snapshot_bootstrap"' <<<"$old_shard" && sbootstrap=1
        sfail=0
        while read -r key new; do
            oldv=$(notes <<<"$old_shard" | awk -v k="$key" '$1==k{print $2; exit}')
            [[ -z "$oldv" || "$oldv" == "null" || "$new" == "null" ]] && continue
            case "$key" in
                *speedup*|*scale_ratio*)
                    bad=$(awk -v n="$new" -v o="$oldv" 'BEGIN{print (n < 0.75*o) ? 1 : 0}')
                    kind="ratio regressed (new $new < 0.75 x old $oldv)" ;;
                *dispatches*)
                    bad=$(awk -v n="$new" -v o="$oldv" 'BEGIN{print (n > 1.25*o) ? 1 : 0}')
                    kind="dispatch count grew (new $new > 1.25 x old $oldv)" ;;
                *err*)
                    # selection is deterministic, so matching-error notes
                    # only move when the algorithm changes; small absolute
                    # slack absorbs f32 reduction-order noise
                    bad=$(awk -v n="$new" -v o="$oldv" 'BEGIN{print (n > 1.25*o + 0.01) ? 1 : 0}')
                    kind="matching error grew (new $new > 1.25 x old $oldv + 0.01)" ;;
                *) continue ;;   # raw timings etc. are machine-dependent
            esac
            if [[ "$bad" == "1" ]]; then
                if [[ "$sbootstrap" == "1" ]]; then
                    echo "ci: WARN (bootstrap snapshot) — $key: $kind"
                else
                    echo "ci: FAIL — $key: $kind"
                    sfail=1
                fi
            fi
        done < <(notes < rust/BENCH_shard.json)
        if [[ "$sfail" == "1" ]]; then
            echo "ci: FAIL — bench regression vs committed BENCH_shard.json"
            exit 1
        fi
        echo "ci: shard bench notes within tolerance"
    fi
    echo "== sweep transfer: reused-vs-per-arm subsets across the strategies x budgets grid =="
    # hard checks live in the bench itself (exit 1 on failure): every
    # reused round is a zero-dispatch cache hit bit-identical to the
    # seeding arm, and its matching error stays in the fresh solve's
    # regime under drift
    old_transfer=$(git show HEAD:rust/BENCH_transfer.json 2>/dev/null || true)
    cargo bench --bench sweep_transfer
    echo "== bench gate: sweep_transfer vs committed rust/BENCH_transfer.json =="
    if [[ -z "$old_transfer" ]]; then
        echo "ci: no committed BENCH_transfer.json at HEAD — skipping transfer notes diff"
    else
        tbootstrap=0
        grep -q '"snapshot_bootstrap"' <<<"$old_transfer" && tbootstrap=1
        tfail=0
        while read -r key new; do
            oldv=$(notes <<<"$old_transfer" | awk -v k="$key" '$1==k{print $2; exit}')
            [[ -z "$oldv" || "$oldv" == "null" || "$new" == "null" ]] && continue
            case "$key" in
                *speedup*)
                    bad=$(awk -v n="$new" -v o="$oldv" 'BEGIN{print (n < 0.75*o) ? 1 : 0}')
                    kind="amortization regressed (new $new < 0.75 x old $oldv)" ;;
                *dispatches*)
                    # note the reused baseline is 0, so ANY dispatch on
                    # the reused path fails here — that is the contract
                    bad=$(awk -v n="$new" -v o="$oldv" 'BEGIN{print (n > 1.25*o) ? 1 : 0}')
                    kind="dispatch count grew (new $new > 1.25 x old $oldv)" ;;
                *err*)
                    bad=$(awk -v n="$new" -v o="$oldv" 'BEGIN{print (n > 1.25*o + 0.01) ? 1 : 0}')
                    kind="matching error grew (new $new > 1.25 x old $oldv + 0.01)" ;;
                *) continue ;;   # raw timings etc. are machine-dependent
            esac
            if [[ "$bad" == "1" ]]; then
                if [[ "$tbootstrap" == "1" ]]; then
                    echo "ci: WARN (bootstrap snapshot) — $key: $kind"
                else
                    echo "ci: FAIL — $key: $kind"
                    tfail=1
                fi
            fi
        done < <(notes < rust/BENCH_transfer.json)
        if [[ "$tfail" == "1" ]]; then
            echo "ci: FAIL — bench regression vs committed BENCH_transfer.json"
            exit 1
        fi
        echo "ci: transfer bench notes within tolerance"
        if [[ "$tbootstrap" == "1" ]]; then
            echo "ci: NOTE — committed transfer snapshot is still the hand-seeded bootstrap;"
            echo "    commit the freshly written rust/BENCH_transfer.json to arm the perf gate"
        fi
    fi
fi

if [[ "$fast" == "1" ]]; then
    echo "ci: fast mode — skipped fmt/clippy"
    exit 0
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "ci: rustfmt not installed — skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings =="
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "ci: clippy not installed — skipping lints"
fi

echo "ci: OK"
