//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The coordinator builds fully offline (no registry, no network), so the
//! small slice of `anyhow` the codebase uses is vendored here: a
//! message-carrying [`Error`], the [`Result`] alias, the [`anyhow!`] /
//! [`bail!`] macros, and the [`Context`] extension trait.  Error *chains*
//! are flattened into the message at wrap time — `{e}` and `{e:#}` both
//! print the full context string, which is all the call sites rely on.
//!
//! Like the real `anyhow::Error`, this type deliberately does **not**
//! implement `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` impl (and thus `?` on io/parse errors)
//! coherent.

use std::fmt;

/// A flattened, message-carrying error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Wrap anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line (`"{context}: {cause}"`).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy context to fallible results/options (the `anyhow` subset
/// the codebase uses).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_print_message() {
        let e = anyhow!("bad {} at {}", "thing", 7);
        assert_eq!(format!("{e}"), "bad thing at 7");
        assert_eq!(format!("{e:#}"), "bad thing at 7");
        assert_eq!(format!("{e:?}"), "bad thing at 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(format!("{}", inner().unwrap_err()).contains("gone"));
    }

    #[test]
    fn with_context_prefixes() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x.json")).unwrap_err();
        assert_eq!(format!("{e}"), "reading x.json: gone");
    }

    #[test]
    fn bail_returns_early() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(3)
        }
        assert_eq!(f(false).unwrap(), 3);
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }
}
