//! Checkpointing: save/restore model state (params + momenta) to disk in a
//! small self-describing binary format, so long experiments can resume and
//! the examples can hand models between runs.
//!
//! Format (little-endian):
//! ```text
//! magic  "GMCK1\0"          6 bytes
//! model  name-len u32 + utf-8 bytes
//! epoch  u64
//! dims   d,h,c u32 ×3       (validated against the manifest on load)
//! state  2·(d·h + h + h·c + c) f32  (ModelState::pack layout)
//! crc    u32 (FNV-1a over the state bytes)
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{ModelMeta, ModelState};

const MAGIC: &[u8; 6] = b"GMCK1\0";

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h = 0x811c9dc5u32;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

/// Save a model state (+ the epoch it was taken at).
pub fn save(path: &Path, st: &ModelState, epoch: u64) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(MAGIC)?;
    let name = st.meta.name.as_bytes();
    f.write_all(&(name.len() as u32).to_le_bytes())?;
    f.write_all(name)?;
    f.write_all(&epoch.to_le_bytes())?;
    for v in [st.meta.d as u32, st.meta.h as u32, st.meta.c as u32] {
        f.write_all(&v.to_le_bytes())?;
    }
    let flat = st.pack();
    let bytes: Vec<u8> = flat.iter().flat_map(|v| v.to_le_bytes()).collect();
    f.write_all(&bytes)?;
    f.write_all(&fnv1a(&bytes).to_le_bytes())?;
    Ok(())
}

/// Load a model state; validates magic, model identity, dims, and checksum.
pub fn load(path: &Path, meta: &ModelMeta) -> Result<(ModelState, u64)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a gradmatch checkpoint", path.display());
    }
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let name_len = u32::from_le_bytes(u32buf) as usize;
    if name_len > 256 {
        bail!("checkpoint name too long");
    }
    let mut name = vec![0u8; name_len];
    f.read_exact(&mut name)?;
    let name = String::from_utf8(name).map_err(|_| anyhow!("bad checkpoint name"))?;
    if name != meta.name {
        bail!("checkpoint is for model '{name}', expected '{}'", meta.name);
    }
    let mut u64buf = [0u8; 8];
    f.read_exact(&mut u64buf)?;
    let epoch = u64::from_le_bytes(u64buf);
    let mut dims = [0u32; 3];
    for d in dims.iter_mut() {
        f.read_exact(&mut u32buf)?;
        *d = u32::from_le_bytes(u32buf);
    }
    if dims != [meta.d as u32, meta.h as u32, meta.c as u32] {
        bail!("checkpoint dims {dims:?} do not match manifest");
    }
    let n_state = 2 * (meta.d * meta.h + meta.h + meta.h * meta.c + meta.c);
    let mut bytes = vec![0u8; n_state * 4];
    f.read_exact(&mut bytes)?;
    f.read_exact(&mut u32buf)?;
    let want_crc = u32::from_le_bytes(u32buf);
    if fnv1a(&bytes) != want_crc {
        bail!("{}: checksum mismatch (corrupt checkpoint)", path.display());
    }
    let flat: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((ModelState::unpack(meta, &flat), epoch))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn meta() -> ModelMeta {
        let m = Manifest::parse(
            r#"{"format":1,"interchange":"hlo-text","models":{"m1":{"d":4,"h":3,
            "c":2,"batch":8,"chunk":16,"p":8,"momentum":0.9,"weight_decay":0.0005,
            "entries":{}}}}"#,
        )
        .unwrap();
        m.models["m1"].clone()
    }

    fn sample_state(meta: &ModelMeta) -> ModelState {
        let mut st = ModelState::new(
            meta,
            (0..12).map(|v| v as f32 * 0.5).collect(),
            vec![1.0, 2.0, 3.0],
            (0..6).map(|v| -(v as f32)).collect(),
            vec![0.1, 0.2],
        );
        st.m_w1[3] = 7.5;
        st
    }

    #[test]
    fn roundtrip_preserves_state_and_epoch() {
        let meta = meta();
        let st = sample_state(&meta);
        let dir = std::env::temp_dir().join("gm_ckpt_test");
        let path = dir.join("a.ckpt");
        save(&path, &st, 42).unwrap();
        let (st2, epoch) = load(&path, &meta).unwrap();
        assert_eq!(epoch, 42);
        assert_eq!(st.w1, st2.w1);
        assert_eq!(st.b2, st2.b2);
        assert_eq!(st.m_w1, st2.m_w1);
    }

    #[test]
    fn rejects_wrong_model() {
        let meta = meta();
        let st = sample_state(&meta);
        let path = std::env::temp_dir().join("gm_ckpt_test/b.ckpt");
        save(&path, &st, 1).unwrap();
        let mut other = meta.clone();
        other.name = "different".into();
        assert!(load(&path, &other).is_err());
    }

    #[test]
    fn rejects_corruption() {
        let meta = meta();
        let st = sample_state(&meta);
        let path = std::env::temp_dir().join("gm_ckpt_test/c.ckpt");
        save(&path, &st, 1).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path, &meta).is_err());
    }

    #[test]
    fn rejects_non_checkpoint_file() {
        let meta = meta();
        let path = std::env::temp_dir().join("gm_ckpt_test/d.ckpt");
        std::fs::write(&path, b"hello world, definitely not a checkpoint").unwrap();
        assert!(load(&path, &meta).is_err());
    }
}
