//! Bench harness (criterion is not in the offline vendor set).
//!
//! Every file under `rust/benches/` is a `harness = false` binary that uses
//! this module to (a) run miniature paper-shaped experiments and (b) print
//! the same rows/series the paper's tables and figures report.  Bench
//! configs are deliberately small (tiny datasets, tens of epochs) so the
//! whole `cargo bench` suite completes on one CPU core; the full-scale runs
//! live in `examples/`.

use std::time::Instant;

use crate::config::ExperimentConfig;

/// Miniature experiment base config shared by the benches: small synthetic
/// dataset, short schedule, frequent re-selection so every code path runs.
pub fn bench_config(dataset: &str, model: &str) -> ExperimentConfig {
    ExperimentConfig {
        dataset: dataset.into(),
        model: model.into(),
        strategy: "gradmatch-pb".into(),
        budget_frac: 0.1,
        epochs: 12,
        r_interval: 4,
        lr0: 0.05,
        lambda: 0.5,
        eps: 1e-10,
        kappa: 0.5,
        seed: 42,
        runs: 1,
        artifacts_dir: artifacts_dir(),
        out_dir: "results/bench".into(),
        eval_every: 0,
        is_valid: false,
        n_train: 1200,
        imbalance_frac: 0.3,
        imbalance_keep: 0.1,
        label_noise: 0.0,
        overlap: false,
        max_staged_rows: 0,
        sketch_width: 0,
        reuse_across_arms: false,
    }
}

/// Artifact dir: honor `GRADMATCH_ARTIFACTS` (CI) else `artifacts`.
pub fn artifacts_dir() -> String {
    std::env::var("GRADMATCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Time a closure once (end-to-end benches) — returns (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Time a closure `iters` times and report best/mean (micro benches).
pub fn bench_iters<T>(label: &str, iters: usize, mut f: impl FnMut() -> T) -> (f64, f64) {
    assert!(iters > 0);
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = f();
        times.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(out);
    }
    let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = times.iter().sum::<f64>() / iters as f64;
    println!("  {label:<48} best {:>9.3}ms  mean {:>9.3}ms  ({iters} iters)", best * 1e3, mean * 1e3);
    (best, mean)
}

/// Section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// The `q`-th percentile (0.0..=1.0) of `samples` by nearest-rank on a
/// sorted copy — tail-latency reporting for the daemon stress bench
/// (`percentile(&lat, 0.99)` = p99).  NaN samples are dropped; an empty
/// slice reports 0.0 so a bench with a failed phase still writes its
/// report instead of panicking.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    let mut v: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = q.clamp(0.0, 1.0);
    let rank = ((q * v.len() as f64).ceil() as usize).clamp(1, v.len());
    v[rank - 1]
}

// ---------------------------------------------------------------------------
// machine-readable bench reports (the perf trajectory)
// ---------------------------------------------------------------------------

/// One timed record: label + best/mean seconds + iteration count.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub label: String,
    pub best_s: f64,
    pub mean_s: f64,
    pub iters: usize,
}

/// Collects [`bench_iters`]-style timings plus derived scalar notes
/// (speedups, check outcomes) and writes them as JSON so successive PRs
/// can diff the perf trajectory (`BENCH_micro.json` et al.).
#[derive(Default)]
pub struct BenchReport {
    pub bench: String,
    pub records: Vec<BenchRecord>,
    pub notes: Vec<(String, f64)>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl BenchReport {
    pub fn new(bench: &str) -> Self {
        BenchReport { bench: bench.into(), records: Vec::new(), notes: Vec::new() }
    }

    /// Time `f` like [`bench_iters`] and record the result.
    pub fn rec<T>(&mut self, label: &str, iters: usize, f: impl FnMut() -> T) -> (f64, f64) {
        let (best, mean) = bench_iters(label, iters, f);
        self.records.push(BenchRecord { label: label.into(), best_s: best, mean_s: mean, iters });
        (best, mean)
    }

    /// Record a derived scalar (speedup factor, pass/fail as 1/0, …).
    pub fn note(&mut self, key: &str, value: f64) {
        self.notes.push((key.into(), value));
    }

    /// Fold one engine round's observability
    /// ([`crate::engine::RoundStats`]) into the notes under `label/…` —
    /// how `SelectionReport`s land in `BENCH_micro.json` so the perf
    /// trajectory tracks the staging/solve split PR-over-PR.
    #[cfg(feature = "xla")]
    pub fn note_round(&mut self, label: &str, stats: &crate::engine::RoundStats) {
        self.note(&format!("{label}/stage_secs"), stats.stage_secs);
        self.note(&format!("{label}/solve_secs"), stats.solve_secs);
        self.note(&format!("{label}/stage_dispatches"), stats.stage_dispatches as f64);
        self.note(
            &format!("{label}/stage_shared"),
            if stats.stage_shared { 1.0 } else { 0.0 },
        );
        self.note(&format!("{label}/fanout"), if stats.fanout { 1.0 } else { 0.0 });
        self.note(&format!("{label}/engine_round"), stats.engine_round as f64);
        self.note(
            &format!("{label}/stage_reused_buffers"),
            if stats.stage_reused_buffers { 1.0 } else { 0.0 },
        );
        self.note(&format!("{label}/retries"), stats.retries as f64);
        self.note(&format!("{label}/quarantined"), stats.quarantined as f64);
        self.note(
            &format!("{label}/degradation"),
            match stats.degradation {
                crate::engine::Degradation::None => 0.0,
                crate::engine::Degradation::ReusedLastRound => 1.0,
                crate::engine::Degradation::RandomFallback => 2.0,
            },
        );
        self.note(&format!("{label}/shards"), stats.shards as f64);
        self.note(&format!("{label}/shard_stage_secs"), stats.shard_stage_secs);
        self.note(&format!("{label}/merge_candidates"), stats.merge_candidates as f64);
        self.note(&format!("{label}/peak_staged_rows"), stats.peak_staged_rows as f64);
    }

    /// Serialize to JSON text.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.bench)));
        s.push_str("  \"records\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"label\": \"{}\", \"best_ms\": {:.6}, \"mean_ms\": {:.6}, \"iters\": {}}}{}\n",
                json_escape(&r.label),
                r.best_s * 1e3,
                r.mean_s * 1e3,
                r.iters,
                if i + 1 < self.records.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"notes\": {\n");
        for (i, (k, v)) in self.notes.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {}{}\n",
                json_escape(k),
                if v.is_finite() { format!("{v:.6}") } else { "null".into() },
                if i + 1 < self.notes.len() { "," } else { "" }
            ));
        }
        s.push_str("  }\n}\n");
        s
    }

    /// Write the JSON report; prints the destination for the console log.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())?;
        println!("\nwrote {path} ({} records, {} notes)", self.records.len(), self.notes.len());
        Ok(())
    }
}

/// Resolve where a bench report file lands: `$BENCH_OUT_DIR/<file>` when
/// the env var is set (the directory is created if missing), else bare
/// `<file>` — i.e. the cargo working directory, unchanged historical
/// behavior.  Every bench binary routes its `BENCH_*.json` through this
/// so CI and local runs cannot silently write to different places.
pub fn bench_out_path(file: &str) -> String {
    match std::env::var("BENCH_OUT_DIR") {
        Ok(dir) if !dir.is_empty() => {
            let _ = std::fs::create_dir_all(&dir);
            format!("{}/{file}", dir.trim_end_matches('/'))
        }
        _ => file.to_string(),
    }
}

/// Table header/row printing with fixed column layout.
pub fn table_header(cols: &[&str]) {
    let row = cols
        .iter()
        .map(|c| format!("{c:>14}"))
        .collect::<Vec<_>>()
        .join(" ");
    println!("{row}");
    println!("{}", "-".repeat(row.len().min(120)));
}

pub fn table_row(cells: &[String]) {
    println!(
        "{}",
        cells.iter().map(|c| format!("{c:>14}")).collect::<Vec<_>>().join(" ")
    );
}

/// `assert!`-like check that prints PASS/FAIL without aborting the bench —
/// the benches verify the paper-*shaped* relationships (who wins, rough
/// factors) and report them inline.
pub fn shape_check(label: &str, ok: bool) -> bool {
    println!("  shape-check [{}] {label}", if ok { "PASS" } else { "FAIL" });
    ok
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_config_is_miniature() {
        let c = bench_config("synmnist", "lenet_s");
        assert!(c.epochs <= 20);
        assert!(c.n_train > 0 && c.n_train <= 2000);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn timed_measures() {
        let (v, secs) = timed(|| {
            std::thread::sleep(std::time::Duration::from_millis(3));
            7
        });
        assert_eq!(v, 7);
        assert!(secs >= 0.002);
    }

    #[test]
    fn bench_iters_runs_all() {
        let mut count = 0;
        let (best, mean) = bench_iters("noop", 5, || {
            count += 1;
            count
        });
        assert_eq!(count, 5);
        assert!(best <= mean);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.99), 99.0);
        assert_eq!(percentile(&v, 0.5), 50.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.0), 1.0, "q=0 clamps to the minimum");
        assert_eq!(percentile(&[], 0.5), 0.0, "empty input must not panic");
        assert_eq!(percentile(&[f64::NAN, 3.0], 0.99), 3.0, "NaNs dropped");
        // unsorted input is handled
        assert_eq!(percentile(&[5.0, 1.0, 3.0], 0.5), 3.0);
    }

    #[test]
    fn shape_check_passthrough() {
        assert!(shape_check("x", true));
        assert!(!shape_check("y", false));
    }

    #[test]
    fn bench_report_json_is_parseable() {
        let mut rep = BenchReport::new("unit");
        rep.rec("noop \"quoted\"", 2, || 1 + 1);
        rep.note("speedup", 2.5);
        rep.note("pass", 1.0);
        let j = crate::jsonlite::Json::parse(&rep.to_json()).unwrap();
        assert_eq!(j.get("bench").and_then(crate::jsonlite::Json::as_str), Some("unit"));
        let recs = j.get("records").and_then(crate::jsonlite::Json::as_arr).unwrap();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].get("best_ms").and_then(crate::jsonlite::Json::as_f64).is_some());
        let notes = j.get("notes").and_then(crate::jsonlite::Json::as_obj).unwrap();
        assert_eq!(notes.len(), 2);
    }
}
