"""Pure-jnp oracles for the Pallas kernels in ``gradmatch_kernels.py``.

Every kernel has a reference implementation here written with plain
``jax.numpy`` ops only.  The pytest suite (``python/tests/test_kernels.py``)
sweeps shapes/dtypes with hypothesis and asserts ``allclose`` between kernel
and oracle — this is the L1 correctness signal for the whole stack, because
the same kernels are baked into the AOT'd HLO the Rust coordinator executes.
"""

from __future__ import annotations

import jax.numpy as jnp


def per_sample_grads_ref(h, err):
    """Per-sample last-layer gradient matrix.

    For sample i with hidden activations ``h[i] : [H]`` and softmax error
    ``err[i] = softmax(logits)_i - onehot(y_i) : [C]`` (already scaled by any
    mask), the gradient of the cross-entropy w.r.t. the last linear layer
    ``(W2[H,C], b2[C])`` is the rank-1 outer product ``h_i ⊗ err_i`` plus
    ``err_i`` for the bias.  Returns ``G : [N, H*C + C]`` with the W2 block
    flattened in row-major [H, C] order followed by the bias block.
    """
    n, hdim = h.shape
    c = err.shape[1]
    outer = h[:, :, None] * err[:, None, :]          # [N, H, C]
    return jnp.concatenate([outer.reshape(n, hdim * c), err], axis=1)


def corr_ref(g, r):
    """OMP residual correlations: ``G @ r`` for ``G : [N, P]``, ``r : [P]``."""
    return g @ r


def sqdist_ref(a, b):
    """Pairwise squared euclidean distances ``D[i,j] = ||a_i - b_j||^2``."""
    a2 = jnp.sum(a * a, axis=1)[:, None]
    b2 = jnp.sum(b * b, axis=1)[None, :]
    cross = a @ b.T
    return jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)


def weighted_gradsum_ref(g, w):
    """Weighted column sum ``Gᵀ w`` — the subset's matched gradient."""
    return g.T @ w
