//! Minimal dense f32 linear algebra for the coordinator side.
//!
//! The *hot* gradient math runs inside the AOT'd XLA executables (L1/L2);
//! this module provides the coordinator-side pieces — the Rust OMP backend
//! used for per-class-per-gradient slices, small normal-equation systems,
//! diagnostics, and a reference implementation the runtime tests compare
//! against.  Row-major `Matrix` + free-function kernels, no generics, no
//! allocation in inner loops.

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c` (rows are contiguous, columns are not).
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// Gather a sub-matrix of the given rows.
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Gather a sub-matrix of the given columns.
    pub fn gather_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for r in 0..self.rows {
            for (j, &c) in idx.iter().enumerate() {
                out.data[r * idx.len() + j] = self.at(r, c);
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

// ---------------------------------------------------------------------------
// vector kernels
// ---------------------------------------------------------------------------

/// Dot product. Accumulates in f64 for stability on long gradient vectors.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        acc += a[i] as f64 * b[i] as f64;
    }
    acc as f32
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// `a - b` into a new vector.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Squared euclidean distance between two rows.
#[inline]
pub fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        acc += d * d;
    }
    acc as f32
}

/// Index of the maximum value. Panics on empty input.
///
/// NaN-safe: NaN entries never win (every comparison with NaN is false,
/// and a NaN is never adopted as the running best).  Ties keep the
/// *first* occurrence.  If *every* entry is NaN, index 0 is returned —
/// callers treating the result as "no signal" get a stable answer
/// instead of whichever NaN happened to sit first in a naive scan.
pub fn argmax(xs: &[f32]) -> usize {
    assert!(!xs.is_empty());
    let mut best: Option<usize> = None;
    for (i, &v) in xs.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) => {
                if v > xs[b] {
                    best = Some(i);
                }
            }
        }
    }
    best.unwrap_or(0)
}

// ---------------------------------------------------------------------------
// matrix kernels
// ---------------------------------------------------------------------------

/// `out = M v` (GEMV).  Rows are contiguous so this is cache-friendly.
pub fn gemv(m: &Matrix, v: &[f32], out: &mut [f32]) {
    assert_eq!(m.cols, v.len());
    assert_eq!(m.rows, out.len());
    for r in 0..m.rows {
        out[r] = dot(m.row(r), v);
    }
}

/// `out = Mᵀ v` without forming the transpose (column accumulation).
pub fn gemv_t(m: &Matrix, v: &[f32], out: &mut [f32]) {
    assert_eq!(m.rows, v.len());
    assert_eq!(m.cols, out.len());
    out.fill(0.0);
    for r in 0..m.rows {
        axpy(v[r], m.row(r), out);
    }
}

/// `C = A B` — blocked ikj loop; adequate for the coordinator-side sizes
/// (support matrices k ≤ a few hundred). Big GEMMs live in XLA.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "gemm: inner dims");
    let mut c = Matrix::zeros(a.rows, b.cols);
    const BLK: usize = 64;
    for kk in (0..a.cols).step_by(BLK) {
        let kend = (kk + BLK).min(a.cols);
        for i in 0..a.rows {
            let arow = a.row(i);
            let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
            for k in kk..kend {
                let aik = arow[k];
                if aik != 0.0 {
                    axpy(aik, b.row(k), crow);
                }
            }
        }
    }
    c
}

/// Gram matrix `A Aᵀ` (symmetric, computed once per OMP support update).
pub fn gram(a: &Matrix) -> Matrix {
    let mut g = Matrix::zeros(a.rows, a.rows);
    for i in 0..a.rows {
        for j in i..a.rows {
            let v = dot(a.row(i), a.row(j));
            g.data[i * a.rows + j] = v;
            g.data[j * a.rows + i] = v;
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn matrix_basics() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(m.col(2), vec![0.0, 5.0]);
    }

    #[test]
    fn eye_is_identity_under_gemm() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = Matrix::eye(2);
        assert_eq!(gemm(&a, &i), a);
        assert_eq!(gemm(&i, &a), a);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(2, 1), 6.0);
    }

    #[test]
    fn dot_axpy_norm() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, -5.0, 6.0];
        approx(dot(&a, &b), 12.0, 1e-6);
        let mut y = b;
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [6.0, -1.0, 12.0]);
        approx(norm2(&[3.0, 4.0]), 5.0, 1e-6);
    }

    #[test]
    fn gemv_matches_manual() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut out = vec![0.0; 2];
        gemv(&m, &[1.0, 0.0, -1.0], &mut out);
        assert_eq!(out, vec![-2.0, -2.0]);
    }

    #[test]
    fn gemv_t_matches_explicit_transpose() {
        let m = Matrix::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let v = [1.0f32, -1.0, 2.0];
        let mut fast = vec![0.0; 2];
        gemv_t(&m, &v, &mut fast);
        let t = m.transpose();
        let mut slow = vec![0.0; 2];
        gemv(&t, &v, &mut slow);
        assert_eq!(fast, slow);
    }

    #[test]
    fn gemm_matches_manual_3x3() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = gemm(&a, &b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let a = Matrix::from_vec(3, 4, (0..12).map(|v| v as f32 * 0.3 - 1.0).collect());
        let g = gram(&a);
        for i in 0..3 {
            assert!(g.at(i, i) >= 0.0);
            for j in 0..3 {
                approx(g.at(i, j), g.at(j, i), 1e-6);
            }
        }
        approx(g.at(0, 0), dot(a.row(0), a.row(0)), 1e-5);
    }

    #[test]
    fn gather_rows_cols() {
        let a = Matrix::from_vec(3, 3, (1..=9).map(|v| v as f32).collect());
        let r = a.gather_rows(&[2, 0]);
        assert_eq!(r.data, vec![7., 8., 9., 1., 2., 3.]);
        let c = a.gather_cols(&[1]);
        assert_eq!(c.data, vec![2., 5., 8.]);
    }

    #[test]
    fn sqdist_and_argmax() {
        approx(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0, 1e-6);
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
    }

    #[test]
    fn argmax_is_nan_safe() {
        // NaN entries must never win, wherever they sit
        assert_eq!(argmax(&[f32::NAN, 1.0, 3.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax(&[2.0, 7.0, f32::NAN]), 1);
        // ties keep the first occurrence even after a leading NaN
        assert_eq!(argmax(&[f32::NAN, 4.0, 4.0]), 1);
        // negative values still beat "no candidate"
        assert_eq!(argmax(&[f32::NAN, -2.0, -1.0, f32::NAN]), 2);
        // all-NaN input degrades to index 0 (documented fallback)
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        // -inf/inf still behave
        assert_eq!(argmax(&[f32::NEG_INFINITY, f32::INFINITY]), 1);
    }

    #[test]
    fn gemm_random_vs_naive() {
        let mut rng = crate::rng::Rng::new(11);
        let a = Matrix::from_vec(17, 23, (0..17 * 23).map(|_| rng.gaussian_f32()).collect());
        let b = Matrix::from_vec(23, 9, (0..23 * 9).map(|_| rng.gaussian_f32()).collect());
        let c = gemm(&a, &b);
        for i in 0..17 {
            for j in 0..9 {
                let mut acc = 0.0f32;
                for k in 0..23 {
                    acc += a.at(i, k) * b.at(k, j);
                }
                approx(c.at(i, j), acc, 1e-3);
            }
        }
    }
}
