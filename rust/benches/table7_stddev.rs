//! Table 7: standard deviation of test accuracy over repeated runs.
//! The paper's observation: warm/PB variants have lower variance than
//! RANDOM; variance grows as subsets shrink.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;
use gradmatch::stats;

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;
    bh::section("Table 7 — test-accuracy std-dev over 3 runs (synmnist)");
    bh::table_header(&["strategy", "std@5%", "std@30%", "mean@5%", "mean@30%"]);
    let mut rnd_std5 = 0.0;
    let mut gm_std5 = 0.0;
    for strat in ["random", "glister", "craig-pb", "gradmatch-pb", "gradmatch-pb-warm"] {
        let mut stds = Vec::new();
        let mut means = Vec::new();
        for &b in &[0.05, 0.30] {
            let mut cfg = bh::bench_config("synmnist", "lenet_s");
            cfg.strategy = strat.into();
            cfg.budget_frac = b;
            cfg.epochs = 10;
            cfg.r_interval = 5;
            cfg.runs = 3;
            let runs = coord.run_multi(&cfg)?;
            let accs: Vec<f64> = runs.iter().map(|r| r.test_acc * 100.0).collect();
            stds.push(stats::stddev(&accs));
            means.push(stats::mean(&accs));
        }
        bh::table_row(&[
            strat.into(),
            format!("{:.3}", stds[0]),
            format!("{:.3}", stds[1]),
            format!("{:.2}", means[0]),
            format!("{:.2}", means[1]),
        ]);
        if strat == "random" {
            rnd_std5 = stds[0];
        }
        if strat == "gradmatch-pb-warm" {
            gm_std5 = stds[0];
        }
    }
    let ok = bh::shape_check(
        "table7: gradmatch-pb-warm variance <= random variance at 5%",
        gm_std5 <= rnd_std5 + 0.5,
    );
    println!("\ntable7_stddev: {}", if ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
