//! Figure-3-style accuracy/efficiency trade-off sweep: every strategy ×
//! every budget on a dataset, printed as the scatter data (relative error
//! vs speedup, both w.r.t. full training) plus the Fig.-1 efficiency
//! summary.
//!
//! ```bash
//! cargo run --release --example tradeoff_sweep -- --dataset synmnist \
//!     --budgets 0.01,0.03,0.05,0.1 --epochs 60 --n-train 6000
//! ```

use anyhow::{anyhow, Result};
use gradmatch::cli::Cli;
use gradmatch::coordinator::{write_results, Coordinator};
use gradmatch::selection::paper_strategies;

fn main() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    args.insert(0, "sweep".into());
    let cli = Cli::parse(&args)?;
    let mut cfg = cli.experiment_config()?;
    if cli.flag("epochs").is_none() {
        cfg.epochs = 60;
    }
    if cli.flag("n-train").is_none() {
        cfg.n_train = 6000;
    }
    cfg.r_interval = cfg.r_interval.min(20);

    let budgets: Vec<f64> = match cli.flag_list("budgets") {
        Some(bs) => bs
            .iter()
            .map(|b| b.parse().map_err(|e| anyhow!("budget {b}: {e}")))
            .collect::<Result<_>>()?,
        None => vec![0.05, 0.10, 0.20, 0.30],
    };
    let strategies: Vec<String> = cli
        .flag_list("strategies")
        .unwrap_or_else(|| paper_strategies().into_iter().map(str::to_string).collect());
    let strat_refs: Vec<&str> = strategies.iter().map(String::as_str).collect();

    println!(
        "trade-off sweep: dataset={} epochs={} budgets={:?}",
        cfg.dataset, cfg.epochs, budgets
    );
    let mut coord = Coordinator::new(&cfg.artifacts_dir)?;
    let rows = coord.sweep(&cfg, &strat_refs, &budgets)?;

    println!("\nfull-training skyline acc: {:.2}%\n", rows[0].full_acc * 100.0);
    println!("speedup-vs-relative-error scatter (paper Fig. 3):");
    for row in &rows {
        println!("  {}", row.format());
    }

    // Fig. 1 style efficiency summary for the paper's flagship variant
    println!("\nFig.-1 efficiency summary (gradmatch-pb-warm):");
    for row in rows.iter().filter(|r| r.summary.strategy == "gradmatch-pb-warm") {
        println!(
            "  {:>3.0}% subset -> {:>5.2}x speedup at {:>5.2}% accuracy drop (selection: stage {:.2}s / solve {:.2}s over {} rounds)",
            row.summary.budget_frac * 100.0,
            row.speedup,
            row.rel_err_pct,
            row.summary.select_stage_secs,
            row.summary.select_solve_secs,
            row.summary.selections
        );
    }

    let summaries: Vec<_> = rows.into_iter().map(|r| r.summary).collect();
    let path = write_results(&cfg.out_dir, &format!("tradeoff_{}", cfg.dataset), &summaries)?;
    println!("\nwrote {path}");
    Ok(())
}
