//! The selection-engine API: a typed [`SelectionRequest`] in, a
//! shared-staging [`SelectionEngine`] round, a structured
//! [`SelectionReport`] out.
//!
//! Algorithm 1 of the paper is one round of gradient staging followed by
//! an OMP solve.  Before this module, every caller (trainer, overlap
//! worker, benches, examples) hand-assembled a mutable
//! [`SelectCtx`](crate::selection::SelectCtx) and called
//! [`Strategy::select`](crate::selection::Strategy::select), so each
//! strategy re-staged its own gradients and the only output was a bare
//! index/weight list.  The engine makes the round a *service* boundary:
//!
//! - [`SelectionRequest`] — a plain, serializable description of one
//!   selection round (strategy spec, budget, λ/ε, ground set,
//!   train-vs-val matching, seed), constructible from
//!   [`ExperimentConfig`] and from CLI flags.
//! - [`SelectionEngine`] — owns the round: a live `Runtime` + model
//!   snapshot (or, for device-free tests and benches, an explicit
//!   [`GradOracle`]) plus a **round-scoped staging cache**
//!   ([`RoundShared`]), so N requests against the same model state — a
//!   strategy sweep, GRAD-MATCH + CRAIG in one round, warm + cold
//!   variants — share ONE [`grads::stage_class_grads`] pass instead of
//!   N.  Strategies are stateless solvers over the staged views; the
//!   old `parse_strategy` + `select` path still works and now rides the
//!   same solvers (with `round: None`, i.e. private staging).
//! - [`SelectionReport`] — the [`Selection`] plus per-round
//!   observability: staging/solve wall-clock split, staging dispatch
//!   count, per-class budgets from `split_budget`, residual
//!   `grad_error`, and the fan-out-vs-serial decision.  Serialized via
//!   [`crate::jsonlite`] into `RunSummary` and `BENCH_micro.json`.
//!
//! The engine is **round-scoped**: one engine per model state.  Build a
//! fresh engine after every parameter update (or call
//! [`SelectionEngine::reset_round`]) — staged gradients are only valid
//! for the snapshot they were computed against.
//!
//! Dispatch contract (pinned by the counting-oracle test in
//! `tests/engine_api.rs`): a multi-strategy round over the class-sliced
//! stage costs exactly `⌈|ground|/chunk⌉` gradient dispatches however
//! many requests consume it.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::grads::{self, ClassStage, GradOracle, StageWidth};
use crate::jsonlite::{arr, num, obj, s, Json};
use crate::rng::Rng;
use crate::runtime::{ModelState, Runtime};
use crate::selection::{
    glister_rank, live_flags, omp_fanout_wins, parse_strategy, solve_classes_fl,
    solve_classes_omp, split_budget, staged_targets, SelectCtx, Selection, Strategy,
};

// ---------------------------------------------------------------------------
// SelectionRequest
// ---------------------------------------------------------------------------

/// A plain description of one selection round — everything the engine
/// needs to reproduce the round, and nothing tied to a live runtime, so
/// requests serialize, cross threads, and batch.
#[derive(Clone, Debug, PartialEq)]
pub struct SelectionRequest {
    /// strategy spec, e.g. `gradmatch-pb-warm` (see
    /// [`crate::selection::parse_strategy`]; the `-warm` suffix is the
    /// trainer's concern and is ignored by the engine)
    pub strategy: String,
    /// subset size k (samples)
    pub budget: usize,
    /// OMP ridge λ
    pub lambda: f32,
    /// OMP tolerance ε
    pub eps: f32,
    /// match validation gradients instead of training gradients (L = L_V)
    pub is_valid: bool,
    /// master run seed — combined with `rng_tag` into the round RNG
    pub seed: u64,
    /// per-round tag decorrelating rounds (the trainer uses 1000 + epoch)
    pub rng_tag: u64,
    /// ground set: dataset rows eligible for selection
    pub ground: Vec<usize>,
}

impl SelectionRequest {
    /// Build a request from an experiment config and a ground set; the
    /// budget is `budget_frac` of the ground size, clamped to `[1, n]`.
    /// (CLI flags reach here through
    /// [`crate::cli::Cli::experiment_config`].)
    pub fn from_config(cfg: &ExperimentConfig, ground: Vec<usize>) -> SelectionRequest {
        let n = ground.len();
        let budget = ((cfg.budget_frac * n as f64).round() as usize).clamp(1, n.max(1));
        SelectionRequest {
            strategy: cfg.strategy.clone(),
            budget,
            lambda: cfg.lambda as f32,
            eps: cfg.eps as f32,
            is_valid: cfg.is_valid,
            seed: cfg.seed,
            rng_tag: 0,
            ground,
        }
    }

    /// The round's RNG stream.  One derivation for every driver — the
    /// synchronous trainer, the overlap worker, and one-shot engine
    /// calls — so a round is reproducible from `(seed, rng_tag)` alone.
    pub fn round_rng(&self) -> Rng {
        Rng::new(self.seed ^ 0xDA7A).split(self.rng_tag)
    }

    /// Serialize for result files / cross-process hand-off.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("strategy", s(&self.strategy)),
            ("budget", num(self.budget as f64)),
            ("lambda", num(self.lambda as f64)),
            ("eps", num(self.eps as f64)),
            ("is_valid", Json::Bool(self.is_valid)),
            // u64 as decimal strings: f64 JSON numbers lose integers
            // above 2^53, and the round RNG must survive hand-off exactly
            ("seed", s(&self.seed.to_string())),
            ("rng_tag", s(&self.rng_tag.to_string())),
            (
                "ground",
                arr(self.ground.iter().map(|&i| num(i as f64)).collect()),
            ),
        ])
    }

    /// Inverse of [`SelectionRequest::to_json`].
    pub fn from_json(j: &Json) -> Result<SelectionRequest> {
        Ok(SelectionRequest {
            strategy: jstr(j, "strategy")?,
            budget: jusize(j, "budget")?,
            lambda: jf64(j, "lambda")? as f32,
            eps: jf64(j, "eps")? as f32,
            is_valid: jbool(j, "is_valid")?,
            seed: ju64(j, "seed")?,
            rng_tag: ju64(j, "rng_tag")?,
            ground: jusize_arr(j, "ground")?,
        })
    }
}

// ---------------------------------------------------------------------------
// SelectionReport
// ---------------------------------------------------------------------------

/// Per-round observability — the staging/solve decomposition of one
/// request.  Timings are wall-clock; `stage_*` covers the shared
/// [`grads::stage_class_grads`] pass (target/score passes count as
/// solve time).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundStats {
    /// seconds spent staging gradients (0 when served from the cache)
    pub stage_secs: f64,
    /// seconds spent in everything after staging (targets, solves, merge)
    pub solve_secs: f64,
    /// padded runtime dispatches the staging pass issued for this request
    /// (`⌈|ground|/chunk⌉` on a cache miss, 0 on a hit)
    pub stage_dispatches: usize,
    /// staged gradients were served from the round's shared cache
    pub stage_shared: bool,
    /// per-class budgets from `split_budget` (empty for strategies that
    /// do not decompose per class)
    pub class_budgets: Vec<usize>,
    /// the per-class solves fanned out across the machine
    /// ([`crate::par::fanout_wins`]) rather than running serially
    pub fanout: bool,
}

/// The engine's answer to one [`SelectionRequest`]: the selection itself
/// plus the round's observability.
#[derive(Clone, Debug, PartialEq)]
pub struct SelectionReport {
    /// the request's strategy spec, echoed
    pub strategy: String,
    /// the request's budget, echoed
    pub budget: usize,
    pub selection: Selection,
    pub stats: RoundStats,
}

impl SelectionReport {
    /// Serialize via [`crate::jsonlite`] (used by `RunSummary` and the
    /// bench reports).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("strategy", s(&self.strategy)),
            ("budget", num(self.budget as f64)),
            (
                "selection",
                obj(vec![
                    (
                        "indices",
                        arr(self.selection.indices.iter().map(|&i| num(i as f64)).collect()),
                    ),
                    (
                        "weights",
                        arr(self.selection.weights.iter().map(|&w| num(w as f64)).collect()),
                    ),
                    (
                        "grad_error",
                        self.selection.grad_error.map(|e| num(e as f64)).unwrap_or(Json::Null),
                    ),
                ]),
            ),
            (
                "round",
                obj(vec![
                    ("stage_secs", num(self.stats.stage_secs)),
                    ("solve_secs", num(self.stats.solve_secs)),
                    ("stage_dispatches", num(self.stats.stage_dispatches as f64)),
                    ("stage_shared", Json::Bool(self.stats.stage_shared)),
                    (
                        "class_budgets",
                        arr(self.stats.class_budgets.iter().map(|&b| num(b as f64)).collect()),
                    ),
                    ("fanout", Json::Bool(self.stats.fanout)),
                ]),
            ),
        ])
    }

    /// Inverse of [`SelectionReport::to_json`].
    pub fn from_json(j: &Json) -> Result<SelectionReport> {
        let sel = j
            .get("selection")
            .ok_or_else(|| anyhow!("report json: missing 'selection'"))?;
        let grad_error = match sel.get("grad_error") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64()
                    .ok_or_else(|| anyhow!("report json: bad 'grad_error'"))? as f32,
            ),
        };
        let weights = sel
            .get("weights")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("report json: missing 'weights'"))?
            .iter()
            .map(|v| v.as_f64().map(|w| w as f32))
            .collect::<Option<Vec<f32>>>()
            .ok_or_else(|| anyhow!("report json: bad weight"))?;
        let round = j
            .get("round")
            .ok_or_else(|| anyhow!("report json: missing 'round'"))?;
        Ok(SelectionReport {
            strategy: jstr(j, "strategy")?,
            budget: jusize(j, "budget")?,
            selection: Selection {
                indices: jusize_arr(sel, "indices")?,
                weights,
                grad_error,
            },
            stats: RoundStats {
                stage_secs: jf64(round, "stage_secs")?,
                solve_secs: jf64(round, "solve_secs")?,
                stage_dispatches: jusize(round, "stage_dispatches")?,
                stage_shared: jbool(round, "stage_shared")?,
                class_budgets: jusize_arr(round, "class_budgets")?,
                fanout: jbool(round, "fanout")?,
            },
        })
    }
}

// -- small jsonlite field readers -------------------------------------------

fn jstr(j: &Json, k: &str) -> Result<String> {
    j.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("json: missing string '{k}'"))
}

fn jf64(j: &Json, k: &str) -> Result<f64> {
    j.get(k)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("json: missing number '{k}'"))
}

fn jusize(j: &Json, k: &str) -> Result<usize> {
    j.get(k)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("json: missing integer '{k}'"))
}

fn jbool(j: &Json, k: &str) -> Result<bool> {
    j.get(k)
        .and_then(Json::as_bool)
        .ok_or_else(|| anyhow!("json: missing bool '{k}'"))
}

/// u64 field: decimal string (exact), with integral-number fallback for
/// hand-written documents.
fn ju64(j: &Json, k: &str) -> Result<u64> {
    match j.get(k) {
        Some(Json::Str(v)) => v
            .parse::<u64>()
            .map_err(|e| anyhow!("json: bad u64 '{k}': {e}")),
        Some(Json::Num(v)) if *v >= 0.0 && v.fract() == 0.0 && *v <= 9_007_199_254_740_992.0 => {
            Ok(*v as u64)
        }
        _ => Err(anyhow!("json: missing u64 '{k}'")),
    }
}

fn jusize_arr(j: &Json, k: &str) -> Result<Vec<usize>> {
    j.get(k)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("json: missing array '{k}'"))?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Option<Vec<usize>>>()
        .ok_or_else(|| anyhow!("json: bad integer in '{k}'"))
}

// ---------------------------------------------------------------------------
// RoundShared — the round-scoped staging cache + observability probe
// ---------------------------------------------------------------------------

/// FNV-1a over the ground indices — the cache key component that lets two
/// requests share a stage only when they select from the same ground set.
fn ground_fingerprint(ground: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &i in ground {
        h ^= i as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h ^ ground.len() as u64
}

/// Round-scoped engine state every request of the round borrows (through
/// `SelectCtx::round`): the staged-gradient cache — keyed by
/// `(StageWidth, ground fingerprint)` — and the per-request
/// observability probe.  The first request at a given key pays the
/// `⌈|ground|/chunk⌉`-dispatch staging pass; every later request reuses
/// the store for free.  Stages are always built with targets (the
/// accumulation costs host flops, not dispatches) so target-free
/// consumers like CRAIG share the target-bearing store with GRAD-MATCH.
#[derive(Default)]
pub struct RoundShared {
    stages: RefCell<HashMap<(StageWidth, u64), Arc<Vec<ClassStage>>>>,
    /// validation class means keyed by the live-flags vector (an
    /// `is_valid` sweep pays the per-class `[P]` readbacks once)
    val_means: RefCell<HashMap<Vec<bool>, Arc<Vec<Option<Vec<f32>>>>>>,
    probe: RefCell<RoundStats>,
}

impl RoundShared {
    pub fn new() -> RoundShared {
        RoundShared::default()
    }

    /// Fetch (or stage once) the per-class gradient matrices for `ground`
    /// at `width`, recording the staging time and dispatch count into the
    /// probe on a miss and the shared flag on a hit.
    pub fn class_stages(
        &self,
        oracle: &mut dyn GradOracle,
        ds: &Dataset,
        ground: &[usize],
        h: usize,
        c: usize,
        width: StageWidth,
    ) -> Result<Arc<Vec<ClassStage>>> {
        let key = (width, ground_fingerprint(ground));
        if let Some(hit) = self.stages.borrow().get(&key) {
            self.probe.borrow_mut().stage_shared = true;
            return Ok(hit.clone());
        }
        let chunk = oracle.chunk_rows().max(1);
        let t0 = Instant::now();
        let staged = Arc::new(grads::stage_class_grads_with(
            oracle, ds, ground, h, c, width, true,
        )?);
        {
            let mut probe = self.probe.borrow_mut();
            probe.stage_secs += t0.elapsed().as_secs_f64();
            probe.stage_dispatches += ground.len().div_ceil(chunk);
        }
        self.stages.borrow_mut().insert(key, staged.clone());
        Ok(staged)
    }

    /// Fetch (or compute once) the validation-side class means for a set
    /// of live-class flags — the L_V matching targets.  Cached like the
    /// stages: the readback-heavy fused per-class mean passes run once
    /// per distinct flag set, however many requests consume them.
    pub fn val_class_means(
        &self,
        oracle: &mut dyn GradOracle,
        val: &Dataset,
        c: usize,
        flags: &[bool],
    ) -> Result<Arc<Vec<Option<Vec<f32>>>>> {
        if let Some(hit) = self.val_means.borrow().get(flags) {
            return Ok(hit.clone());
        }
        let means = Arc::new(grads::live_val_class_means_with(oracle, val, c, flags)?);
        self.val_means.borrow_mut().insert(flags.to_vec(), means.clone());
        Ok(means)
    }

    /// Record the round's per-class budgets.
    pub fn note_budgets(&self, budgets: &[usize]) {
        self.probe.borrow_mut().class_budgets = budgets.to_vec();
    }

    /// Record the fan-out-vs-serial decision.
    pub fn note_fanout(&self, fanout: bool) {
        self.probe.borrow_mut().fanout = fanout;
    }

    /// Drain the probe for the request that just finished (the cache
    /// itself persists for the rest of the round).
    pub fn take_stats(&self) -> RoundStats {
        std::mem::take(&mut *self.probe.borrow_mut())
    }
}

// ---------------------------------------------------------------------------
// SelectionEngine
// ---------------------------------------------------------------------------

/// Gradient source backing an engine: the live PJRT runtime + model
/// snapshot, or an explicit oracle (tests/benches — covers the
/// device-free subset of the strategy space).
enum Backend<'a> {
    Live {
        rt: &'a Runtime,
        state: &'a ModelState,
    },
    Oracle {
        oracle: RefCell<&'a mut dyn GradOracle>,
        h: usize,
        c: usize,
    },
}

/// One selection round as a service: owns the gradient source and the
/// shared staging cache, answers [`SelectionRequest`]s with
/// [`SelectionReport`]s.  See the module docs for the sharing contract.
pub struct SelectionEngine<'a> {
    backend: Backend<'a>,
    train: &'a Dataset,
    val: &'a Dataset,
    shared: RoundShared,
    /// mini-batch size handed to strategy constructors (PB ground sets)
    batch: usize,
}

impl<'a> SelectionEngine<'a> {
    /// Live engine over a runtime and one model snapshot.
    pub fn new(
        rt: &'a Runtime,
        state: &'a ModelState,
        train: &'a Dataset,
        val: &'a Dataset,
    ) -> SelectionEngine<'a> {
        SelectionEngine {
            batch: state.meta.batch,
            backend: Backend::Live { rt, state },
            train,
            val,
            shared: RoundShared::default(),
        }
    }

    /// Device-free engine over an explicit [`GradOracle`] (`h`/`c` give
    /// the class column layout; the oracle's P must equal `h*c + c`).
    /// Serves the staged per-class strategies (GRAD-MATCH per-class
    /// variants, CRAIG's per-class arm, GLISTER, RANDOM, FULL); specs
    /// that need runtime entry points beyond gradients (PB variants,
    /// ENTROPY, FORGETTING, XLA solve arms) return an error.
    pub fn with_oracle(
        oracle: &'a mut dyn GradOracle,
        train: &'a Dataset,
        val: &'a Dataset,
        h: usize,
        c: usize,
    ) -> SelectionEngine<'a> {
        SelectionEngine {
            batch: 128,
            backend: Backend::Oracle { oracle: RefCell::new(oracle), h, c },
            train,
            val,
            shared: RoundShared::default(),
        }
    }

    /// The round's shared staging cache (what `SelectCtx::round` borrows).
    pub fn shared(&self) -> &RoundShared {
        &self.shared
    }

    /// Drop the round-scoped staging cache.  Call between model updates
    /// when reusing one engine value across rounds — staged gradients are
    /// only valid for the snapshot they were computed against.
    pub fn reset_round(&mut self) {
        self.shared = RoundShared::default();
    }

    /// Answer one request, resolving the strategy spec fresh.  Stateful
    /// baselines (FORGETTING) lose their cross-round memory on this path —
    /// drive those through [`SelectionEngine::select_with`] with a
    /// caller-held instance, as the trainer does.
    pub fn select(&self, req: &SelectionRequest) -> Result<SelectionReport> {
        match &self.backend {
            Backend::Live { .. } => {
                let (mut strategy, _warm) = parse_strategy(&req.strategy, self.batch)?;
                self.select_with(strategy.as_mut(), req)
            }
            Backend::Oracle { oracle, h, c } => {
                let t0 = Instant::now();
                let selection = {
                    let mut o = oracle.borrow_mut();
                    self.select_oracle(&mut **o, *h, *c, req)
                        .map_err(|e| self.drop_probe(e))?
                };
                Ok(self.report(req, selection, t0))
            }
        }
    }

    /// Answer one request with a caller-held strategy instance (stateful
    /// baselines keep their memory; the trainer keeps one instance per
    /// run).  Requires the live backend — strategies drive runtime entry
    /// points the oracle seam does not cover.
    pub fn select_with(
        &self,
        strategy: &mut dyn Strategy,
        req: &SelectionRequest,
    ) -> Result<SelectionReport> {
        let (rt, state) = match &self.backend {
            Backend::Live { rt, state } => (*rt, *state),
            Backend::Oracle { .. } => {
                return Err(anyhow!(
                    "select_with drives a caller-held Strategy and needs a live-runtime engine"
                ))
            }
        };
        let t0 = Instant::now();
        let mut rng = req.round_rng();
        let selection = strategy
            .select(&mut SelectCtx {
                rt,
                state,
                train: self.train,
                ground: &req.ground,
                val: self.val,
                budget: req.budget,
                lambda: req.lambda,
                eps: req.eps,
                is_valid: req.is_valid,
                rng: &mut rng,
                round: Some(&self.shared),
            })
            .map_err(|e| self.drop_probe(e))?;
        Ok(self.report(req, selection, t0))
    }

    /// Answer a batch of requests against this round's model state —
    /// the sweep entry point: every request that stages at the same
    /// `(width, ground)` key shares one staging pass.
    pub fn select_batch(&self, reqs: &[SelectionRequest]) -> Result<Vec<SelectionReport>> {
        reqs.iter().map(|r| self.select(r)).collect()
    }

    /// A failed request must not leak its probe (staging time/dispatches
    /// it already paid) into the next request's report.
    fn drop_probe(&self, e: anyhow::Error) -> anyhow::Error {
        let _ = self.shared.take_stats();
        e
    }

    fn report(&self, req: &SelectionRequest, selection: Selection, t0: Instant) -> SelectionReport {
        let total = t0.elapsed().as_secs_f64();
        let mut stats = self.shared.take_stats();
        stats.solve_secs = (total - stats.stage_secs).max(0.0);
        SelectionReport {
            strategy: req.strategy.clone(),
            budget: req.budget,
            selection,
            stats,
        }
    }

    /// The oracle-backed solve path: the same stateless solvers the
    /// `Strategy` impls consume, fed from the shared cache.
    fn select_oracle(
        &self,
        oracle: &mut dyn GradOracle,
        h: usize,
        c: usize,
        req: &SelectionRequest,
    ) -> Result<Selection> {
        let mut spec = req.strategy.trim().to_lowercase();
        if spec.ends_with("-warm") {
            spec.truncate(spec.len() - "-warm".len());
        }
        match spec.as_str() {
            "gradmatch" | "gradmatch-rust" => self.oracle_gradmatch(oracle, h, c, req, true),
            "gradmatch-perclass" => self.oracle_gradmatch(oracle, h, c, req, false),
            "craig" => {
                let stages = self.shared.class_stages(
                    oracle,
                    self.train,
                    &req.ground,
                    h,
                    c,
                    StageWidth::ClassSlice,
                )?;
                let sizes: Vec<usize> = stages.iter().map(|st| st.rows.len()).collect();
                let budgets = split_budget(req.budget, &sizes);
                let (sel, fan) = solve_classes_fl(&stages, &budgets, true);
                self.shared.note_budgets(&budgets);
                self.shared.note_fanout(fan);
                Ok(sel)
            }
            "glister" => {
                let val_rows: Vec<usize> = (0..self.val.len()).collect();
                let v = grads::mean_gradient_with(oracle, self.val, &val_rows)?;
                let scores = grads::score_grads_with(oracle, self.train, &req.ground, &v)?;
                let (sel, budgets, fan) = glister_rank(self.train, &req.ground, &scores, req.budget);
                self.shared.note_budgets(&budgets);
                self.shared.note_fanout(fan);
                Ok(sel)
            }
            "random" => {
                let mut rng = req.round_rng();
                let k = req.budget.min(req.ground.len());
                let mut out = Selection::default();
                for j in rng.sample_indices(req.ground.len(), k) {
                    out.indices.push(req.ground[j]);
                    out.weights.push(1.0);
                }
                Ok(out)
            }
            "full" | "full-earlystop" => {
                let mut out = Selection::default();
                for &i in &req.ground {
                    out.indices.push(i);
                    out.weights.push(1.0);
                }
                Ok(out)
            }
            other => Err(anyhow!(
                "strategy '{other}' needs a live-runtime engine (the oracle backend covers \
                 gradmatch[-perclass], craig, glister, random, full)"
            )),
        }
    }

    fn oracle_gradmatch(
        &self,
        oracle: &mut dyn GradOracle,
        h: usize,
        c: usize,
        req: &SelectionRequest,
        per_gradient: bool,
    ) -> Result<Selection> {
        let width = if per_gradient { StageWidth::ClassSlice } else { StageWidth::Full };
        let stages =
            self.shared.class_stages(oracle, self.train, &req.ground, h, c, width)?;
        let sizes: Vec<usize> = stages.iter().map(|st| st.rows.len()).collect();
        let budgets = split_budget(req.budget, &sizes);
        let val_means = if req.is_valid {
            let flags = live_flags(&stages, &budgets, c);
            Some(self.shared.val_class_means(oracle, self.val, c, &flags)?)
        } else {
            None
        };
        let targets =
            staged_targets(&stages, h, c, per_gradient, val_means.as_ref().map(|v| v.as_slice()));
        self.shared.note_budgets(&budgets);
        self.shared.note_fanout(omp_fanout_wins(&stages, &budgets));
        solve_classes_omp(&stages, &budgets, &targets, req.lambda, req.eps, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrips() {
        let req = SelectionRequest {
            strategy: "gradmatch-pb-warm".into(),
            budget: 37,
            lambda: 0.5,
            eps: 1e-10,
            is_valid: true,
            // above 2^53: must survive exactly (u64s travel as strings)
            seed: u64::MAX - 7,
            rng_tag: 1004,
            ground: vec![3, 1, 4, 1, 5, 9],
        };
        let parsed = Json::parse(&req.to_json().dump()).unwrap();
        let back = SelectionRequest::from_json(&parsed).unwrap();
        assert_eq!(req, back);
    }

    #[test]
    fn request_from_config_clamps_budget() {
        let cfg = ExperimentConfig { budget_frac: 0.1, ..Default::default() };
        let req = SelectionRequest::from_config(&cfg, (0..50).collect());
        assert_eq!(req.budget, 5);
        assert_eq!(req.strategy, cfg.strategy);
        // degenerate ground sets still produce a sane request
        let tiny = SelectionRequest::from_config(&cfg, vec![7]);
        assert_eq!(tiny.budget, 1);
        let empty = SelectionRequest::from_config(&cfg, Vec::new());
        assert_eq!(empty.budget, 1);
        assert!(empty.ground.is_empty());
    }

    #[test]
    fn round_rng_is_reproducible_and_tag_sensitive() {
        let mut req = SelectionRequest::from_config(&ExperimentConfig::default(), vec![0, 1, 2]);
        req.rng_tag = 1003;
        let (mut a, mut b) = (req.round_rng(), req.round_rng());
        assert_eq!(a.next_u64(), b.next_u64());
        let mut other = req.clone();
        other.rng_tag = 1004;
        assert_ne!(req.round_rng().next_u64(), other.round_rng().next_u64());
    }

    #[test]
    fn report_json_roundtrips() {
        let rep = SelectionReport {
            strategy: "gradmatch".into(),
            budget: 12,
            selection: Selection {
                indices: vec![5, 2, 9],
                weights: vec![1.5, 0.25, 3.0],
                grad_error: Some(0.125),
            },
            stats: RoundStats {
                stage_secs: 0.5,
                solve_secs: 1.25,
                stage_dispatches: 4,
                stage_shared: false,
                class_budgets: vec![4, 0, 8],
                fanout: true,
            },
        };
        let parsed = Json::parse(&rep.to_json().dump()).unwrap();
        let back = SelectionReport::from_json(&parsed).unwrap();
        assert_eq!(rep, back);
        // grad_error = None survives as JSON null
        let mut no_err = rep.clone();
        no_err.selection.grad_error = None;
        let parsed = Json::parse(&no_err.to_json().dump()).unwrap();
        assert_eq!(SelectionReport::from_json(&parsed).unwrap(), no_err);
    }

    #[test]
    fn ground_fingerprint_separates_sets() {
        let a = ground_fingerprint(&[1, 2, 3]);
        let b = ground_fingerprint(&[3, 2, 1]);
        let c = ground_fingerprint(&[1, 2]);
        assert_eq!(a, ground_fingerprint(&[1, 2, 3]));
        assert_ne!(a, b, "order matters — stages scatter in ground order");
        assert_ne!(a, c);
    }
}
