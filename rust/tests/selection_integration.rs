//! Integration: every strategy produces valid selections over the live
//! runtime, and GRAD-MATCH's selections actually match gradients better
//! than random (the paper's core claim, in miniature).

mod common;

use common::{runtime, tiny_mnist};
use gradmatch::grads;
use gradmatch::rng::Rng;
use gradmatch::selection::{parse_strategy, GradSource, SelectCtx, Selection};
use gradmatch::tensor::Matrix;

const MODEL: &str = "lenet_narrow";

fn select_with(spec: &str, budget_frac: f64, seed: u64) -> (Selection, usize) {
    let rt = runtime();
    let st = rt.init(MODEL, seed as i32).unwrap();
    let splits = tiny_mnist(800);
    let ground: Vec<usize> = (0..splits.train.len()).collect();
    let budget = ((budget_frac * ground.len() as f64).round() as usize).max(1);
    let (mut strategy, _) = parse_strategy(spec, st.meta.batch).unwrap();
    let mut rng = Rng::new(seed);
    let sel = strategy
        .select(&mut SelectCtx {
            src: GradSource::Live { rt: &rt, state: &st },
            train: &splits.train,
            ground: &ground,
            val: &splits.val,
            budget,
            lambda: 0.5,
            eps: 1e-10,
            is_valid: false,
            rng: &mut rng,
            round: None,
        })
        .unwrap();
    (sel, budget)
}

#[test]
fn all_strategies_produce_valid_selections() {
    if !common::runtime_available() {
        return;
    }
    for spec in [
        "random",
        "full",
        "glister",
        "craig",
        "craig-pb",
        "gradmatch",
        "gradmatch-perclass",
        "gradmatch-pb",
        "entropy",
        "forgetting",
        "featurefl",
    ] {
        let (sel, budget) = select_with(spec, 0.10, 3);
        assert!(!sel.indices.is_empty(), "{spec}: empty selection");
        assert_eq!(sel.indices.len(), sel.weights.len(), "{spec}");
        assert!(sel.weights.iter().all(|&w| w >= 0.0), "{spec}: negative weight");
        assert!(sel.indices.iter().all(|&i| i < 800), "{spec}: oob index");
        // no duplicates
        let mut s = sel.indices.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), sel.indices.len(), "{spec}: duplicate index");
        if spec == "full" {
            assert_eq!(sel.indices.len(), 800);
        } else if spec != "gradmatch-pb" && spec != "craig-pb" {
            // PB variants quantize to whole mini-batches
            assert!(
                sel.indices.len() <= budget,
                "{spec}: {} > budget {budget}",
                sel.indices.len()
            );
        }
    }
}

#[test]
fn pb_variants_select_whole_batches() {
    if !common::runtime_available() {
        return;
    }
    let (sel, _) = select_with("gradmatch-pb", 0.33, 4);
    // 800 ground rows, batch 128: batches are 6×128 plus one 32-row tail;
    // a PB selection is a union of whole batches
    let rem = sel.indices.len() % 128;
    assert!(
        rem == 0 || rem == 800 % 128,
        "PB must select whole mini-batches, got {}",
        sel.indices.len()
    );
    // one weight per batch: at most #batches distinct weights
    let mut ws = sel.weights.clone();
    ws.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ws.dedup();
    assert!(ws.len() <= sel.indices.len() / 128 + 1);
}

#[test]
fn selections_are_deterministic_for_fixed_seed() {
    if !common::runtime_available() {
        return;
    }
    for spec in ["random", "gradmatch", "craig", "glister"] {
        let (a, _) = select_with(spec, 0.08, 5);
        let (b, _) = select_with(spec, 0.08, 5);
        assert_eq!(a.indices, b.indices, "{spec} not deterministic");
        assert_eq!(a.weights, b.weights, "{spec} weights not deterministic");
    }
}

#[test]
fn gradmatch_covers_every_class() {
    if !common::runtime_available() {
        return;
    }
    let (sel, _) = select_with("gradmatch", 0.10, 6);
    let splits = tiny_mnist(800);
    let mut seen = vec![false; 10];
    for &i in &sel.indices {
        seen[splits.train.y[i] as usize] = true;
    }
    assert!(seen.iter().all(|&s| s), "per-class OMP must hit all classes: {seen:?}");
}

#[test]
fn gradmatch_matches_gradient_better_than_random() {
    if !common::runtime_available() {
        return;
    }
    // The paper's Table 9, in miniature: gradient-matching error of the
    // GRAD-MATCH selection must beat a random subset of the same size.
    let rt = runtime();
    let st = rt.init(MODEL, 8).unwrap();
    let splits = tiny_mnist(800);
    let ground: Vec<usize> = (0..splits.train.len()).collect();
    let target = grads::mean_gradient(&rt, &st, &splits.train, &ground).unwrap();

    let err_of = |sel: &Selection| -> f32 {
        let store = grads::per_sample_grads(&rt, &st, &splits.train, &sel.indices).unwrap();
        let wsum: f32 = sel.weights.iter().sum();
        let norm_w: Vec<f32> = sel.weights.iter().map(|w| w / wsum.max(1e-9)).collect();
        grads::gradient_error(&store.g, &norm_w, &target)
    };

    let (gm, _) = select_with("gradmatch", 0.10, 8);
    let (rnd, _) = select_with("random", 0.10, 8);
    let (e_gm, e_rnd) = (err_of(&gm), err_of(&rnd));
    assert!(
        e_gm < e_rnd,
        "gradmatch err {e_gm} should beat random err {e_rnd}"
    );
}

#[test]
fn gradmatch_pb_error_decreases_with_budget() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let st = rt.init(MODEL, 9).unwrap();
    let splits = tiny_mnist(900);
    let ground: Vec<usize> = (0..splits.train.len()).collect();
    let mut errs = Vec::new();
    for frac in [0.15, 0.45, 0.9] {
        let budget = (frac * 900.0) as usize;
        let (mut strategy, _) = parse_strategy("gradmatch-pb", 128).unwrap();
        let mut rng = Rng::new(77); // same shuffle each time
        let sel = strategy
            .select(&mut SelectCtx {
                src: GradSource::Live { rt: &rt, state: &st },
                train: &splits.train,
                ground: &ground,
                val: &splits.val,
                budget,
                lambda: 0.1,
                eps: 1e-12,
                is_valid: false,
                rng: &mut rng,
                round: None,
            })
            .unwrap();
        errs.push(sel.grad_error.expect("pb reports residual"));
    }
    assert!(
        errs[2] <= errs[0] + 1e-4,
        "more batches should not match worse: {errs:?}"
    );
}

#[test]
fn validation_matching_runs_under_imbalance() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let st = rt.init(MODEL, 10).unwrap();
    let splits = tiny_mnist(800);
    let mut rng = Rng::new(11);
    let ground = gradmatch::data::imbalance_indices(&splits.train, 0.3, 0.1, &mut rng);
    assert!(ground.len() < 800);
    for spec in ["gradmatch", "gradmatch-pb", "glister"] {
        let (mut strategy, _) = parse_strategy(spec, 128).unwrap();
        let mut srng = Rng::new(12);
        let sel = strategy
            .select(&mut SelectCtx {
                src: GradSource::Live { rt: &rt, state: &st },
                train: &splits.train,
                ground: &ground,
                val: &splits.val,
                budget: 80,
                lambda: 0.5,
                eps: 1e-10,
                is_valid: true,
                rng: &mut srng,
                round: None,
            })
            .unwrap();
        assert!(!sel.indices.is_empty(), "{spec}");
        // selections come from the (imbalanced) ground set only
        assert!(sel.indices.iter().all(|i| ground.contains(i)), "{spec}");
    }
}

#[test]
fn craig_weights_are_medoid_counts() {
    if !common::runtime_available() {
        return;
    }
    let (sel, _) = select_with("craig", 0.05, 13);
    // weights are counts: positive, and sum to roughly the ground size
    let per_class_total: f32 = sel.weights.iter().sum();
    assert!(per_class_total >= 800.0 * 0.99, "craig counts sum ~n: {per_class_total}");
    assert!(sel.weights.iter().all(|&w| w >= 0.0));
}

#[test]
fn xla_and_rust_gradmatch_agree_on_selection() {
    if !common::runtime_available() {
        return;
    }
    // per-class per-gradient path is rust-only; compare full-P per-class
    // (XLA corr) against the rust backend on identical inputs
    let rt = runtime();
    let st = rt.init(MODEL, 14).unwrap();
    let splits = tiny_mnist(500);
    let ground: Vec<usize> = (0..500).collect();
    let run = |use_xla: bool| -> Selection {
        let mut s = gradmatch::selection::GradMatch::new(
            gradmatch::selection::GradMatchVariant::PerBatch,
            64,
            use_xla,
        );
        let mut rng = Rng::new(15);
        gradmatch::selection::Strategy::select(
            &mut s,
            &mut SelectCtx {
                src: GradSource::Live { rt: &rt, state: &st },
                train: &splits.train,
                ground: &ground,
                val: &splits.val,
                budget: 192,
                lambda: 0.5,
                eps: 1e-10,
                is_valid: false,
                rng: &mut rng,
                round: None,
            },
        )
        .unwrap()
    };
    let a = run(true);
    let b = run(false);
    assert_eq!(a.indices, b.indices, "XLA and Rust corr backends must agree");
    for (wa, wb) in a.weights.iter().zip(&b.weights) {
        assert!((wa - wb).abs() < 1e-3, "{wa} vs {wb}");
    }
}

#[test]
fn staged_fanout_round_matches_serial_reference() {
    if !common::runtime_available() {
        return;
    }
    // The round engine's live-runtime pin: the staged + fan-out path must
    // reproduce the pre-engine serial path (per-class runtime passes,
    // serial solves) — same supports, weights within 1e-4, identical
    // merge order — for both per-class variants, balanced and imbalanced
    // ground sets.
    let rt = runtime();
    let st = rt.init(MODEL, 30).unwrap();
    let splits = tiny_mnist(600);
    let grounds: Vec<Vec<usize>> = vec![(0..600).collect(), {
        let mut rng = Rng::new(31);
        gradmatch::data::imbalance_indices(&splits.train, 0.3, 0.1, &mut rng)
    }];
    for variant in [
        gradmatch::selection::GradMatchVariant::PerClassPerGradient,
        gradmatch::selection::GradMatchVariant::PerClass,
    ] {
        for ground in &grounds {
            let run = |parallel: bool| -> Selection {
                let mut s = gradmatch::selection::GradMatch::new(variant, st.meta.batch, false);
                s.parallel = parallel;
                let mut rng = Rng::new(32);
                gradmatch::selection::Strategy::select(
                    &mut s,
                    &mut SelectCtx {
                        src: GradSource::Live { rt: &rt, state: &st },
                        train: &splits.train,
                        ground,
                        val: &splits.val,
                        budget: 60,
                        lambda: 0.5,
                        eps: 1e-10,
                        is_valid: false,
                        rng: &mut rng,
                        round: None,
                    },
                )
                .unwrap()
            };
            let serial = run(false);
            let fanout = run(true);
            // The two arms compute targets at different precision (fused
            // device f32 mean_grad_chunk sums vs staged f64 column
            // means), so exact support identity is not numerically
            // guaranteed on near-tie OMP rounds — demand near-total
            // agreement here; the bit-identical serial-vs-fan-out pin
            // (shared targets on both arms) lives in
            // tests/round_engine.rs and the selection.rs property tests.
            assert_eq!(serial.indices.len(), fanout.indices.len(), "{variant:?}");
            let picked: std::collections::HashSet<usize> =
                serial.indices.iter().copied().collect();
            let common = fanout.indices.iter().filter(|i| picked.contains(i)).count();
            assert!(
                common * 10 >= serial.indices.len() * 9,
                "{variant:?} |ground|={}: only {common}/{} supports agree",
                ground.len(),
                serial.indices.len()
            );
            let (ws, wf): (f32, f32) =
                (serial.weights.iter().sum(), fanout.weights.iter().sum());
            assert!(
                (ws - wf).abs() <= 1e-2 * (1.0 + wf.abs()),
                "{variant:?}: weight mass {ws} vs {wf}"
            );
        }
    }
}

#[test]
fn per_sample_grads_row_order_matches_requested_indices() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let st = rt.init(MODEL, 16).unwrap();
    let splits = tiny_mnist(600);
    let idx = vec![17usize, 3, 599, 123, 45];
    let store = grads::per_sample_grads(&rt, &st, &splits.train, &idx).unwrap();
    assert_eq!(store.rows, idx);
    assert_eq!(store.g.rows, 5);
    // rows are individually recomputable
    let single = grads::per_sample_grads(&rt, &st, &splits.train, &[599]).unwrap();
    let want = store.g.row(2);
    let got = single.g.row(0);
    for (a, b) in want.iter().zip(got) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn forgetting_accumulates_across_rounds() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let splits = tiny_mnist(400);
    let ground: Vec<usize> = (0..400).collect();
    let mut strategy = gradmatch::selection::Forgetting::new();
    // two rounds with different params — counts must persist in between
    for seed in [20, 21] {
        let st = rt.init(MODEL, seed).unwrap();
        let mut rng = Rng::new(seed as u64);
        let sel = gradmatch::selection::Strategy::select(
            &mut strategy,
            &mut SelectCtx {
                src: GradSource::Live { rt: &rt, state: &st },
                train: &splits.train,
                ground: &ground,
                val: &splits.val,
                budget: 40,
                lambda: 0.5,
                eps: 1e-10,
                is_valid: false,
                rng: &mut rng,
                round: None,
            },
        )
        .unwrap();
        assert_eq!(sel.indices.len(), 40);
    }
}

#[test]
fn grad_error_diagnostic_matches_manual_weighted_sum() {
    if !common::runtime_available() {
        return;
    }
    let g = Matrix::from_vec(3, 2, vec![1., 0., 0., 1., 1., 1.]);
    let target = [1.0f32, 1.0];
    // w = (0.5, 0.5, 0.5): fitted = (1.0, 1.0) → err 0
    let e = grads::gradient_error(&g, &[0.5, 0.5, 0.5], &target);
    assert!(e < 1e-6);
}
