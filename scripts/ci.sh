#!/usr/bin/env bash
# CI gate for the workspace: build, tests (default AND no-default
# features), formatting, lints.
#
#   scripts/ci.sh          # everything
#   scripts/ci.sh --fast   # build + tests only (skip fmt/clippy)
#
# Tier-1 (enforced): cargo build --release && cargo test -q.
# The suite also runs with --no-default-features (the pure-host math
# core, no `xla` stub at all) so the feature seam cannot rot, and the
# two engine-coverage suites (strategy_conformance, engine_reuse) are
# gated warning-free.  fmt/clippy run when the components are installed;
# a missing component is reported but does not fail the gate (offline
# toolchains may omit them), while an installed component failing DOES
# fail.

set -euo pipefail
cd "$(dirname "$0")/.."

fast=0
[[ "${1:-}" == "--fast" ]] && fast=1

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo test -q --no-default-features (pure-host math core) =="
cargo test -q --no-default-features

echo "== warnings gate: strategy_conformance + engine_reuse =="
# cargo replays cached warnings, so a --no-run rebuild of just the two
# suites surfaces any warning attributed to their files; fail on match.
conf_warn=$(cargo test --test strategy_conformance --test engine_reuse --no-run 2>&1 \
    | grep -E "^warning" -A 3 \
    | grep -E "tests/(strategy_conformance|engine_reuse)\.rs" || true)
if [[ -n "$conf_warn" ]]; then
    echo "$conf_warn"
    echo "ci: FAIL — warnings in the engine-coverage suites"
    exit 1
fi

if [[ "$fast" == "1" ]]; then
    echo "ci: fast mode — skipped fmt/clippy"
    exit 0
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "ci: rustfmt not installed — skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings =="
    cargo clippy --workspace --all-targets -- -D warnings
else
    echo "ci: clippy not installed — skipping lints"
fi

echo "ci: OK"
