//! Table 8: pairwise one-tailed Wilcoxon signed-rank p-values between
//! strategies, over the paired (dataset × budget × seed) accuracy cells.
//! The paper's claim: GRAD-MATCH-PB-WARM significantly (p < 0.01 there;
//! we report the miniature p-values) outperforms the baselines.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;
use gradmatch::stats::wilcoxon_signed_rank;

fn main() -> anyhow::Result<()> {
    let strategies = ["random", "glister", "craig-pb", "gradmatch-pb", "gradmatch-pb-warm"];
    let budgets = [0.05, 0.1, 0.2, 0.3];
    let seeds = [42u64, 43, 44];
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;

    bh::section("Table 8 — paired accuracy cells");
    // cells[strategy] = accuracy per (dataset, budget, seed) in fixed order
    let mut cells: Vec<Vec<f64>> = vec![Vec::new(); strategies.len()];
    for (ds, model) in [("synmnist", "lenet_s"), ("syncifar10", "resnet_s")] {
        for &b in &budgets {
            for &seed in &seeds {
                for (si, strat) in strategies.iter().enumerate() {
                    let mut cfg = bh::bench_config(ds, model);
                    cfg.strategy = strat.to_string();
                    cfg.budget_frac = b;
                    cfg.epochs = 8;
                    cfg.r_interval = 4;
                    cfg.seed = seed;
                    let r = coord.run_one(&cfg, seed)?;
                    cells[si].push(r.test_acc);
                }
            }
        }
    }
    println!("collected {} paired cells per strategy", cells[0].len());

    bh::section("Table 8 — one-tailed Wilcoxon p-values (row beats column)");
    let mut header = vec!["vs".to_string()];
    header.extend(strategies.iter().map(|s| s.to_string()));
    bh::table_header(&header.iter().map(String::as_str).collect::<Vec<_>>());
    let mut p_gm_vs_random = 1.0;
    for (i, si) in strategies.iter().enumerate() {
        let mut row = vec![si.to_string()];
        for (j, _) in strategies.iter().enumerate() {
            if i == j {
                row.push("-".into());
                continue;
            }
            let w = wilcoxon_signed_rank(&cells[i], &cells[j]);
            row.push(format!("{:.4}", w.p_one_tailed));
            if *si == "gradmatch-pb-warm" && strategies[j] == "random" {
                p_gm_vs_random = w.p_one_tailed;
            }
        }
        bh::table_row(&row);
    }
    let ok = bh::shape_check(
        "table8: gradmatch-pb-warm > random with p < 0.1 (miniature)",
        p_gm_vs_random < 0.1,
    );
    println!("\ntable8_wilcoxon: {}", if ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
