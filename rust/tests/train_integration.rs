//! Integration: the full training loop (Algorithm 1) over the live runtime —
//! learning actually happens, warm-start / early-stop / R-interval semantics
//! hold, and the coordinator produces coherent summaries.

mod common;

use common::runtime;
use gradmatch::config::ExperimentConfig;
use gradmatch::coordinator::Coordinator;
use gradmatch::data::DatasetCard;
use gradmatch::selection::parse_strategy;
use gradmatch::trainer::{evaluate, train, TrainOpts};

fn mini_cfg(strategy: &str) -> ExperimentConfig {
    ExperimentConfig {
        dataset: "synmnist".into(),
        model: "lenet_narrow".into(),
        strategy: strategy.into(),
        budget_frac: 0.10,
        epochs: 8,
        r_interval: 4,
        lr0: 0.05,
        n_train: 800,
        eval_every: 0,
        artifacts_dir: common::artifacts_dir(),
        ..Default::default()
    }
}

#[test]
fn training_improves_over_init() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let card = DatasetCard::by_name("synmnist").unwrap();
    let splits = card.generate(3, 800);
    let ground: Vec<usize> = (0..800).collect();
    let st = rt.init("lenet_narrow", 1).unwrap();
    let acc0 = evaluate(&rt, &st, &splits.test).unwrap();
    let (mut strategy, _) = parse_strategy("random", 128).unwrap();
    let opts = TrainOpts { epochs: 10, r_interval: 5, budget_frac: 0.2, ..Default::default() };
    let (st_after, out) = train(&rt, st, &splits, &ground, strategy.as_mut(), &opts).unwrap();
    let acc1 = evaluate(&rt, &st_after, &splits.test).unwrap();
    assert!(
        acc1 > acc0 + 0.2,
        "training should lift accuracy well above chance: {acc0} -> {acc1}"
    );
    assert_eq!(out.final_test_acc, acc1);
    assert!(out.steps > 0);
}

#[test]
fn loss_history_trends_down() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let card = DatasetCard::by_name("synmnist").unwrap();
    let splits = card.generate(4, 800);
    let ground: Vec<usize> = (0..800).collect();
    let st = rt.init("lenet_narrow", 2).unwrap();
    let (mut strategy, _) = parse_strategy("random", 128).unwrap();
    let opts = TrainOpts { epochs: 12, r_interval: 6, budget_frac: 0.3, ..Default::default() };
    let (_, out) = train(&rt, st, &splits, &ground, strategy.as_mut(), &opts).unwrap();
    let first = out.history[0].mean_loss;
    let last = out.history.last().unwrap().mean_loss;
    assert!(last < first * 0.8, "loss {first} -> {last}");
    // cumulative time is monotone
    for w in out.history.windows(2) {
        assert!(w[1].cum_secs >= w[0].cum_secs);
    }
}

#[test]
fn r_interval_controls_selection_count() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let card = DatasetCard::by_name("synmnist").unwrap();
    let splits = card.generate(5, 600);
    let ground: Vec<usize> = (0..600).collect();
    for (r, expect) in [(2usize, 5usize), (5, 2), (10, 1)] {
        let st = rt.init("lenet_narrow", 3).unwrap();
        let (mut strategy, _) = parse_strategy("random", 128).unwrap();
        let opts = TrainOpts {
            epochs: 10,
            r_interval: r,
            budget_frac: 0.2,
            ..Default::default()
        };
        let (_, out) = train(&rt, st, &splits, &ground, strategy.as_mut(), &opts).unwrap();
        assert_eq!(out.selections, expect, "R={r}");
    }
}

#[test]
fn non_adaptive_strategy_selects_once() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let card = DatasetCard::by_name("synmnist").unwrap();
    let splits = card.generate(6, 600);
    let ground: Vec<usize> = (0..600).collect();
    let st = rt.init("lenet_narrow", 4).unwrap();
    let (mut strategy, _) = parse_strategy("featurefl", 128).unwrap();
    let opts = TrainOpts { epochs: 9, r_interval: 3, budget_frac: 0.2, ..Default::default() };
    let (_, out) = train(&rt, st, &splits, &ground, strategy.as_mut(), &opts).unwrap();
    assert_eq!(out.selections, 1, "featurefl is not adaptive");
}

#[test]
fn warm_start_runs_full_epochs_first() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let card = DatasetCard::by_name("synmnist").unwrap();
    let splits = card.generate(7, 640);
    let ground: Vec<usize> = (0..640).collect();
    let st = rt.init("lenet_narrow", 5).unwrap();
    let (mut strategy, warm) = parse_strategy("random-warm", 128).unwrap();
    assert!(warm);
    // κ=1, frac=0.5 ⇒ T_f = 1·20·0.5 = 10 warm epochs of 5 batches (640/128),
    // then 10 subset epochs of ⌈320/128⌉=3 batches
    let opts = TrainOpts {
        epochs: 20,
        r_interval: 50,
        budget_frac: 0.5,
        kappa: 1.0,
        warm: true,
        ..Default::default()
    };
    let (_, out) = train(&rt, st, &splits, &ground, strategy.as_mut(), &opts).unwrap();
    assert_eq!(out.steps, 10 * 5 + 10 * 3, "warm/subset step split");
    // warm phase touches every sample
    assert!(out.ever_selected.iter().all(|&b| b));
}

#[test]
fn early_stop_truncates_epochs() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let card = DatasetCard::by_name("synmnist").unwrap();
    let splits = card.generate(8, 640);
    let ground: Vec<usize> = (0..640).collect();
    let st = rt.init("lenet_narrow", 6).unwrap();
    let (mut strategy, _) = parse_strategy("full", 128).unwrap();
    let opts = TrainOpts {
        epochs: 20,
        budget_frac: 1.0,
        early_stop_frac: Some(0.25),
        ..Default::default()
    };
    let (_, out) = train(&rt, st, &splits, &ground, strategy.as_mut(), &opts).unwrap();
    assert_eq!(out.history.len(), 5, "20 epochs * 0.25");
    assert_eq!(out.steps, 5 * 5); // 640/128 = 5 batches per epoch
}

#[test]
fn coordinator_summary_fields_are_coherent() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let cfg = mini_cfg("gradmatch-pb");
    let r = coord.run_one(&cfg, 42).unwrap();
    assert_eq!(r.strategy, "gradmatch-pb");
    assert!(r.test_acc > 0.2 && r.test_acc <= 1.0, "{}", r.test_acc);
    assert!(r.total_secs >= r.train_secs);
    assert!(r.select_secs > 0.0, "gradmatch-pb must spend selection time");
    assert!(r.selections >= 1);
    // ONE engine per run: every applied round after the first must have
    // ridden the reused engine (reset_round, not a rebuild).  Stated as
    // bounds — an empty (unapplied) round advances the engine without
    // being recorded, so `== selections - 1` is not an invariant.
    assert!(
        r.engine_reused_rounds + 1 >= r.selections && r.engine_reused_rounds <= r.selections,
        "selections {} vs engine_reused_rounds {}",
        r.selections,
        r.engine_reused_rounds
    );
    assert!(r.redundant_frac > 0.0 && r.redundant_frac < 1.0, "{}", r.redundant_frac);
    assert!(r.mean_grad_error.is_some());
    assert!(r.energy_kwh > 0.0);
}

#[test]
fn coordinator_full_baseline_is_cached_and_budget_one() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let cfg = mini_cfg("gradmatch-pb");
    let a = coord.full_baseline(&cfg, cfg.seed).unwrap();
    let b = coord.full_baseline(&cfg, cfg.seed).unwrap();
    assert_eq!(a.test_acc, b.test_acc);
    assert_eq!(a.strategy, "full");
    assert!(a.redundant_frac < 1e-9, "full training uses everything");
}

#[test]
fn run_multi_seeds_differ_but_are_reproducible() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let mut cfg = mini_cfg("random");
    cfg.runs = 2;
    cfg.epochs = 4;
    let rs = coord.run_multi(&cfg).unwrap();
    assert_eq!(rs.len(), 2);
    assert_ne!(rs[0].seed, rs[1].seed);
    let rs2 = coord.run_multi(&cfg).unwrap();
    assert_eq!(rs[0].test_acc, rs2[0].test_acc, "same seed same result");
}

#[test]
fn imbalanced_run_uses_reduced_ground_set() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let mut cfg = mini_cfg("gradmatch");
    cfg.is_valid = true;
    cfg.epochs = 4;
    cfg.r_interval = 2;
    let r = coord.run_one(&cfg, 42).unwrap();
    // 30% of classes reduced by 90% ⇒ ground ≈ 0.73·n; redundant fraction
    // must reflect that many rows are not even eligible
    assert!(r.redundant_frac > 0.2, "{}", r.redundant_frac);
    assert!(r.test_acc > 0.2);
    // the staged per-class rounds re-stage the same ground set every
    // round, so the reused engine recycles the staging buffers (bounds,
    // not equality — empty rounds advance the engine unrecorded)
    assert!(
        r.engine_reused_rounds + 1 >= r.selections && r.engine_reused_rounds <= r.selections,
        "selections {} vs engine_reused_rounds {}",
        r.selections,
        r.engine_reused_rounds
    );
    assert!(
        r.selections < 2 || r.stage_buffer_reuses >= r.selections - 1,
        "selections {} but only {} buffer reuses",
        r.selections,
        r.stage_buffer_reuses
    );
}

#[test]
fn overlapped_selection_trains_and_selects() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let mut cfg = mini_cfg("gradmatch-pb");
    cfg.overlap = true;
    cfg.epochs = 10;
    cfg.r_interval = 2;
    let r = coord.run_one(&cfg, 42).unwrap();
    // background rounds must have landed and been applied
    assert!(r.selections >= 1, "no overlapped selection applied");
    assert!(r.test_acc > 0.3, "{}", r.test_acc);
    // main-thread selection time is only request/poll overhead
    assert!(r.select_secs < 0.5, "overlap should keep selection off the critical path: {}", r.select_secs);
}

#[test]
fn overlapped_matches_sync_quality_roughly() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let mut sync_cfg = mini_cfg("gradmatch-pb");
    sync_cfg.epochs = 10;
    sync_cfg.r_interval = 3;
    let sync = coord.run_one(&sync_cfg, 7).unwrap();
    let mut ov_cfg = sync_cfg.clone();
    ov_cfg.overlap = true;
    let ov = coord.run_one(&ov_cfg, 7).unwrap();
    // stale-subset training may lag slightly but must stay in the same band
    assert!(
        ov.test_acc > sync.test_acc - 0.15,
        "overlap {} vs sync {}",
        ov.test_acc,
        sync.test_acc
    );
}

#[test]
fn label_noise_robustness_validation_matching_helps() {
    if !common::runtime_available() {
        return;
    }
    // robust-learning extension: with 30% flipped labels, validation-
    // gradient GRAD-MATCH should beat random selection trained on the
    // same noisy data
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let mut base = mini_cfg("random");
    base.label_noise = 0.3;
    base.epochs = 10;
    base.r_interval = 5;
    base.budget_frac = 0.2;
    let rnd = coord.run_one(&base, 11).unwrap();
    let mut gm = base.clone();
    gm.strategy = "gradmatch".into();
    gm.is_valid = true; // clean validation target
    gm.imbalance_frac = 0.0; // noise experiment, no class imbalance
    let g = coord.run_one(&gm, 11).unwrap();
    assert!(
        g.test_acc > rnd.test_acc - 0.05,
        "gradmatch(val) {} vs random {} under label noise",
        g.test_acc,
        rnd.test_acc
    );
}

#[test]
fn sweep_produces_rows_with_sane_relationships() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let mut cfg = mini_cfg("gradmatch-pb");
    cfg.epochs = 6;
    cfg.r_interval = 3;
    let rows = coord.sweep(&cfg, &["random", "gradmatch-pb"], &[0.1, 0.3]).unwrap();
    assert_eq!(rows.len(), 4);
    for row in &rows {
        // at miniature scale selection overhead can eat some of the win;
        // the full-scale speedup shape is asserted by the benches/examples
        assert!(row.speedup > 0.8, "subset training should beat full time: {}", row.speedup);
        assert!(row.acc_mean > 0.0 && row.acc_mean <= 1.0);
        assert!(row.energy_ratio > 0.0);
    }
}
