//! Conformance for the two-level sharded OMP path, pinned device-free on
//! the synthetic gradient oracle:
//!
//! - **1-shard ≡ flat** — a shard plan that resolves to one shard
//!   (explicit count 1, or a `max_staged_rows` budget the whole ground
//!   set fits under) is bit-identical to the plan-less flat path for
//!   EVERY `strategy_specs()` spec, with identical dispatch counts;
//! - **dispatch contract** — the counting oracle pins the sharded
//!   round's acquisition cost at `Σ_s ⌈n_s/chunk⌉` shard passes plus
//!   the `⌈|winners|/chunk⌉` merge re-stage, and the round probe's
//!   `stage_dispatches` agrees with the oracle's own counter;
//! - **memory budget** — `peak_staged_rows` never exceeds
//!   `max_staged_rows` (waves of one shard, buffers recycled), while an
//!   unbounded explicit-count plan stages everything at once.

use gradmatch::data::Dataset;
use gradmatch::engine::{SelectionEngine, SelectionRequest, ShardPlan};
use gradmatch::grads::SynthGrads;
use gradmatch::rng::Rng;
use gradmatch::selection::strategy_specs;
use gradmatch::tensor::Matrix;

const CHUNK: usize = 16;
const BATCH: usize = 4;

/// Imbalanced synthetic dataset: heavy head, long tail, every class
/// populated (the same fixture shape the strategy conformance suite
/// uses, so per-class and scoring strategies all have work).
fn imbalanced(seed: u64, classes: usize, d: usize) -> Dataset {
    let mut y: Vec<i32> = Vec::new();
    for cls in 0..classes {
        let n_c = match cls % 3 {
            0 => 37,
            1 => 11,
            _ => 4,
        };
        y.extend(std::iter::repeat(cls as i32).take(n_c));
    }
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut y);
    let n = y.len();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

/// Balanced synthetic dataset sized exactly `n` (`y = i mod classes`),
/// for the dispatch-count arithmetic tests.
fn balanced(seed: u64, n: usize, classes: usize, d: usize) -> Dataset {
    let mut rng = Rng::new(seed);
    let y: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

fn request(
    strategy: &str,
    ground: Vec<usize>,
    budget: usize,
    shards: Option<ShardPlan>,
) -> SelectionRequest {
    SelectionRequest {
        strategy: strategy.into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: 7,
        ground,
        shards,
        sketch: None,
    }
}

#[test]
fn one_shard_plan_is_bit_identical_to_flat_for_every_spec() {
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(61, classes, d);
    let val = imbalanced(62, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let budget = n / 4;

    for spec in strategy_specs() {
        let mut flat_oracle = SynthGrads::with_batch(CHUNK, p, BATCH);
        let flat = {
            let engine = SelectionEngine::with_oracle(&mut flat_oracle, &train, &val, h, classes);
            engine.select(&request(spec, ground.clone(), budget, None)).unwrap()
        };

        // both 1-shard spellings: an explicit count of 1, and a memory
        // budget the whole ground set fits under (count auto-derives to 1)
        let plans = [
            ShardPlan { shards: 1, max_staged_rows: 0 },
            ShardPlan { shards: 0, max_staged_rows: n },
        ];
        for plan in plans {
            let mut oracle = SynthGrads::with_batch(CHUNK, p, BATCH);
            let got = {
                let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
                engine.select(&request(spec, ground.clone(), budget, Some(plan))).unwrap()
            };
            assert_eq!(
                got.selection, flat.selection,
                "{spec}: 1-shard plan {plan:?} must be bit-identical to the flat path"
            );
            assert_eq!(
                (oracle.grad_calls, oracle.mean_calls, oracle.gradsum_calls, oracle.eval_calls),
                (
                    flat_oracle.grad_calls,
                    flat_oracle.mean_calls,
                    flat_oracle.gradsum_calls,
                    flat_oracle.eval_calls
                ),
                "{spec}: 1-shard plan {plan:?} must cost the flat path's dispatches"
            );
        }
    }
}

#[test]
fn shard_dispatches_and_peak_rows_obey_the_budget() {
    let (classes, h, d) = (3usize, 2usize, 5usize);
    let p = h * classes + classes;
    let (n, budget, max_rows) = (600usize, 60usize, 150usize);
    let train = balanced(71, n, classes, d);
    let val = balanced(72, 60, classes, d);
    let ground: Vec<usize> = (0..n).collect();

    let mut oracle = SynthGrads::new(CHUNK, p);
    let plan = ShardPlan { shards: 0, max_staged_rows: max_rows };
    let report = {
        let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
        engine.select(&request("gradmatch", ground, budget, Some(plan))).unwrap()
    };
    let stats = &report.stats;

    // n / max_rows derives 4 equal shards of exactly max_rows rows
    assert_eq!(stats.shards, 4, "shard count derivation");
    assert!(
        stats.merge_candidates > 0 && stats.merge_candidates <= 2 * budget,
        "merge pool within the oversample cap: {}",
        stats.merge_candidates
    );
    assert!(
        stats.peak_staged_rows <= max_rows,
        "peak staged rows {} must stay under the budget {max_rows}",
        stats.peak_staged_rows
    );
    assert!(
        stats.shard_stage_secs <= stats.stage_secs + 1e-9,
        "shard staging time is a subset of staging time"
    );

    // dispatch contract: Σ_s ⌈n_s/chunk⌉ shard passes + the merge
    // re-stage over the winners
    let shard_passes = 4 * max_rows.div_ceil(CHUNK);
    let merge_passes = stats.merge_candidates.div_ceil(CHUNK);
    assert_eq!(
        oracle.grad_calls,
        shard_passes + merge_passes,
        "sharded staging must cost Σ_s ⌈n_s/chunk⌉ + ⌈|winners|/chunk⌉"
    );
    assert_eq!(
        stats.stage_dispatches, oracle.grad_calls,
        "the round probe must agree with the oracle's own counter"
    );
    assert_eq!((oracle.mean_calls, oracle.gradsum_calls, oracle.eval_calls), (0, 0, 0));

    // selection sanity: within budget, unique, in range, weights finite
    let sel = &report.selection;
    assert!(!sel.indices.is_empty() && sel.indices.len() <= budget);
    let mut uniq = sel.indices.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), sel.indices.len(), "duplicate rows selected");
    assert!(uniq.iter().all(|&i| i < n), "out-of-range row selected");
    assert_eq!(sel.weights.len(), sel.indices.len());
    assert!(sel.weights.iter().all(|w| w.is_finite()));
}

#[test]
fn unbounded_explicit_count_stages_everything_at_once() {
    let (classes, h, d) = (3usize, 2usize, 5usize);
    let p = h * classes + classes;
    let (n, budget) = (600usize, 60usize);
    let train = balanced(81, n, classes, d);
    let val = balanced(82, 60, classes, d);
    let ground: Vec<usize> = (0..n).collect();

    let mut oracle = SynthGrads::new(CHUNK, p);
    let plan = ShardPlan { shards: 3, max_staged_rows: 0 };
    let report = {
        let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
        engine.select(&request("gradmatch", ground, budget, Some(plan))).unwrap()
    };
    let stats = &report.stats;
    assert_eq!(stats.shards, 3);
    // no memory budget: all three shards staged simultaneously, so the
    // high-water mark is the whole ground set
    assert_eq!(stats.peak_staged_rows, n);
    let shard_passes = 3 * (n / 3).div_ceil(CHUNK);
    let merge_passes = stats.merge_candidates.div_ceil(CHUNK);
    assert_eq!(oracle.grad_calls, shard_passes + merge_passes);
}
