//! PJRT runtime: load the AOT'd HLO-text artifacts and execute them.
//!
//! This is the only module that talks to the `xla` crate.  It owns the CPU
//! PJRT client, parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`), compiles each HLO module once on first use,
//! and exposes typed wrappers for the seven entry points of a model
//! variant.  Everything above (trainer, selection) works with plain
//! `Vec<f32>` / [`crate::tensor::Matrix`] buffers.
//!
//! Perf note: executables are cached; input literals for the train step are
//! built from reused host buffers.  See EXPERIMENTS.md §Perf for the
//! literal-vs-buffer execution measurements.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonlite::Json;
use crate::tensor::Matrix;

/// Static metadata of one model variant, mirrored from the manifest.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub d: usize,
    pub h: usize,
    pub c: usize,
    /// train mini-batch rows (B)
    pub batch: usize,
    /// eval/grad chunk rows (E = G)
    pub chunk: usize,
    /// last-layer gradient dimension H*C + C
    pub p: usize,
    pub momentum: f32,
    pub weight_decay: f32,
    /// entry name -> artifact path (relative to artifact root)
    pub entries: HashMap<String, String>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub models: HashMap<String, ModelMeta>,
}

impl Manifest {
    /// Parse the manifest file under `root`.
    pub fn load(root: &Path) -> Result<Manifest> {
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        if j.get("interchange").and_then(Json::as_str) != Some("hlo-text") {
            bail!("manifest: unsupported interchange format");
        }
        let mut models = HashMap::new();
        let mobj = j
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing models"))?;
        for (name, m) in mobj {
            let u = |k: &str| -> Result<usize> {
                m.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("manifest: {name}.{k}"))
            };
            let f = |k: &str| -> Result<f32> {
                m.get(k)
                    .and_then(Json::as_f64)
                    .map(|v| v as f32)
                    .ok_or_else(|| anyhow!("manifest: {name}.{k}"))
            };
            let mut entries = HashMap::new();
            let eobj = m
                .get("entries")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("manifest: {name}.entries"))?;
            for (ename, e) in eobj {
                let path = e
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("manifest: {name}.{ename}.path"))?;
                entries.insert(ename.clone(), path.to_string());
            }
            models.insert(
                name.clone(),
                ModelMeta {
                    name: name.clone(),
                    d: u("d")?,
                    h: u("h")?,
                    c: u("c")?,
                    batch: u("batch")?,
                    chunk: u("chunk")?,
                    p: u("p")?,
                    momentum: f("momentum")?,
                    weight_decay: f("weight_decay")?,
                    entries,
                },
            );
        }
        Ok(Manifest { models })
    }
}

/// Model parameters + momentum buffers, host-side.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
    pub m_w1: Vec<f32>,
    pub m_b1: Vec<f32>,
    pub m_w2: Vec<f32>,
    pub m_b2: Vec<f32>,
    pub meta: ModelMeta,
}

impl ModelState {
    /// Zero-momentum state from raw parameter buffers.
    pub fn new(meta: &ModelMeta, w1: Vec<f32>, b1: Vec<f32>, w2: Vec<f32>, b2: Vec<f32>) -> Self {
        assert_eq!(w1.len(), meta.d * meta.h);
        assert_eq!(b1.len(), meta.h);
        assert_eq!(w2.len(), meta.h * meta.c);
        assert_eq!(b2.len(), meta.c);
        ModelState {
            m_w1: vec![0.0; w1.len()],
            m_b1: vec![0.0; b1.len()],
            m_w2: vec![0.0; w2.len()],
            m_b2: vec![0.0; b2.len()],
            w1,
            b1,
            w2,
            b2,
            meta: meta.clone(),
        }
    }

    /// Total parameter count (excluding momenta).
    pub fn param_count(&self) -> usize {
        self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len()
    }

    /// Pack (params, momenta) into the flat layout of `train_step_fused`.
    pub fn pack(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(2 * self.param_count());
        for v in [&self.w1, &self.b1, &self.w2, &self.b2, &self.m_w1, &self.m_b1, &self.m_w2, &self.m_b2] {
            out.extend_from_slice(v);
        }
        out
    }

    /// Inverse of [`ModelState::pack`].
    pub fn unpack(meta: &ModelMeta, flat: &[f32]) -> ModelState {
        let sizes = [
            meta.d * meta.h,
            meta.h,
            meta.h * meta.c,
            meta.c,
            meta.d * meta.h,
            meta.h,
            meta.h * meta.c,
            meta.c,
        ];
        assert_eq!(flat.len(), sizes.iter().sum::<usize>(), "unpack: state size");
        let mut parts = Vec::with_capacity(8);
        let mut off = 0;
        for n in sizes {
            parts.push(flat[off..off + n].to_vec());
            off += n;
        }
        let mut it = parts.into_iter();
        let (w1, b1, w2, b2) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let mut st = ModelState::new(meta, w1, b1, w2, b2);
        st.m_w1 = it.next().unwrap();
        st.m_b1 = it.next().unwrap();
        st.m_w2 = it.next().unwrap();
        st.m_b2 = it.next().unwrap();
        st
    }
}

/// Device-friendly packed training state: one literal threaded through
/// consecutive `train_step_fused` executions, so the model state is never
/// re-marshalled host-side between steps (§Perf).
pub struct FusedState {
    /// packed (params, momenta) literal, updated in place per step
    lit: xla::Literal,
    pub meta: ModelMeta,
}

impl FusedState {
    /// Pack a host-side state.
    pub fn from_state(st: &ModelState) -> Result<FusedState> {
        let flat = st.pack();
        Ok(FusedState { lit: xla::Literal::vec1(&flat), meta: st.meta.clone() })
    }

    /// Download to a host-side state (selection / eval boundaries).
    pub fn to_state(&self) -> Result<ModelState> {
        let flat = self.lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(ModelState::unpack(&self.meta, &flat))
    }
}

/// The PJRT runtime: client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    root: PathBuf,
    exes: RefCell<HashMap<(String, String), xla::PjRtLoadedExecutable>>,
    /// executions per entry (telemetry for the perf pass)
    pub exec_counts: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Create a runtime over an artifact directory.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&root)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            root,
            exes: RefCell::new(HashMap::new()),
            exec_counts: RefCell::new(HashMap::new()),
        })
    }

    /// Model metadata by name.
    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.manifest
            .models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model variant '{name}'"))
    }

    fn ensure_compiled(&self, model: &str, entry: &str) -> Result<()> {
        let key = (model.to_string(), entry.to_string());
        if self.exes.borrow().contains_key(&key) {
            return Ok(());
        }
        let meta = self.model(model)?;
        let rel = meta
            .entries
            .get(entry)
            .ok_or_else(|| anyhow!("model '{model}' has no entry '{entry}'"))?;
        let path = self.root.join(rel);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {model}/{entry}: {e:?}"))?;
        self.exes.borrow_mut().insert(key, exe);
        Ok(())
    }

    /// Execute an entry point; returns the flattened tuple of outputs.
    pub fn exec(&self, model: &str, entry: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.exec_ref(model, entry, &refs)
    }

    /// Execute with borrowed input literals.
    ///
    /// Lifetime notes: the vendored xla crate's `execute()` is patched
    /// (third_party/xla/xla_rs/xla_rs.cc) to free its input device
    /// buffers after the outputs are ready — upstream 0.1.6 leaked every
    /// input (~2.3 MB/step in the train loop, OOMing real runs; §Perf).
    /// Re-using caller-held `PjRtBuffer`s across executions via
    /// `execute_b` is NOT safe with xla_extension 0.5.1 (the second use
    /// trips buffer-aliasing checks), so all hot paths stay on cached
    /// *literals* + per-call transfer.
    pub fn exec_ref(
        &self,
        model: &str,
        entry: &str,
        inputs: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(model, entry)?;
        *self
            .exec_counts
            .borrow_mut()
            .entry(format!("{model}/{entry}"))
            .or_insert(0) += 1;
        let exes = self.exes.borrow();
        let exe = exes.get(&(model.to_string(), entry.to_string())).unwrap();
        let bufs = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {model}/{entry}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("readback {model}/{entry}: {e:?}"))?;
        // aot.py lowers with return_tuple=True → always a tuple
        lit.to_tuple().map_err(|e| anyhow!("untuple {model}/{entry}: {e:?}"))
    }

    /// `corr_chunk` against a pre-marshalled gradient-chunk literal (the
    /// OMP hot path: the chunk literal is built once; only the transfer
    /// and the fresh residual are per-iteration).
    pub fn corr_chunk_lit(
        &self,
        model: &str,
        g_lit: &xla::Literal,
        r: &[f32],
    ) -> Result<Vec<f32>> {
        let r_lit = xla::Literal::vec1(r);
        let outs = self.exec_ref(model, "corr_chunk", &[g_lit, &r_lit])?;
        outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Marshal a row-major matrix into a 2-D literal (for literal caching).
    pub fn matrix_literal(m: &Matrix) -> Result<xla::Literal> {
        lit2(&m.data, m.rows, m.cols)
    }

    // -- typed wrappers ------------------------------------------------------

    /// Initialize model parameters from a seed.
    pub fn init(&self, model: &str, seed: i32) -> Result<ModelState> {
        let meta = self.model(model)?.clone();
        let outs = self.exec(model, "init", &[xla::Literal::scalar(seed)])?;
        let v = |i: usize| -> Result<Vec<f32>> {
            outs[i].to_vec::<f32>().map_err(|e| anyhow!("init out {i}: {e:?}"))
        };
        Ok(ModelState::new(&meta, v(0)?, v(1)?, v(2)?, v(3)?))
    }

    fn params_literals(&self, st: &ModelState) -> Result<Vec<xla::Literal>> {
        let m = &st.meta;
        Ok(vec![
            lit2(&st.w1, m.d, m.h)?,
            lit1(&st.b1),
            lit2(&st.w2, m.h, m.c)?,
            lit1(&st.b2),
        ])
    }

    /// One weighted SGD step.  Mutates `st` in place; returns (loss, correct).
    pub fn train_step(
        &self,
        st: &mut ModelState,
        x: &[f32],
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<(f32, f32)> {
        let m = st.meta.clone();
        assert_eq!(x.len(), m.batch * m.d, "train_step x shape");
        assert_eq!(y.len(), m.batch);
        assert_eq!(w.len(), m.batch);
        let mut inputs = self.params_literals(st)?;
        inputs.push(lit2(&st.m_w1, m.d, m.h)?);
        inputs.push(lit1(&st.m_b1));
        inputs.push(lit2(&st.m_w2, m.h, m.c)?);
        inputs.push(lit1(&st.m_b2));
        inputs.push(lit2(x, m.batch, m.d)?);
        inputs.push(xla::Literal::vec1(y));
        inputs.push(lit1(w));
        inputs.push(xla::Literal::scalar(lr));
        let outs = self.exec(&m.name, "train_step", &inputs)?;
        let v = |i: usize| -> Result<Vec<f32>> {
            outs[i].to_vec::<f32>().map_err(|e| anyhow!("train_step out {i}: {e:?}"))
        };
        st.w1 = v(0)?;
        st.b1 = v(1)?;
        st.w2 = v(2)?;
        st.b2 = v(3)?;
        st.m_w1 = v(4)?;
        st.m_b1 = v(5)?;
        st.m_w2 = v(6)?;
        st.m_b2 = v(7)?;
        let loss = scalar_f32(&outs[8])?;
        let correct = scalar_f32(&outs[9])?;
        Ok((loss, correct))
    }

    /// One weighted SGD step over a packed state (the trainer hot loop).
    /// The state literal is threaded through without host conversion;
    /// only loss/correct scalars are read back.
    pub fn train_step_fused(
        &self,
        fs: &mut FusedState,
        x: &[f32],
        y: &[i32],
        w: &[f32],
        lr: f32,
    ) -> Result<(f32, f32)> {
        let m = fs.meta.clone();
        debug_assert_eq!(x.len(), m.batch * m.d);
        let x_lit = lit2(x, m.batch, m.d)?;
        let y_lit = xla::Literal::vec1(y);
        let w_lit = lit1(w);
        let lr_lit = xla::Literal::scalar(lr);
        let mut outs =
            self.exec_ref(&m.name, "train_step_fused", &[&fs.lit, &x_lit, &y_lit, &w_lit, &lr_lit])?;
        let correct = scalar_f32(&outs[2])?;
        let loss = scalar_f32(&outs[1])?;
        fs.lit = outs.swap_remove(0);
        Ok((loss, correct))
    }

    /// Masked eval over one chunk: (Σloss, Σcorrect, correct[E], entropy[E]).
    pub fn eval_chunk(
        &self,
        st: &ModelState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<(f32, f32, Vec<f32>, Vec<f32>)> {
        let m = &st.meta;
        assert_eq!(x.len(), m.chunk * m.d, "eval_chunk x shape");
        let mut inputs = self.params_literals(st)?;
        inputs.push(lit2(x, m.chunk, m.d)?);
        inputs.push(xla::Literal::vec1(y));
        inputs.push(lit1(mask));
        let outs = self.exec(&m.name, "eval_chunk", &inputs)?;
        Ok((
            scalar_f32(&outs[0])?,
            scalar_f32(&outs[1])?,
            outs[2].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            outs[3].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        ))
    }

    /// Per-sample last-layer gradients for one chunk → `[chunk, P]`.
    pub fn grads_chunk(
        &self,
        st: &ModelState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<Matrix> {
        let m = &st.meta;
        let mut inputs = self.params_literals(st)?;
        inputs.push(lit2(x, m.chunk, m.d)?);
        inputs.push(xla::Literal::vec1(y));
        inputs.push(lit1(mask));
        let outs = self.exec(&m.name, "grads_chunk", &inputs)?;
        let data = outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(Matrix::from_vec(m.chunk, m.p, data))
    }

    /// Sum of per-sample gradients for one chunk → `[P]` (fused fast path).
    pub fn mean_grad_chunk(
        &self,
        st: &ModelState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<Vec<f32>> {
        let m = &st.meta;
        let mut inputs = self.params_literals(st)?;
        inputs.push(lit2(x, m.chunk, m.d)?);
        inputs.push(xla::Literal::vec1(y));
        inputs.push(lit1(mask));
        let outs = self.exec(&m.name, "mean_grad_chunk", &inputs)?;
        outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Per-mini-batch gradient *sums* for one chunk → `[chunk/B, P]`
    /// (device-side reduction; the PB fast path — §Perf).
    pub fn batch_gradsum_chunk(
        &self,
        st: &ModelState,
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<Matrix> {
        let m = &st.meta;
        let mut inputs = self.params_literals(st)?;
        inputs.push(lit2(x, m.chunk, m.d)?);
        inputs.push(xla::Literal::vec1(y));
        inputs.push(lit1(mask));
        let outs = self.exec(&m.name, "batch_gradsum_chunk", &inputs)?;
        let data = outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(Matrix::from_vec(m.chunk / m.batch, m.p, data))
    }

    /// OMP residual correlations over one padded gradient chunk.
    pub fn corr_chunk(&self, model: &str, g: &Matrix, r: &[f32]) -> Result<Vec<f32>> {
        let m = self.model(model)?;
        assert_eq!(g.rows, m.chunk, "corr_chunk rows");
        assert_eq!(g.cols, m.p, "corr_chunk cols");
        assert_eq!(r.len(), m.p);
        let outs = self.exec(model, "corr_chunk", &[lit2(&g.data, g.rows, g.cols)?, lit1(r)])?;
        outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Pairwise squared distances between two padded gradient chunks.
    pub fn sqdist_chunk(&self, model: &str, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let m = self.model(model)?;
        assert_eq!(a.rows, m.chunk);
        assert_eq!(b.rows, m.chunk);
        let outs = self.exec(
            model,
            "sqdist_chunk",
            &[lit2(&a.data, a.rows, a.cols)?, lit2(&b.data, b.rows, b.cols)?],
        )?;
        let data = outs[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(Matrix::from_vec(m.chunk, m.chunk, data))
    }
}

fn lit1(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

fn lit2(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(v.len(), rows * cols);
    xla::Literal::vec1(v)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape [{rows},{cols}]: {e:?}"))
}

fn scalar_f32(l: &xla::Literal) -> Result<f32> {
    l.to_vec::<f32>()
        .map_err(|e| anyhow!("{e:?}"))?
        .first()
        .copied()
        .ok_or_else(|| anyhow!("empty scalar"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE_MANIFEST: &str = r#"{
      "format": 1, "interchange": "hlo-text",
      "models": {"m1": {"d": 4, "h": 3, "c": 2, "batch": 8, "chunk": 16,
                         "p": 8, "momentum": 0.9, "weight_decay": 0.0005,
                         "grad_layout": "w2_row_major_hc_then_bias",
                         "entries": {"init": {"path": "m1/init.hlo.txt",
                                              "inputs": [], "outputs": []}}}}}"#;

    #[test]
    fn manifest_parses_fields() {
        let m = Manifest::parse(SAMPLE_MANIFEST).unwrap();
        let meta = &m.models["m1"];
        assert_eq!(meta.d, 4);
        assert_eq!(meta.p, 8);
        assert!((meta.momentum - 0.9).abs() < 1e-6);
        assert_eq!(meta.entries["init"], "m1/init.hlo.txt");
    }

    #[test]
    fn manifest_rejects_wrong_interchange() {
        let bad = SAMPLE_MANIFEST.replace("hlo-text", "proto");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn model_state_shape_checks() {
        let m = Manifest::parse(SAMPLE_MANIFEST).unwrap();
        let meta = m.models["m1"].clone();
        let st = ModelState::new(
            &meta,
            vec![0.0; 12],
            vec![0.0; 3],
            vec![0.0; 6],
            vec![0.0; 2],
        );
        assert_eq!(st.param_count(), 23);
        assert_eq!(st.m_w1.len(), 12);
    }
}
