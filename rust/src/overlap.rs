//! Overlapped selection: run data selection in a background worker so the
//! training loop never stalls on a selection round.
//!
//! The paper amortizes selection cost by selecting only every `R` epochs;
//! this module removes it from the critical path entirely — the trainer
//! keeps stepping on the *stale* subset while the worker computes the next
//! one against a parameter snapshot, and swaps it in when ready (a
//! double-buffered subset).  On a multi-core box this hides the full
//! selection latency; on one core it still bounds tail latency per epoch.
//!
//! The worker is a [`SelectionEngine`] client: it holds one
//! [`SelectionRequest`] template (strategy spec, budget, λ/ε, ground set,
//! seed) and ONE engine for its lifetime — each submission
//! `reset_round`s the engine with the parameter snapshot it carries
//! (staging buffers recycle across rounds) — and ships the full
//! [`SelectionReport`] back, so overlapped rounds carry the same
//! staging/solve observability and engine-reuse counters as synchronous
//! ones.  The worker owns
//! its **own** PJRT runtime (the xla client handles are not `Send`, and
//! executables are compiled per thread) plus clones of the train/val
//! splits; only parameter snapshots ([`ModelState`], plain host buffers)
//! and reports cross the channels.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::data::Dataset;
use crate::engine::{SelectionEngine, SelectionReport, SelectionRequest};
use crate::runtime::{ModelState, Runtime};
use crate::selection::parse_strategy;

/// A queued round: parameter snapshot + the tag that seeds the per-round
/// RNG (so overlapped and synchronous runs draw the same shuffles for a
/// given epoch — both derive through
/// [`SelectionRequest::round_rng`]).
pub struct SelectRequest {
    pub state: ModelState,
    pub rng_tag: u64,
}

/// Background selection worker.
pub struct AsyncSelector {
    req_tx: Option<Sender<SelectRequest>>,
    res_rx: Receiver<Result<SelectionReport>>,
    handle: Option<JoinHandle<()>>,
    /// requests in flight (0 or 1 — the trainer never stacks requests)
    pub inflight: usize,
}

/// Static configuration the worker needs to serve rounds.
#[derive(Clone)]
pub struct SelectorConfig {
    pub artifacts_dir: String,
    /// round-request template (strategy/budget/λ/ε/ground/seed); the
    /// worker stamps `rng_tag` per submission
    pub request: SelectionRequest,
}

impl AsyncSelector {
    /// Spawn the worker with its own runtime + dataset copies.
    pub fn spawn(cfg: SelectorConfig, train: Dataset, val: Dataset) -> Result<AsyncSelector> {
        let (req_tx, req_rx) = channel::<SelectRequest>();
        let (res_tx, res_rx) = channel::<Result<SelectionReport>>();
        let handle = std::thread::Builder::new()
            .name("gradmatch-selector".into())
            .spawn(move || {
                // own runtime + strategy; failures are reported per request
                let rt = match Runtime::load(&cfg.artifacts_dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = res_tx.send(Err(anyhow!("selector runtime: {e}")));
                        return;
                    }
                };
                let batch = rt
                    .manifest
                    .models
                    .values()
                    .next()
                    .map(|m| m.batch)
                    .unwrap_or(128);
                // one strategy instance for the worker's lifetime, so
                // stateful baselines keep their cross-round memory
                let mut strategy = match parse_strategy(&cfg.request.strategy, batch) {
                    Ok((s, _)) => s,
                    Err(e) => {
                        let _ = res_tx.send(Err(e));
                        return;
                    }
                };
                // ONE engine for the worker's lifetime: each submission
                // resets the round (recycling staging buffers) and
                // installs the snapshot it carries
                let mut engine: Option<SelectionEngine<'_>> = None;
                while let Ok(req) = req_rx.recv() {
                    let mut round = cfg.request.clone();
                    round.rng_tag = req.rng_tag;
                    if engine.is_none() {
                        engine = Some(SelectionEngine::new(&rt, req.state, &train, &val));
                    } else {
                        engine.as_mut().unwrap().reset_round(Some(req.state));
                    }
                    let out = engine.as_ref().unwrap().select_with(strategy.as_mut(), &round);
                    if res_tx.send(out).is_err() {
                        break; // trainer gone
                    }
                }
            })
            .map_err(|e| anyhow!("spawning selector thread: {e}"))?;
        Ok(AsyncSelector {
            req_tx: Some(req_tx),
            res_rx,
            handle: Some(handle),
            inflight: 0,
        })
    }

    /// Submit a snapshot for selection (non-blocking). At most one request
    /// should be in flight; the trainer checks `inflight` first.  A shut
    /// down or dead worker is an `Err`, never a panic — the trainer
    /// logs it and falls back to synchronous rounds.
    pub fn request(&mut self, state: ModelState, rng_tag: u64) -> Result<()> {
        self.req_tx
            .as_ref()
            .ok_or_else(|| anyhow!("selector shut down"))?
            .send(SelectRequest { state, rng_tag })
            .map_err(|_| anyhow!("selector thread died"))?;
        self.inflight += 1;
        Ok(())
    }

    /// Non-blocking poll for a finished round.
    pub fn try_recv(&mut self) -> Result<Option<SelectionReport>> {
        match self.res_rx.try_recv() {
            Ok(res) => {
                self.inflight = self.inflight.saturating_sub(1);
                res.map(Some)
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(anyhow!("selector thread died")),
        }
    }

    /// Blocking wait for a finished round.
    pub fn recv(&mut self) -> Result<SelectionReport> {
        let res = self
            .res_rx
            .recv()
            .map_err(|_| anyhow!("selector thread died"))?;
        self.inflight = self.inflight.saturating_sub(1);
        res
    }

    /// Deadline-bounded wait for a finished round: `Ok(Some(report))` when
    /// one lands within `timeout`, `Ok(None)` on timeout (the round stays
    /// in flight — a *wedged* worker costs the caller `timeout`, never
    /// forever, which is why the trainer routes its overlapped-round wait
    /// through here and falls back to a synchronous round on `None`), and
    /// `Err` when the worker died.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<SelectionReport>> {
        match self.res_rx.recv_timeout(timeout) {
            Ok(res) => {
                self.inflight = self.inflight.saturating_sub(1);
                res.map(Some)
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(anyhow!("selector thread died")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::RoundStats;
    use crate::selection::Selection;

    /// An AsyncSelector over raw channels (no runtime, no thread): the
    /// harness for pinning the channel-facing contract, including the
    /// wedged-worker case a real worker cannot produce on demand.
    fn fake_selector() -> (Sender<Result<SelectionReport>>, AsyncSelector) {
        let (res_tx, res_rx) = channel::<Result<SelectionReport>>();
        let (req_tx, _req_rx_parked) = channel::<SelectRequest>();
        // keep the request receiver alive inside a leaked box so request()
        // submissions succeed; tests only exercise the response side
        std::mem::forget(_req_rx_parked);
        let sel = AsyncSelector {
            req_tx: Some(req_tx),
            res_rx,
            handle: None,
            inflight: 1,
        };
        (res_tx, sel)
    }

    fn report() -> SelectionReport {
        SelectionReport {
            strategy: "gradmatch".into(),
            budget: 2,
            selection: Selection {
                indices: vec![1, 2],
                weights: vec![1.0, 1.0],
                grad_error: None,
            },
            stats: RoundStats::default(),
        }
    }

    #[test]
    fn recv_timeout_times_out_and_leaves_round_inflight() {
        let (_tx, mut sel) = fake_selector();
        let t0 = std::time::Instant::now();
        let got = sel.recv_timeout(Duration::from_millis(30)).unwrap();
        assert!(got.is_none(), "nothing was sent — must time out");
        assert!(t0.elapsed() >= Duration::from_millis(25));
        assert_eq!(sel.inflight, 1, "a timeout must not consume the in-flight slot");
    }

    #[test]
    fn recv_timeout_delivers_and_decrements_inflight() {
        let (tx, mut sel) = fake_selector();
        tx.send(Ok(report())).unwrap();
        let got = sel.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(got.unwrap().budget, 2);
        assert_eq!(sel.inflight, 0);
    }

    #[test]
    fn recv_timeout_late_report_lands_on_the_next_wait() {
        let (tx, mut sel) = fake_selector();
        let worker = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            let _ = tx.send(Ok(report()));
        });
        // first wait times out (worker still "computing")...
        assert!(sel.recv_timeout(Duration::from_millis(5)).unwrap().is_none());
        // ...the late round lands on a later wait, not lost
        let got = sel.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(got.is_some());
        assert_eq!(sel.inflight, 0);
        worker.join().unwrap();
    }

    #[test]
    fn recv_timeout_dead_worker_is_err_not_hang() {
        let (tx, mut sel) = fake_selector();
        drop(tx);
        let t0 = std::time::Instant::now();
        assert!(sel.recv_timeout(Duration::from_secs(30)).is_err());
        assert!(t0.elapsed() < Duration::from_secs(5), "disconnect must not wait out the deadline");
    }
}

impl Drop for AsyncSelector {
    fn drop(&mut self) {
        // closing the request channel lets the worker loop exit
        self.req_tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
