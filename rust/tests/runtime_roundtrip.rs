//! Integration: the AOT'd HLO executables compute what the Rust reference
//! math says they should — the cross-layer correctness contract between
//! python/compile (L1+L2) and the coordinator (L3).

mod common;

use common::{assert_close, runtime, tiny_mnist};
use gradmatch::data::padded_chunks;
use gradmatch::rng::Rng;
use gradmatch::tensor::{dot, Matrix};

const MODEL: &str = "lenet_narrow"; // smallest variant: d=784 h=32 c=10 P=330

/// Rust-side forward pass: returns (hidden, logits) for one sample.
fn forward_ref(
    st: &gradmatch::runtime::ModelState,
    x: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let m = &st.meta;
    let mut h = vec![0.0f32; m.h];
    for j in 0..m.h {
        let mut acc = st.b1[j];
        for i in 0..m.d {
            acc += x[i] * st.w1[i * m.h + j];
        }
        h[j] = acc.max(0.0);
    }
    let mut logits = vec![0.0f32; m.c];
    for c in 0..m.c {
        let mut acc = st.b2[c];
        for j in 0..m.h {
            acc += h[j] * st.w2[j * m.c + c];
        }
        logits[c] = acc;
    }
    (h, logits)
}

fn softmax(v: &[f32]) -> Vec<f32> {
    let mx = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = v.iter().map(|&x| (x - mx).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let a = rt.init(MODEL, 3).unwrap();
    let b = rt.init(MODEL, 3).unwrap();
    let c = rt.init(MODEL, 4).unwrap();
    assert_eq!(a.w1, b.w1);
    assert_eq!(a.w2, b.w2);
    assert_ne!(a.w1, c.w1);
    // He-init scale sanity: std ≈ sqrt(2/d)
    let std: f32 = (a.w1.iter().map(|v| v * v).sum::<f32>() / a.w1.len() as f32).sqrt();
    let want = (2.0f32 / a.meta.d as f32).sqrt();
    assert_close(std, want, want * 0.2, "init std");
}

#[test]
fn grads_chunk_matches_rust_reference_math() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let st = rt.init(MODEL, 1).unwrap();
    let splits = tiny_mnist(600);
    let idx: Vec<usize> = (0..40).collect();
    let chunk = padded_chunks(&splits.train, &idx, st.meta.chunk).next().unwrap();
    let g = rt.grads_chunk(&st, &chunk.x, &chunk.y, &chunk.mask).unwrap();
    let m = st.meta.clone();
    for s in [0usize, 7, 39] {
        let x = &chunk.x[s * m.d..(s + 1) * m.d];
        let (h, logits) = forward_ref(&st, x);
        let p = softmax(&logits);
        let y = chunk.y[s] as usize;
        // expected row: flatten(h ⊗ err) ++ err
        for (j, &hj) in h.iter().enumerate().step_by(5) {
            for c in (0..m.c).step_by(3) {
                let err = p[c] - if c == y { 1.0 } else { 0.0 };
                assert_close(
                    g.at(s, j * m.c + c),
                    hj * err,
                    2e-4,
                    &format!("grad[{s}][{j},{c}]"),
                );
            }
        }
        for c in 0..m.c {
            let err = p[c] - if c == y { 1.0 } else { 0.0 };
            assert_close(g.at(s, m.h * m.c + c), err, 2e-4, "bias grad");
        }
    }
    // padded rows must be zero
    for s in 40..st.meta.chunk {
        assert!(g.row(s).iter().all(|&v| v == 0.0), "padding row {s} nonzero");
    }
}

#[test]
fn mean_grad_chunk_equals_column_sum_of_grads() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let st = rt.init(MODEL, 2).unwrap();
    let splits = tiny_mnist(600);
    let idx: Vec<usize> = (5..77).collect();
    let chunk = padded_chunks(&splits.train, &idx, st.meta.chunk).next().unwrap();
    let g = rt.grads_chunk(&st, &chunk.x, &chunk.y, &chunk.mask).unwrap();
    let mg = rt.mean_grad_chunk(&st, &chunk.x, &chunk.y, &chunk.mask).unwrap();
    for col in (0..st.meta.p).step_by(17) {
        let sum: f32 = (0..st.meta.chunk).map(|r| g.at(r, col)).sum();
        assert_close(mg[col], sum, 3e-3, &format!("mean_grad col {col}"));
    }
}

#[test]
fn corr_chunk_matches_rust_gemv() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let meta = rt.model(MODEL).unwrap().clone();
    let mut rng = Rng::new(9);
    let g = Matrix::from_vec(
        meta.chunk,
        meta.p,
        (0..meta.chunk * meta.p).map(|_| rng.gaussian_f32()).collect(),
    );
    let r: Vec<f32> = (0..meta.p).map(|_| rng.gaussian_f32()).collect();
    let got = rt.corr_chunk(MODEL, &g, &r).unwrap();
    for row in (0..meta.chunk).step_by(31) {
        let want = dot(g.row(row), &r);
        assert_close(got[row], want, 3e-2_f32.max(want.abs() * 1e-3), "corr row");
    }
}

#[test]
fn sqdist_chunk_matches_rust_sqdist() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let meta = rt.model(MODEL).unwrap().clone();
    let mut rng = Rng::new(10);
    let a = Matrix::from_vec(
        meta.chunk,
        meta.p,
        (0..meta.chunk * meta.p).map(|_| rng.gaussian_f32()).collect(),
    );
    let b = Matrix::from_vec(
        meta.chunk,
        meta.p,
        (0..meta.chunk * meta.p).map(|_| rng.gaussian_f32()).collect(),
    );
    let d = rt.sqdist_chunk(MODEL, &a, &b).unwrap();
    for i in (0..meta.chunk).step_by(63) {
        for j in (0..meta.chunk).step_by(47) {
            let want = gradmatch::tensor::sqdist(a.row(i), b.row(j));
            assert_close(d.at(i, j), want, want.abs() * 5e-3 + 0.05, "sqdist cell");
        }
    }
}

#[test]
fn train_step_descends_and_matches_update_rule_shape() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let mut st = rt.init(MODEL, 5).unwrap();
    let splits = tiny_mnist(600);
    let m = st.meta.clone();
    let idx: Vec<usize> = (0..m.batch).collect();
    let mut x = vec![0.0f32; m.batch * m.d];
    let mut y = vec![0i32; m.batch];
    for (s, &i) in idx.iter().enumerate() {
        x[s * m.d..(s + 1) * m.d].copy_from_slice(splits.train.x.row(i));
        y[s] = splits.train.y[i];
    }
    let w = vec![1.0f32; m.batch];
    let w1_before = st.w1.clone();
    let (loss0, _) = rt.train_step(&mut st, &x, &y, &w, 0.05).unwrap();
    assert_ne!(st.w1, w1_before, "params must move");
    let mut last = loss0;
    for _ in 0..25 {
        let (loss, _) = rt.train_step(&mut st, &x, &y, &w, 0.05).unwrap();
        last = loss;
    }
    assert!(
        last < loss0 * 0.6,
        "fixed-batch loss should drop: {loss0} -> {last}"
    );
}

#[test]
fn train_step_zero_lr_is_identity_on_params() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let mut st = rt.init(MODEL, 6).unwrap();
    let splits = tiny_mnist(600);
    let m = st.meta.clone();
    let mut x = vec![0.0f32; m.batch * m.d];
    let mut y = vec![0i32; m.batch];
    for s in 0..m.batch {
        x[s * m.d..(s + 1) * m.d].copy_from_slice(splits.train.x.row(s));
        y[s] = splits.train.y[s];
    }
    let w = vec![1.0f32; m.batch];
    let w1 = st.w1.clone();
    let b2 = st.b2.clone();
    rt.train_step(&mut st, &x, &y, &w, 0.0).unwrap();
    assert_eq!(st.w1, w1);
    assert_eq!(st.b2, b2);
    // momentum buffers still accumulate the gradient
    assert!(st.m_w2.iter().any(|&v| v != 0.0));
}

#[test]
fn fused_train_step_matches_unfused() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let splits = tiny_mnist(600);
    let m = rt.model(MODEL).unwrap().clone();
    let mut x = vec![0.0f32; m.batch * m.d];
    let mut y = vec![0i32; m.batch];
    for s in 0..m.batch {
        x[s * m.d..(s + 1) * m.d].copy_from_slice(splits.train.x.row(s));
        y[s] = splits.train.y[s];
    }
    let w = vec![1.0f32; m.batch];
    let mut st = rt.init(MODEL, 11).unwrap();
    let mut fs = gradmatch::runtime::FusedState::from_state(&st).unwrap();
    for step in 0..4 {
        let (l1, c1) = rt.train_step(&mut st, &x, &y, &w, 0.05).unwrap();
        let (l2, c2) = rt.train_step_fused(&mut fs, &x, &y, &w, 0.05).unwrap();
        assert_close(l1, l2, 1e-5 + l1.abs() * 1e-4, &format!("fused loss step {step}"));
        assert_close(c1, c2, 0.5, "fused correct");
    }
    let st2 = fs.to_state().unwrap();
    for (a, b) in st.w1.iter().zip(&st2.w1) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
    for (a, b) in st.m_w2.iter().zip(&st2.m_w2) {
        assert!((a - b).abs() < 1e-4, "momentum {a} vs {b}");
    }
}

#[test]
fn batch_gradsum_matches_per_sample_grouping() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let st = rt.init(MODEL, 13).unwrap();
    let splits = tiny_mnist(700);
    // 300 rows: two full 128-batches + one 44-row tail across two chunks
    let order: Vec<usize> = (0..300).collect();
    let (bg, members) =
        gradmatch::grads::per_batch_grads_fused(&rt, &st, &splits.train, &order).unwrap();
    let store = gradmatch::grads::per_sample_grads(&rt, &st, &splits.train, &order).unwrap();
    let (bg_ref, members_ref) = gradmatch::grads::per_batch_grads(&store, st.meta.batch);
    assert_eq!(bg.rows, bg_ref.rows);
    assert_eq!(members, members_ref);
    for b in 0..bg.rows {
        for col in (0..st.meta.p).step_by(13) {
            assert_close(
                bg.at(b, col),
                bg_ref.at(b, col),
                2e-4 + bg_ref.at(b, col).abs() * 1e-3,
                &format!("batch {b} col {col}"),
            );
        }
    }
}

#[test]
fn pack_unpack_roundtrip() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let st = rt.init(MODEL, 12).unwrap();
    let flat = st.pack();
    assert_eq!(flat.len(), 2 * st.param_count());
    let st2 = gradmatch::runtime::ModelState::unpack(&st.meta, &flat);
    assert_eq!(st.w1, st2.w1);
    assert_eq!(st.b2, st2.b2);
    assert_eq!(st.m_b1, st2.m_b1);
}

#[test]
fn eval_chunk_counts_are_consistent() {
    if !common::runtime_available() {
        return;
    }
    let rt = runtime();
    let st = rt.init(MODEL, 7).unwrap();
    let splits = tiny_mnist(600);
    let idx: Vec<usize> = (0..100).collect();
    let mut total_correct = 0.0;
    for chunk in padded_chunks(&splits.train, &idx, st.meta.chunk) {
        let (sl, sc, correct, entropy) =
            rt.eval_chunk(&st, &chunk.x, &chunk.y, &chunk.mask).unwrap();
        assert!(sl >= 0.0);
        let live_correct: f32 = correct.iter().sum();
        assert_close(sc, live_correct, 1e-3, "eval count");
        // entropy ∈ [0, ln C] on live rows, 0 on padding
        for (s, &e) in entropy.iter().enumerate() {
            if s < chunk.live {
                assert!(e >= -1e-5 && e <= (st.meta.c as f32).ln() + 1e-4, "{e}");
            } else {
                assert_eq!(e, 0.0);
            }
        }
        total_correct += sc;
    }
    assert!(total_correct <= 100.0);
}

#[test]
fn xla_corr_backend_equals_rust_backend_inside_omp() {
    if !common::runtime_available() {
        return;
    }
    use gradmatch::omp::{omp_select, CorrBackend, OmpOpts, RustCorr, XlaCorr};
    let rt = runtime();
    let meta = rt.model(MODEL).unwrap().clone();
    let mut rng = Rng::new(11);
    // 3 chunks worth of candidates, arbitrary rows
    let n = meta.chunk * 2 + 57;
    let g = Matrix::from_vec(n, meta.p, (0..n * meta.p).map(|_| rng.gaussian_f32()).collect());
    let target: Vec<f32> = (0..meta.p).map(|_| rng.gaussian_f32()).collect();
    let mut xla = XlaCorr::new(&rt, MODEL, &g).unwrap();
    let mut rust = RustCorr { g: &g };
    let cx = xla.corr(&target).unwrap();
    let cr = rust.corr(&target).unwrap();
    assert_eq!(cx.len(), cr.len());
    for i in (0..n).step_by(97) {
        assert_close(cx[i], cr[i], cr[i].abs() * 2e-3 + 5e-2, "corr backend");
    }
    let opts = OmpOpts { k: 6, lambda: 0.5, eps: 1e-12 };
    let rx = omp_select(&mut xla, &|j| g.row(j).to_vec(), &target, opts).unwrap();
    let rr = omp_select(&mut rust, &|j| g.row(j).to_vec(), &target, opts).unwrap();
    assert_eq!(rx.selected, rr.selected, "same support through both backends");
}
