//! Gradient acquisition layer: runs the AOT'd gradient entry points over a
//! dataset and exposes the views the selection strategies need —
//! per-sample last-layer gradients, per-mini-batch (PB) aggregates,
//! per-class column slices (the paper's per-class-per-gradient
//! approximation), and mean/target gradients.
//!
//! # Single-pass class-sliced staging
//!
//! Per-class strategies used to issue one padded `grads_chunk` pass *per
//! class* (each paying its own chunk-padding waste) plus a second
//! `mean_grad_chunk` pass per class for the train-side target —
//! `Σ_c ⌈n_c/chunk⌉ + Σ_c ⌈n_c/chunk⌉` runtime dispatches per selection
//! round.  [`stage_class_grads`] replaces all of that with **one** padded
//! pass over the full ground set (`⌈|ground|/chunk⌉` dispatches): each
//! live row's gradient is scattered directly into its class's staged
//! matrix (the `(H+1)`-dim class column slice, or the full-P row for the
//! PerClass variant), and the per-class train-side targets fall out
//! for free as f64-accumulated column means of the same pass.  GLISTER
//! needs only the scalar Taylor gains, so it streams through
//! [`score_grads`] (same one-pass dispatch count, O(chunk·P) transient
//! memory).  The [`GradOracle`] seam keeps every pass testable without a
//! device — the dispatch-count contract above is pinned by a counting
//! oracle in `tests/round_engine.rs`.
//!
//! Memory: staging holds all classes at once — `[|ground|, H+1]` on the
//! per-gradient path (cheaper than the old transient full-P stores), or
//! `[|ground|, P]` on the PerClass full-P path (the old path's *peak*
//! was one class at a time; set `GradMatch::parallel = false` to fall
//! back to the serial per-class passes on memory-constrained full-P
//! runs — the paper-default per-gradient variant never pays this).

use anyhow::Result;

use crate::data::{padded_chunks, Dataset, PaddedChunk};
use crate::par::{dot, norm2};
use crate::runtime::{ModelState, Runtime};
use crate::tensor::{axpy, Matrix};

/// Per-sample evaluation streams of one padded chunk — the entries the
/// scoring baselines consume: ENTROPY ranks `entropy`, FORGETTING tracks
/// `correct` flips.  Padded slots carry zeros.
#[derive(Clone, Debug, Default)]
pub struct EvalEntries {
    /// 1.0 where the model classifies the slot correctly, else 0.0
    pub correct: Vec<f32>,
    /// predictive entropy per slot
    pub entropy: Vec<f32>,
}

/// Chunk-level gradient oracle: the runtime entry points an acquisition
/// pass may dispatch, behind a seam so tests and benches can substitute
/// synthetic ([`SynthGrads`]) or counting implementations.  Production
/// code goes through [`RtGrads`] (the AOT'd executables).
///
/// The seam covers the full acquisition surface of the strategy catalog:
/// per-sample gradients and fused means (per-class strategies, GLISTER),
/// per-mini-batch group sums (the PB ground sets), and per-sample eval
/// entries (ENTROPY, FORGETTING) — which is what lets every spec in
/// [`crate::selection::strategy_specs`] run device-free through a
/// [`crate::engine::SelectionEngine`] oracle backend.
pub trait GradOracle {
    /// fixed rows of every padded dispatch (the executables' static shape)
    fn chunk_rows(&self) -> usize;
    /// last-layer gradient dimension P
    fn p(&self) -> usize;
    /// mini-batch group rows B of [`GradOracle::batch_gradsum_chunk`]
    /// (divides `chunk_rows`; the PB ground-set granularity)
    fn batch_rows(&self) -> usize;
    /// per-sample last-layer gradients of one padded chunk → `[chunk, P]`
    fn grads_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix>;
    /// masked gradient *sum* of one padded chunk → `[P]` (fused fast path)
    fn mean_grad_chunk(&mut self, chunk: &PaddedChunk) -> Result<Vec<f32>>;
    /// masked per-group gradient *sums* of one padded chunk →
    /// `[chunk/B, P]` (device-side group reduction; the PB fast path)
    fn batch_gradsum_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix>;
    /// per-sample eval entries of one padded chunk (correctness flags +
    /// predictive entropies; padded slots zero)
    fn eval_chunk(&mut self, chunk: &PaddedChunk) -> Result<EvalEntries>;
}

/// A `&mut` reference dispatches through to the referent, so decorators
/// (`FaultyOracle`, `Retrying`) can be generic over *either* an owned oracle
/// or a borrowed one — the daemon boxes owned stacks, tests keep borrowing.
impl<T: GradOracle + ?Sized> GradOracle for &mut T {
    fn chunk_rows(&self) -> usize {
        (**self).chunk_rows()
    }

    fn p(&self) -> usize {
        (**self).p()
    }

    fn batch_rows(&self) -> usize {
        (**self).batch_rows()
    }

    fn grads_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        (**self).grads_chunk(chunk)
    }

    fn mean_grad_chunk(&mut self, chunk: &PaddedChunk) -> Result<Vec<f32>> {
        (**self).mean_grad_chunk(chunk)
    }

    fn batch_gradsum_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        (**self).batch_gradsum_chunk(chunk)
    }

    fn eval_chunk(&mut self, chunk: &PaddedChunk) -> Result<EvalEntries> {
        (**self).eval_chunk(chunk)
    }
}

/// Boxed oracles dispatch through — the engine pool stores per-run oracle
/// stacks as `Box<dyn GradOracle + Send>`.
impl GradOracle for Box<dyn GradOracle + Send> {
    fn chunk_rows(&self) -> usize {
        (**self).chunk_rows()
    }

    fn p(&self) -> usize {
        (**self).p()
    }

    fn batch_rows(&self) -> usize {
        (**self).batch_rows()
    }

    fn grads_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        (**self).grads_chunk(chunk)
    }

    fn mean_grad_chunk(&mut self, chunk: &PaddedChunk) -> Result<Vec<f32>> {
        (**self).mean_grad_chunk(chunk)
    }

    fn batch_gradsum_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        (**self).batch_gradsum_chunk(chunk)
    }

    fn eval_chunk(&mut self, chunk: &PaddedChunk) -> Result<EvalEntries> {
        (**self).eval_chunk(chunk)
    }
}

/// The production oracle: a model snapshot driven through the runtime.
pub struct RtGrads<'a> {
    pub rt: &'a Runtime,
    pub st: &'a ModelState,
}

impl GradOracle for RtGrads<'_> {
    fn chunk_rows(&self) -> usize {
        self.st.meta.chunk
    }

    fn p(&self) -> usize {
        self.st.meta.p
    }

    fn batch_rows(&self) -> usize {
        self.st.meta.batch
    }

    fn grads_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        self.rt.grads_chunk(self.st, &chunk.x, &chunk.y, &chunk.mask)
    }

    fn mean_grad_chunk(&mut self, chunk: &PaddedChunk) -> Result<Vec<f32>> {
        self.rt.mean_grad_chunk(self.st, &chunk.x, &chunk.y, &chunk.mask)
    }

    fn batch_gradsum_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        self.rt.batch_gradsum_chunk(self.st, &chunk.x, &chunk.y, &chunk.mask)
    }

    fn eval_chunk(&mut self, chunk: &PaddedChunk) -> Result<EvalEntries> {
        let (_, _, correct, entropy) =
            self.rt.eval_chunk(self.st, &chunk.x, &chunk.y, &chunk.mask)?;
        Ok(EvalEntries { correct, entropy })
    }
}

/// Bounded retry with deterministic backoff for transient chunk-dispatch
/// failures.  `max_attempts` counts the first try (1 = no retry); the
/// sleep before attempt `k+1` is `backoff_ms << (k-1)` milliseconds —
/// deterministic, so a replayed fault schedule replays the same timing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// total attempts per dispatch (the first try included; min 1)
    pub max_attempts: usize,
    /// base backoff in milliseconds, doubled per extra attempt (0 = none)
    pub backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, backoff_ms: 0 }
    }
}

/// A [`GradOracle`] decorator that retries each chunk dispatch under a
/// [`RetryPolicy`] — the fault-tolerance seam the selection context
/// wraps around both backends, so a transient `grads_chunk` /
/// `batch_gradsum_chunk` / `eval_chunk` failure costs one retry instead
/// of the whole round.  `retries` counts attempts beyond each dispatch's
/// first; the engine folds it into `RoundStats::retries`.
pub struct Retrying<'a> {
    inner: &'a mut dyn GradOracle,
    policy: RetryPolicy,
    /// dispatch attempts beyond the first, across all entry points
    pub retries: usize,
}

impl<'a> Retrying<'a> {
    pub fn new(inner: &'a mut dyn GradOracle, policy: RetryPolicy) -> Self {
        Retrying { inner, policy, retries: 0 }
    }

    fn run<T>(
        &mut self,
        what: &str,
        mut f: impl FnMut(&mut dyn GradOracle) -> Result<T>,
    ) -> Result<T> {
        let attempts = self.policy.max_attempts.max(1);
        let mut last: Option<anyhow::Error> = None;
        for attempt in 1..=attempts {
            if attempt > 1 {
                self.retries += 1;
                if self.policy.backoff_ms > 0 {
                    let delay = self.policy.backoff_ms << (attempt as u64 - 2).min(16);
                    std::thread::sleep(std::time::Duration::from_millis(delay));
                }
            }
            match f(self.inner) {
                Ok(v) => return Ok(v),
                Err(e) => last = Some(e),
            }
        }
        Err(last
            .expect("max_attempts >= 1 ran at least once")
            .context(format!("{what}: failed after {attempts} attempts")))
    }
}

impl GradOracle for Retrying<'_> {
    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn p(&self) -> usize {
        self.inner.p()
    }

    fn batch_rows(&self) -> usize {
        self.inner.batch_rows()
    }

    fn grads_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        self.run("grads_chunk", |o| o.grads_chunk(chunk))
    }

    fn mean_grad_chunk(&mut self, chunk: &PaddedChunk) -> Result<Vec<f32>> {
        self.run("mean_grad_chunk", |o| o.mean_grad_chunk(chunk))
    }

    fn batch_gradsum_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        self.run("batch_gradsum_chunk", |o| o.batch_gradsum_chunk(chunk))
    }

    fn eval_chunk(&mut self, chunk: &PaddedChunk) -> Result<EvalEntries> {
        self.run("eval_chunk", |o| o.eval_chunk(chunk))
    }
}

/// Deterministic synthetic oracle for tests and benches: pseudo-gradients
/// computed host-side from the chunk contents, with dispatch-shaped cost
/// (every call runs over the full *padded* shape, like the fixed-shape
/// executables) and per-entry-point call counters.  A row's gradient
/// depends only on its `(x, y)` values, so staged and per-class passes
/// see bit-identical rows regardless of chunking.
pub struct SynthGrads {
    pub chunk: usize,
    pub p: usize,
    /// mini-batch group rows B of `batch_gradsum_chunk` ([`SynthGrads::new`]
    /// sets B = chunk — one group per dispatch; [`SynthGrads::with_batch`]
    /// picks a finer PB granularity)
    pub batch: usize,
    /// eval-stream salt, mixed into the synthetic correctness/entropy hash
    /// only: tests bump it between rounds to emulate model updates without
    /// perturbing the (state-free) pseudo-gradients
    pub salt: u64,
    /// `grads_chunk` dispatches issued
    pub grad_calls: usize,
    /// `mean_grad_chunk` dispatches issued
    pub mean_calls: usize,
    /// `batch_gradsum_chunk` dispatches issued
    pub gradsum_calls: usize,
    /// `eval_chunk` dispatches issued
    pub eval_calls: usize,
}

impl SynthGrads {
    pub fn new(chunk: usize, p: usize) -> Self {
        Self::with_batch(chunk, p, chunk)
    }

    /// [`SynthGrads::new`] with an explicit PB group size (must divide
    /// `chunk`, like the fixed-shape executables' B | E layout).
    pub fn with_batch(chunk: usize, p: usize, batch: usize) -> Self {
        assert!(batch > 0 && chunk % batch == 0, "PB group size must divide the chunk");
        SynthGrads {
            chunk,
            p,
            batch,
            salt: 0,
            grad_calls: 0,
            mean_calls: 0,
            gradsum_calls: 0,
            eval_calls: 0,
        }
    }

    /// The deterministic per-row feature fold every synthetic entry point
    /// derives from — a row's outputs depend only on its `(x, y)` values,
    /// so results are chunking-invariant.
    fn fold_features(x: &[f32]) -> (f32, f32) {
        let (mut a0, mut a1) = (0.0f32, 0.0f32);
        for (j, &v) in x.iter().enumerate() {
            if j % 2 == 0 {
                a0 += v;
            } else {
                a1 -= v;
            }
        }
        (a0, a1)
    }

    fn compute(&self, chunk: &PaddedChunk) -> Matrix {
        let d = chunk.x.len() / self.chunk;
        let mut out = Matrix::zeros(self.chunk, self.p);
        // every slot is computed — padded slots have zeroed inputs and so
        // produce zero rows, but they still cost flops, mirroring the
        // fixed-shape executables (a dispatch pays for the whole padded
        // chunk however few rows are live — the waste the staged
        // single-pass engine eliminates)
        for slot in 0..self.chunk {
            let x = &chunk.x[slot * d..(slot + 1) * d];
            let (a0, a1) = Self::fold_features(x);
            // cheap deterministic basis (integer hash, no transcendentals
            // — the bench runs millions of these entries)
            let label = chunk.y[slot] as usize;
            let row = out.row_mut(slot);
            for (j, r) in row.iter_mut().enumerate() {
                let t1 = ((j * 37 + label * 17) % 101) as f32 * 0.02 - 1.0;
                let t2 = ((j * 11 + label * 29) % 97) as f32 * 0.02 - 0.97;
                *r = a0 * t1 + a1 * t2;
            }
        }
        out
    }
}

impl GradOracle for SynthGrads {
    fn chunk_rows(&self) -> usize {
        self.chunk
    }

    fn p(&self) -> usize {
        self.p
    }

    fn batch_rows(&self) -> usize {
        self.batch
    }

    fn grads_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        self.grad_calls += 1;
        Ok(self.compute(chunk))
    }

    fn mean_grad_chunk(&mut self, chunk: &PaddedChunk) -> Result<Vec<f32>> {
        self.mean_calls += 1;
        let gm = self.compute(chunk);
        let mut sum = vec![0.0f32; self.p];
        for slot in 0..chunk.live {
            axpy(1.0, gm.row(slot), &mut sum);
        }
        Ok(sum)
    }

    fn batch_gradsum_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        self.gradsum_calls += 1;
        let gm = self.compute(chunk);
        let mut out = Matrix::zeros(self.chunk / self.batch, self.p);
        // masked group sums: padded slots contribute zero, like the
        // device-side reduction
        for slot in 0..chunk.live {
            axpy(1.0, gm.row(slot), out.row_mut(slot / self.batch));
        }
        Ok(out)
    }

    fn eval_chunk(&mut self, chunk: &PaddedChunk) -> Result<EvalEntries> {
        self.eval_calls += 1;
        let d = chunk.x.len() / self.chunk;
        let mut correct = vec![0.0f32; self.chunk];
        let mut entropy = vec![0.0f32; self.chunk];
        for slot in 0..self.chunk {
            if chunk.mask[slot] <= 0.0 {
                continue; // padded slots stay zero
            }
            let x = &chunk.x[slot * d..(slot + 1) * d];
            let (a0, a1) = Self::fold_features(x);
            // quantize the fold so the hash is exactly reproducible across
            // chunkings, then mix in the label and the round salt
            let q0 = (a0 * 512.0).round() as i64;
            let q1 = (a1 * 512.0).round() as i64;
            let h = q0
                .wrapping_mul(0x9E37_79B9)
                .wrapping_add(q1.wrapping_mul(0x85EB_CA6B))
                .wrapping_add((chunk.y[slot] as i64).wrapping_mul(131))
                .wrapping_add(self.salt as i64);
            correct[slot] = if h.rem_euclid(3) == 0 { 1.0 } else { 0.0 };
            entropy[slot] = h.rem_euclid(1009) as f32 / 1009.0;
        }
        Ok(EvalEntries { correct, entropy })
    }
}

/// Per-sample gradients for a set of dataset rows.
#[derive(Clone, Debug)]
pub struct GradientStore {
    /// `[rows.len(), P]` — one last-layer gradient per row
    pub g: Matrix,
    /// dataset index of each gradient row
    pub rows: Vec<usize>,
}

/// Compute per-sample last-layer gradients for `indices` (chunked through
/// the `grads_chunk` executable; padding rows are dropped).
pub fn per_sample_grads(
    rt: &Runtime,
    st: &ModelState,
    ds: &Dataset,
    indices: &[usize],
) -> Result<GradientStore> {
    per_sample_grads_with(&mut RtGrads { rt, st }, ds, indices)
}

/// [`per_sample_grads`] over an explicit oracle.
pub fn per_sample_grads_with(
    oracle: &mut dyn GradOracle,
    ds: &Dataset,
    indices: &[usize],
) -> Result<GradientStore> {
    let (rows, p) = (oracle.chunk_rows(), oracle.p());
    let mut g = Matrix::zeros(indices.len(), p);
    let mut cursor = 0usize;
    for chunk in padded_chunks(ds, indices, rows) {
        let gm = oracle.grads_chunk(&chunk)?;
        for slot in 0..chunk.live {
            g.row_mut(cursor).copy_from_slice(gm.row(slot));
            cursor += 1;
        }
    }
    debug_assert_eq!(cursor, indices.len());
    Ok(GradientStore { g, rows: indices.to_vec() })
}

/// Mean last-layer gradient over `indices` — the matching target
/// ∇L(θ).  Uses the fused `mean_grad_chunk` fast path (never materializes
/// the per-sample matrix).
pub fn mean_gradient(
    rt: &Runtime,
    st: &ModelState,
    ds: &Dataset,
    indices: &[usize],
) -> Result<Vec<f32>> {
    mean_gradient_with(&mut RtGrads { rt, st }, ds, indices)
}

/// [`mean_gradient`] over an explicit oracle.
pub fn mean_gradient_with(
    oracle: &mut dyn GradOracle,
    ds: &Dataset,
    indices: &[usize],
) -> Result<Vec<f32>> {
    let (rows, p) = (oracle.chunk_rows(), oracle.p());
    let mut acc = vec![0.0f32; p];
    for chunk in padded_chunks(ds, indices, rows) {
        let partial = oracle.mean_grad_chunk(&chunk)?;
        axpy(1.0, &partial, &mut acc);
    }
    let n = indices.len().max(1) as f32;
    for v in acc.iter_mut() {
        *v /= n;
    }
    Ok(acc)
}

// ---------------------------------------------------------------------------
// single-pass class-sliced staging (the parallel round engine's feed)
// ---------------------------------------------------------------------------

/// Which per-class matrix the staged pass scatters.  (`Hash`: the width
/// is half of the engine's round-cache key — see
/// [`crate::engine::RoundShared`].)
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StageWidth {
    /// the `(H+1)`-dim class column slice — the paper's per-gradient
    /// approximation (GRAD-MATCH default, CRAIG per-class)
    ClassSlice,
    /// the full P-dim last-layer gradient (PerClass variant, GLISTER)
    Full,
}

/// One class's slice of the staged single-pass gradient store.
#[derive(Clone, Debug)]
pub struct ClassStage {
    /// `[n_c, width]` staged gradients (width = H+1 or P), rows in ground
    /// order
    pub g: Matrix,
    /// dataset row per staged row (same order as `g`)
    pub rows: Vec<usize>,
    /// full-P mean gradient of this class's rows — the train-side
    /// matching target, free as the f64-accumulated column means of the
    /// staged pass.  All-zero when the class is empty; **empty** when the
    /// stage was built with `want_targets = false` (callers like CRAIG
    /// that never match a target skip the O(n·P) accumulation).
    pub target_full: Vec<f32>,
}

/// Stage per-class gradient matrices — and, when `want_targets`,
/// train-side class targets — from one padded pass over `ground` (see
/// the module docs for the dispatch-count contract).  Returns one
/// [`ClassStage`] per class `0..c`; classes absent from `ground` get an
/// empty stage.
pub fn stage_class_grads(
    rt: &Runtime,
    st: &ModelState,
    ds: &Dataset,
    ground: &[usize],
    width: StageWidth,
    want_targets: bool,
) -> Result<Vec<ClassStage>> {
    let (h, c) = (st.meta.h, st.meta.c);
    stage_class_grads_with(&mut RtGrads { rt, st }, ds, ground, h, c, width, want_targets)
}

/// [`stage_class_grads`] over an explicit oracle (`h`/`c` give the class
/// column layout; the oracle's P must equal `h*c + c`).
pub fn stage_class_grads_with(
    oracle: &mut dyn GradOracle,
    ds: &Dataset,
    ground: &[usize],
    h: usize,
    c: usize,
    width: StageWidth,
    want_targets: bool,
) -> Result<Vec<ClassStage>> {
    Ok(stage_class_grads_reusing(oracle, ds, ground, h, c, width, want_targets, Vec::new())?.0)
}

/// [`stage_class_grads_with`] that recycles a previous round's staged
/// buffers: when `prev` has the exact per-class shapes this stage needs
/// (same class count, per-class sizes, and width — true whenever the
/// same ground set is re-staged, e.g. every trainer round), the scatter
/// writes into the old matrices instead of allocating `[|ground|, w]`
/// afresh.  Returns the stages, whether the buffers were reused (the
/// engine's round-reuse path ([`crate::engine::RoundShared`]) feeds the
/// flag into `RoundStats::stage_reused_buffers`), and how many rows were
/// quarantined.
///
/// # Gradient hygiene
///
/// Any dispatched row containing a non-finite value (NaN/Inf — a device
/// fault, an overflowed loss) is **quarantined**: skipped from its
/// class's staged matrix, row list, and target accumulation, so it can
/// never reach OMP or be selected.  Class matrices shrink to their
/// finite row count; a class emptied by quarantine simply presents zero
/// rows, and [`crate::selection::split_budget`] redistributes its budget
/// to the surviving classes.  The fault-free path pays only the
/// `is_finite` scan — staged bytes are bit-identical.
#[allow(clippy::too_many_arguments)]
pub fn stage_class_grads_reusing(
    oracle: &mut dyn GradOracle,
    ds: &Dataset,
    ground: &[usize],
    h: usize,
    c: usize,
    width: StageWidth,
    want_targets: bool,
    prev: Vec<ClassStage>,
) -> Result<(Vec<ClassStage>, bool, usize)> {
    let (chunk_rows, p) = (oracle.chunk_rows(), oracle.p());
    // exact per-class allocations up front (ground order == scatter order)
    let mut sizes = vec![0usize; c];
    for &i in ground {
        sizes[ds.y[i] as usize] += 1;
    }
    let w = match width {
        StageWidth::ClassSlice => h + 1,
        StageWidth::Full => p,
    };
    let slice_cols: Vec<Vec<usize>> = match width {
        StageWidth::ClassSlice => (0..c).map(|cls| class_columns(h, c, cls)).collect(),
        StageWidth::Full => Vec::new(),
    };
    // recycle the previous round's buffers when every shape lines up; the
    // scatter below overwrites every cell of every live row, so no
    // zeroing pass is needed
    let reuse = prev.len() == c
        && prev.iter().zip(&sizes).all(|(st, &n)| st.g.rows == n && st.g.cols == w);
    let (mut gs, mut rows): (Vec<Matrix>, Vec<Vec<usize>>) = if reuse {
        let mut gs = Vec::with_capacity(c);
        let mut rows = Vec::with_capacity(c);
        for stage in prev {
            gs.push(stage.g);
            let mut r = stage.rows;
            r.clear();
            rows.push(r);
        }
        (gs, rows)
    } else {
        (
            sizes.iter().map(|&n| Matrix::zeros(n, w)).collect(),
            sizes.iter().map(|&n| Vec::with_capacity(n)).collect(),
        )
    };
    let mut acc: Vec<Vec<f64>> =
        if want_targets { (0..c).map(|_| vec![0.0f64; p]).collect() } else { Vec::new() };
    let mut cursor = vec![0usize; c];
    let mut quarantined = vec![0usize; c];
    let mut total_quarantined = 0usize;
    for chunk in padded_chunks(ds, ground, chunk_rows) {
        let gm = oracle.grads_chunk(&chunk)?;
        for slot in 0..chunk.live {
            let idx = chunk.indices[slot];
            let cls = ds.y[idx] as usize;
            let src = gm.row(slot);
            if !src.iter().all(|v| v.is_finite()) {
                quarantined[cls] += 1;
                total_quarantined += 1;
                continue;
            }
            let dst = gs[cls].row_mut(cursor[cls]);
            match width {
                StageWidth::Full => dst.copy_from_slice(src),
                StageWidth::ClassSlice => {
                    for (o, &j) in slice_cols[cls].iter().enumerate() {
                        dst[o] = src[j];
                    }
                }
            }
            if want_targets {
                for (a, &v) in acc[cls].iter_mut().zip(src.iter()) {
                    *a += v as f64;
                }
            }
            rows[cls].push(idx);
            cursor[cls] += 1;
        }
    }
    if total_quarantined > 0 {
        // shrink each class matrix to its finite row count (allocated at
        // the pre-quarantine size; the tail rows were never written)
        for cls in 0..c {
            gs[cls].data.truncate(cursor[cls] * w);
            gs[cls].rows = cursor[cls];
        }
    }
    debug_assert!(
        (0..c).all(|cls| cursor[cls] + quarantined[cls] == sizes[cls]),
        "staged + quarantined rows must account for every ground row"
    );
    let mut out = Vec::with_capacity(c);
    for (cls, (g, r)) in gs.into_iter().zip(rows).enumerate() {
        let target_full: Vec<f32> = if want_targets {
            let n = r.len().max(1) as f64;
            acc[cls].iter().map(|&v| (v / n) as f32).collect()
        } else {
            Vec::new()
        };
        out.push(ClassStage { g, rows: r, target_full });
    }
    Ok((out, reuse, total_quarantined))
}

// ---------------------------------------------------------------------------
// sharded staging — the two-level hierarchical-OMP seam
// ---------------------------------------------------------------------------

/// Deterministic contiguous shard boundaries: `n` ground rows cut into
/// `shards` near-equal `[start, end)` slices (the first `n % shards`
/// shards get one extra row).  Contiguous slices keep the per-shard
/// staging passes riding the same `⌈n_s/chunk⌉` chunk-dispatch contract
/// as the flat pass, and make the split independent of label layout.
pub fn shard_bounds(n: usize, shards: usize) -> Vec<(usize, usize)> {
    let s = shards.clamp(1, n.max(1));
    let (base, extra) = (n / s, n % s);
    let mut out = Vec::with_capacity(s);
    let mut start = 0usize;
    for i in 0..s {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Stage one shard's slice of the ground set — a thin, name-giving
/// wrapper over [`stage_class_grads_reusing`]: each shard is staged
/// independently through the same chunk-dispatch seam (`⌈n_s/chunk⌉`
/// grads dispatches), and `prev` carries the *previous shard slot's*
/// buffers, so a budget-bounded sharded round that stages shards one at
/// a time recycles a single allocation across every shard of equal size
/// (and hands it on to the merge re-stage).  Quarantine semantics are
/// inherited unchanged.
#[allow(clippy::too_many_arguments)]
pub fn stage_shard_grads(
    oracle: &mut dyn GradOracle,
    ds: &Dataset,
    shard_ground: &[usize],
    h: usize,
    c: usize,
    width: StageWidth,
    want_targets: bool,
    prev: Vec<ClassStage>,
) -> Result<(Vec<ClassStage>, bool, usize)> {
    stage_class_grads_reusing(oracle, ds, shard_ground, h, c, width, want_targets, prev)
}

/// Validation-side full-P class mean gradients for the **live** classes
/// of a selection round (`flags[c]` from
/// [`crate::selection::live_flags`]): one fused `mean_grad_chunk` pass
/// per live class with validation rows — the `[P]`-readback device
/// traffic the GRAD-MATCH val path always paid (readback, not dispatch
/// count, dominates that term on device backends).  Dead or val-absent
/// classes yield `None` (callers fall back to the staged train target).
/// Shared by the `Strategy` impls (over [`RtGrads`]) and the engine's
/// oracle path, so both compute L_V targets identically.
pub fn live_val_class_means_with(
    oracle: &mut dyn GradOracle,
    val: &Dataset,
    c: usize,
    flags: &[bool],
) -> Result<Vec<Option<Vec<f32>>>> {
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); c];
    for i in 0..val.len() {
        let cls = val.y[i] as usize;
        if cls < c {
            per_class[cls].push(i);
        }
    }
    let mut means = Vec::with_capacity(c);
    for cls in 0..c {
        let rows = &per_class[cls];
        if !flags.get(cls).copied().unwrap_or(false) || rows.is_empty() {
            means.push(None);
        } else {
            means.push(Some(mean_gradient_with(oracle, val, rows)?));
        }
    }
    Ok(means)
}

/// Per-sample scores `g_i · v` for every row of `indices`, streamed
/// chunk-by-chunk from **one** padded pass (GLISTER's Taylor gains
/// against the validation gradient): `⌈n/chunk⌉` dispatches and
/// O(chunk·P) transient memory — the `[n, P]` per-sample store is never
/// materialized.  Scores come back in `indices` order.
pub fn score_grads(
    rt: &Runtime,
    st: &ModelState,
    ds: &Dataset,
    indices: &[usize],
    v: &[f32],
) -> Result<Vec<f32>> {
    score_grads_with(&mut RtGrads { rt, st }, ds, indices, v)
}

/// [`score_grads`] over an explicit oracle.
pub fn score_grads_with(
    oracle: &mut dyn GradOracle,
    ds: &Dataset,
    indices: &[usize],
    v: &[f32],
) -> Result<Vec<f32>> {
    let chunk_rows = oracle.chunk_rows();
    let mut out = Vec::with_capacity(indices.len());
    let mut buf = vec![0.0f32; chunk_rows];
    for chunk in padded_chunks(ds, indices, chunk_rows) {
        let gm = oracle.grads_chunk(&chunk)?;
        crate::par::gemv(&gm, v, &mut buf);
        out.extend_from_slice(&buf[..chunk.live]);
    }
    Ok(out)
}

/// Per-class full-P mean gradients over `rows` of `ds` in **one** padded
/// pass of the `grads_chunk` entry.  Classes absent from `rows` yield
/// `None`.
///
/// Dispatch-vs-readback tradeoff: this replaces `Σ_c ⌈n_c/chunk⌉` fused
/// `mean_grad_chunk` dispatches with `⌈n/chunk⌉` `grads_chunk`
/// dispatches, but each readback grows from `[P]` to `[chunk, P]` —
/// ~chunk× more device-to-host bytes.  Use it where the oracle is
/// host-side (tests/benches) or readback is cheap; the GRAD-MATCH
/// staged round keeps the fused per-class means for its validation
/// targets precisely because readback dominates on real PJRT backends.
pub fn class_mean_gradients(
    rt: &Runtime,
    st: &ModelState,
    ds: &Dataset,
    rows: &[usize],
    c: usize,
) -> Result<Vec<Option<Vec<f32>>>> {
    class_mean_gradients_with(&mut RtGrads { rt, st }, ds, rows, c)
}

/// [`class_mean_gradients`] over an explicit oracle.
pub fn class_mean_gradients_with(
    oracle: &mut dyn GradOracle,
    ds: &Dataset,
    rows: &[usize],
    c: usize,
) -> Result<Vec<Option<Vec<f32>>>> {
    let (chunk_rows, p) = (oracle.chunk_rows(), oracle.p());
    let mut acc: Vec<Vec<f64>> = (0..c).map(|_| vec![0.0f64; p]).collect();
    let mut count = vec![0usize; c];
    for chunk in padded_chunks(ds, rows, chunk_rows) {
        let gm = oracle.grads_chunk(&chunk)?;
        for slot in 0..chunk.live {
            let cls = ds.y[chunk.indices[slot]] as usize;
            for (a, &v) in acc[cls].iter_mut().zip(gm.row(slot)) {
                *a += v as f64;
            }
            count[cls] += 1;
        }
    }
    Ok(acc
        .into_iter()
        .zip(count)
        .map(|(a, n)| {
            if n == 0 {
                None
            } else {
                Some(a.iter().map(|&v| (v / n as f64) as f32).collect())
            }
        })
        .collect())
}

/// Per-mini-batch mean gradients computed with the **device-side group
/// reduction** (`batch_gradsum_chunk`) — the PB fast path: readback is
/// `[n/B, P]` instead of `[n, P]` (§Perf: ~2× on PB selection rounds).
/// Groups are consecutive `meta.batch`-row blocks of `order`.
pub fn per_batch_grads_fused(
    rt: &Runtime,
    st: &ModelState,
    ds: &Dataset,
    order: &[usize],
) -> Result<(Matrix, Vec<Vec<usize>>)> {
    per_batch_grads_fused_with(&mut RtGrads { rt, st }, ds, order)
}

/// [`per_batch_grads_fused`] over an explicit oracle: groups are
/// consecutive [`GradOracle::batch_rows`]-row blocks of `order`, summed
/// by the oracle's group reduction (`⌈n/chunk⌉` dispatches, no `[n, P]`
/// per-sample store).  Returns the batch-gradient matrix and the member
/// rows of each batch.
pub fn per_batch_grads_fused_with(
    oracle: &mut dyn GradOracle,
    ds: &Dataset,
    order: &[usize],
) -> Result<(Matrix, Vec<Vec<usize>>)> {
    let (chunk_rows, p, b) = (oracle.chunk_rows(), oracle.p(), oracle.batch_rows());
    assert!(b > 0 && chunk_rows % b == 0, "PB group size must divide the chunk");
    let nb_total = order.len().div_ceil(b);
    let mut bg = Matrix::zeros(nb_total, p);
    let mut members: Vec<Vec<usize>> = Vec::with_capacity(nb_total);
    let mut batch_cursor = 0usize;
    for chunk in padded_chunks(ds, order, chunk_rows) {
        let sums = oracle.batch_gradsum_chunk(&chunk)?;
        let groups_in_chunk = chunk_rows / b;
        for gi in 0..groups_in_chunk {
            let lo = gi * b;
            if lo >= chunk.live {
                break;
            }
            let hi = ((gi + 1) * b).min(chunk.live);
            let live = (hi - lo) as f32;
            let row = bg.row_mut(batch_cursor);
            row.copy_from_slice(sums.row(gi));
            for v in row.iter_mut() {
                *v /= live;
            }
            members.push(chunk.indices[lo..hi].to_vec());
            batch_cursor += 1;
        }
    }
    debug_assert_eq!(batch_cursor, nb_total);
    Ok((bg, members))
}

/// Per-sample eval entries (correctness flags + predictive entropies) for
/// every row of `indices`, streamed from one padded pass of the oracle's
/// eval entry (`⌈n/chunk⌉` dispatches).  Entries come back in `indices`
/// order — the acquisition pass behind ENTROPY and FORGETTING.
pub fn eval_entries_with(
    oracle: &mut dyn GradOracle,
    ds: &Dataset,
    indices: &[usize],
) -> Result<EvalEntries> {
    let chunk_rows = oracle.chunk_rows();
    let mut correct = Vec::with_capacity(indices.len());
    let mut entropy = Vec::with_capacity(indices.len());
    for chunk in padded_chunks(ds, indices, chunk_rows) {
        let ev = oracle.eval_chunk(&chunk)?;
        correct.extend_from_slice(&ev.correct[..chunk.live]);
        entropy.extend_from_slice(&ev.entropy[..chunk.live]);
    }
    Ok(EvalEntries { correct, entropy })
}

/// Per-mini-batch aggregation (the PB variants): group gradient rows into
/// consecutive batches of `batch` and average.  Returns the batch-gradient
/// matrix and the member rows of each batch.
pub fn per_batch_grads(store: &GradientStore, batch: usize) -> (Matrix, Vec<Vec<usize>>) {
    assert!(batch > 0);
    let n = store.g.rows;
    let p = store.g.cols;
    let nb = n.div_ceil(batch);
    let mut bg = Matrix::zeros(nb, p);
    let mut members = Vec::with_capacity(nb);
    for b in 0..nb {
        let lo = b * batch;
        let hi = ((b + 1) * batch).min(n);
        let row = bg.row_mut(b);
        for i in lo..hi {
            axpy(1.0, store.g.row(i), row);
        }
        let cnt = (hi - lo) as f32;
        for v in row.iter_mut() {
            *v /= cnt;
        }
        members.push(store.rows[lo..hi].to_vec());
    }
    (bg, members)
}

/// Column indices of class `cls` in the last-layer gradient layout
/// (`w2_row_major_hc_then_bias`): W2 entries `{j*C + cls : j < H}` plus the
/// bias entry `H*C + cls`.  This is the paper's *per-gradient*
/// approximation — class-c rows only have nonzero error in a few logits,
/// and their own logit dominates, so OMP runs on this (H+1)-dim slice.
pub fn class_columns(h: usize, c: usize, cls: usize) -> Vec<usize> {
    assert!(cls < c);
    let mut cols: Vec<usize> = (0..h).map(|j| j * c + cls).collect();
    cols.push(h * c + cls);
    cols
}

/// Gradient-matching error ‖ Σᵢ wᵢ gᵢ − target ‖ — the `Err` term of
/// Theorem 1, reported in Table 9 and logged at every selection round.
pub fn gradient_error(g_sel: &Matrix, weights: &[f32], target: &[f32]) -> f32 {
    assert_eq!(g_sel.rows, weights.len());
    assert_eq!(g_sel.cols, target.len());
    let mut fitted = vec![0.0f32; target.len()];
    for (i, &w) in weights.iter().enumerate() {
        if w != 0.0 {
            axpy(w, g_sel.row(i), &mut fitted);
        }
    }
    let diff = crate::tensor::sub(&fitted, target);
    norm2(&diff)
}

/// Cosine similarity between a matched gradient and the target — a cheap
/// health metric (Theorem 4's descent condition needs it positive).
pub fn match_cosine(g_sel: &Matrix, weights: &[f32], target: &[f32]) -> f32 {
    let mut fitted = vec![0.0f32; target.len()];
    for (i, &w) in weights.iter().enumerate() {
        axpy(w, g_sel.row(i), &mut fitted);
    }
    let denom = norm2(&fitted) * norm2(target);
    if denom <= 1e-20 {
        return 0.0;
    }
    dot(&fitted, target) / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Tiny synthetic dataset with the given class labels.
    fn toy_dataset(d: usize, y: Vec<i32>, classes: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let n = y.len();
        let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
        Dataset { x, y, classes }
    }

    #[test]
    fn synth_oracle_rows_are_chunking_invariant() {
        // the same dataset row must produce the same pseudo-gradient
        // whatever chunk it lands in — the property the staging
        // equivalence tests lean on
        let (h, c) = (3usize, 2usize);
        let p = h * c + c;
        let ds = toy_dataset(5, vec![0, 1, 1, 0, 1, 0, 0], 2, 9);
        let idx: Vec<usize> = (0..7).collect();
        let mut o_small = SynthGrads::new(2, p);
        let mut o_big = SynthGrads::new(16, p);
        let a = per_sample_grads_with(&mut o_small, &ds, &idx).unwrap();
        let b = per_sample_grads_with(&mut o_big, &ds, &idx).unwrap();
        assert_eq!(a.g.data, b.g.data);
        assert_eq!(o_small.grad_calls, 4); // ⌈7/2⌉
        assert_eq!(o_big.grad_calls, 1); // ⌈7/16⌉
        assert_eq!(o_small.mean_calls, 0);
    }

    #[test]
    fn shard_bounds_partition_exactly() {
        for (n, s) in [(10usize, 3usize), (7, 7), (12, 4), (5, 9), (1, 1), (100, 1)] {
            let bounds = shard_bounds(n, s);
            assert_eq!(bounds.len(), s.clamp(1, n.max(1)));
            assert_eq!(bounds[0].0, 0);
            assert_eq!(bounds.last().unwrap().1, n);
            for w in bounds.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            let (min, max) = bounds.iter().fold((usize::MAX, 0), |(lo, hi), &(a, b)| {
                (lo.min(b - a), hi.max(b - a))
            });
            assert!(max - min <= 1, "near-equal shards: {min}..{max}");
        }
        // degenerate: empty ground set still yields one (empty) shard
        assert_eq!(shard_bounds(0, 4), vec![(0, 0)]);
    }

    #[test]
    fn shard_staging_recycles_one_buffer_across_equal_shards() {
        // class-interleaved labels: equal-size contiguous shards have
        // identical per-class shapes, so the previous shard slot's
        // buffers are reused by every later shard
        let (h, c) = (2usize, 2usize);
        let p = h * c + c;
        let n = 12usize;
        let y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
        let ds = toy_dataset(3, y, c, 21);
        let ground: Vec<usize> = (0..n).collect();
        let mut oracle = SynthGrads::new(4, p);
        let mut prev: Vec<ClassStage> = Vec::new();
        for (k, &(a, b)) in shard_bounds(n, 3).iter().enumerate() {
            let (stages, reused, q) = stage_shard_grads(
                &mut oracle, &ds, &ground[a..b], h, c, StageWidth::ClassSlice, true, prev,
            )
            .unwrap();
            assert_eq!(reused, k > 0, "shard {k} reuse");
            assert_eq!(q, 0);
            assert_eq!(stages.iter().map(|s| s.rows.len()).sum::<usize>(), b - a);
            prev = stages;
        }
        // Σ_s ⌈n_s/chunk⌉ = 3 · ⌈4/4⌉
        assert_eq!(oracle.grad_calls, 3);
    }

    #[test]
    fn staged_pass_scatters_rows_in_ground_order() {
        let (h, c) = (2usize, 3usize);
        let p = h * c + c;
        let ds = toy_dataset(4, vec![2, 0, 1, 2, 0, 1, 2, 0], 3, 11);
        let ground = vec![6usize, 1, 3, 4, 0, 7];
        let mut oracle = SynthGrads::new(4, p);
        let stages =
            stage_class_grads_with(&mut oracle, &ds, &ground, h, c, StageWidth::Full, true)
                .unwrap();
        assert_eq!(stages.len(), 3);
        // class 0 rows in ground order: 1, 4, 7; class 2: 6, 3, 0
        assert_eq!(stages[0].rows, vec![1, 4, 7]);
        assert_eq!(stages[1].rows, Vec::<usize>::new());
        assert_eq!(stages[2].rows, vec![6, 3, 0]);
        assert_eq!(stages[0].g.rows, 3);
        assert_eq!(stages[0].g.cols, p);
        // empty class: empty stage, zero target
        assert_eq!(stages[1].g.rows, 0);
        assert!(stages[1].target_full.iter().all(|&v| v == 0.0));
        // exactly one padded pass: ⌈6/4⌉ = 2 dispatches, no mean calls
        assert_eq!(oracle.grad_calls, 2);
        assert_eq!(oracle.mean_calls, 0);
        // target accumulation is opt-in: without it the scatter is
        // identical and target_full stays empty
        let mut no_t = SynthGrads::new(4, p);
        let lean =
            stage_class_grads_with(&mut no_t, &ds, &ground, h, c, StageWidth::Full, false)
                .unwrap();
        for (a, b) in lean.iter().zip(&stages) {
            assert_eq!(a.g.data, b.g.data);
            assert_eq!(a.rows, b.rows);
            assert!(a.target_full.is_empty());
        }
    }

    #[test]
    fn staged_targets_match_per_class_means() {
        let (h, c) = (3usize, 2usize);
        let p = h * c + c;
        let ds = toy_dataset(6, vec![0, 1, 0, 1, 0, 1, 0, 0, 1, 0], 2, 13);
        let ground: Vec<usize> = (0..10).collect();
        let mut oracle = SynthGrads::new(3, p);
        let stages =
            stage_class_grads_with(&mut oracle, &ds, &ground, h, c, StageWidth::ClassSlice, true)
                .unwrap();
        for cls in 0..c {
            let mut mean_oracle = SynthGrads::new(3, p);
            let want = mean_gradient_with(&mut mean_oracle, &ds, &stages[cls].rows).unwrap();
            assert!(mean_oracle.mean_calls > 0);
            for (a, b) in stages[cls].target_full.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
            }
            // staged slice matches the gathered per-class store
            let mut ps_oracle = SynthGrads::new(3, p);
            let store = per_sample_grads_with(&mut ps_oracle, &ds, &stages[cls].rows).unwrap();
            let cols = class_columns(h, c, cls);
            assert_eq!(stages[cls].g.data, store.g.gather_cols(&cols).data);
        }
    }

    #[test]
    fn class_mean_gradients_single_pass_matches_filtered_means() {
        let (h, c) = (2usize, 3usize);
        let p = h * c + c;
        let ds = toy_dataset(5, vec![0, 2, 0, 2, 2, 0], 3, 17);
        let rows: Vec<usize> = (0..6).collect();
        let mut oracle = SynthGrads::new(4, p);
        let means = class_mean_gradients_with(&mut oracle, &ds, &rows, c).unwrap();
        assert_eq!(oracle.grad_calls, 2); // ⌈6/4⌉
        assert!(means[1].is_none(), "class 1 absent");
        for cls in [0usize, 2] {
            let class_rows: Vec<usize> =
                rows.iter().copied().filter(|&i| ds.y[i] as usize == cls).collect();
            let mut ref_oracle = SynthGrads::new(4, p);
            let want = mean_gradient_with(&mut ref_oracle, &ds, &class_rows).unwrap();
            let got = means[cls].as_ref().unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "cls {cls}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn class_columns_layout() {
        // h=3, c=2: class 0 -> [0, 2, 4, 6]; class 1 -> [1, 3, 5, 7]
        assert_eq!(class_columns(3, 2, 0), vec![0, 2, 4, 6]);
        assert_eq!(class_columns(3, 2, 1), vec![1, 3, 5, 7]);
    }

    #[test]
    fn class_columns_cover_p_exactly_once() {
        let (h, c) = (5, 4);
        let mut all: Vec<usize> = (0..c).flat_map(|cls| class_columns(h, c, cls)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..h * c + c).collect::<Vec<_>>());
    }

    #[test]
    fn fused_pb_oracle_pass_matches_per_sample_grouping() {
        // the oracle group reduction must reproduce grouping the
        // per-sample store host-side — one gradsum dispatch per chunk,
        // zero per-sample dispatches
        let (h, c) = (3usize, 2usize);
        let p = h * c + c;
        let ds = toy_dataset(5, vec![0, 1, 1, 0, 1, 0, 0, 1, 0, 1, 0], 2, 21);
        let order: Vec<usize> = vec![4, 0, 9, 2, 7, 1, 10, 5, 3];
        let mut fused = SynthGrads::with_batch(8, p, 2);
        let (bg, members) = per_batch_grads_fused_with(&mut fused, &ds, &order).unwrap();
        assert_eq!(fused.gradsum_calls, order.len().div_ceil(8));
        assert_eq!(fused.grad_calls, 0);
        let mut serial = SynthGrads::new(8, p);
        let store = per_sample_grads_with(&mut serial, &ds, &order).unwrap();
        let (want_bg, want_members) = per_batch_grads(&store, 2);
        assert_eq!(members, want_members);
        assert_eq!(bg.rows, want_bg.rows);
        for (a, b) in bg.data.iter().zip(&want_bg.data) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn eval_entries_are_chunking_invariant_and_salt_sensitive() {
        let (h, c) = (2usize, 3usize);
        let p = h * c + c;
        let ds = toy_dataset(6, vec![0, 2, 1, 0, 2, 1, 0, 1], 3, 23);
        let idx: Vec<usize> = (0..8).collect();
        let mut small = SynthGrads::new(2, p);
        let mut big = SynthGrads::new(16, p);
        let a = eval_entries_with(&mut small, &ds, &idx).unwrap();
        let b = eval_entries_with(&mut big, &ds, &idx).unwrap();
        assert_eq!(a.correct, b.correct, "same row → same flag whatever the chunking");
        assert_eq!(a.entropy, b.entropy);
        assert_eq!(small.eval_calls, 4); // ⌈8/2⌉
        assert_eq!(big.eval_calls, 1);
        assert_eq!(small.grad_calls, 0, "eval entries never dispatch gradients");
        assert!(a.entropy.iter().all(|&e| (0.0..1.0).contains(&e)));
        assert!(a.correct.iter().all(|&f| f == 0.0 || f == 1.0));
        // a salted oracle (emulating a model update) changes the streams
        let mut salted = SynthGrads::new(16, p);
        salted.salt = 7;
        let s = eval_entries_with(&mut salted, &ds, &idx).unwrap();
        assert_ne!(a.entropy, s.entropy, "salt must perturb the eval stream");
    }

    #[test]
    fn restaging_reuses_matching_buffers_and_rejects_mismatches() {
        let (h, c) = (2usize, 3usize);
        let p = h * c + c;
        let ds = toy_dataset(4, vec![2, 0, 1, 2, 0, 1, 2, 0], 3, 25);
        let ground: Vec<usize> = (0..8).collect();
        let mut oracle = SynthGrads::new(4, p);
        let first = stage_class_grads_with(
            &mut oracle, &ds, &ground, h, c, StageWidth::ClassSlice, true,
        )
        .unwrap();
        let fresh = first.clone();
        // same ground, same width: buffers recycle and contents match a
        // fresh stage exactly
        let (again, reused, quarantined) = stage_class_grads_reusing(
            &mut oracle, &ds, &ground, h, c, StageWidth::ClassSlice, true, first,
        )
        .unwrap();
        assert!(reused, "identical shapes must recycle");
        assert_eq!(quarantined, 0);
        for (a, b) in again.iter().zip(&fresh) {
            assert_eq!(a.g.data, b.g.data);
            assert_eq!(a.rows, b.rows);
            assert_eq!(a.target_full, b.target_full);
        }
        // a different width cannot reuse class-slice buffers
        let (_, reused, _) = stage_class_grads_reusing(
            &mut oracle, &ds, &ground, h, c, StageWidth::Full, true, again,
        )
        .unwrap();
        assert!(!reused, "width change must fall back to fresh allocation");
    }

    #[test]
    fn retrying_oracle_recovers_transient_failures_bit_for_bit() {
        let (h, c) = (2usize, 3usize);
        let p = h * c + c;
        let ds = toy_dataset(4, vec![2, 0, 1, 2, 0, 1, 2, 0], 3, 27);
        let idx: Vec<usize> = (0..8).collect();
        let mut clean_oracle = SynthGrads::new(2, p);
        let clean = per_sample_grads_with(&mut clean_oracle, &ds, &idx).unwrap();
        // fail every 2nd attempt: each failed dispatch's immediate retry
        // always lands on an odd attempt and succeeds
        let mut inner = SynthGrads::new(2, p);
        let mut plan = crate::fault::FaultPlan::none(3);
        plan.fail_every = 2;
        let mut faulty = crate::fault::FaultyOracle::new(&mut inner, plan);
        let mut retrying = Retrying::new(&mut faulty, RetryPolicy::default());
        let recovered = per_sample_grads_with(&mut retrying, &ds, &idx).unwrap();
        assert_eq!(recovered.g.data, clean.g.data, "retried rounds must be bit-identical");
        assert_eq!(recovered.rows, clean.rows);
        assert!(retrying.retries > 0, "the schedule must have forced retries");
        assert_eq!(
            inner.grad_calls, clean_oracle.grad_calls,
            "failed attempts never reach the inner oracle"
        );
    }

    #[test]
    fn retrying_oracle_gives_up_after_max_attempts() {
        let p = 9;
        let ds = toy_dataset(4, vec![0, 1, 2, 0], 3, 28);
        let idx: Vec<usize> = (0..4).collect();
        let mut inner = SynthGrads::new(4, p);
        let mut plan = crate::fault::FaultPlan::none(3);
        plan.dispatch_fail = 1.0;
        let mut faulty = crate::fault::FaultyOracle::new(&mut inner, plan);
        let policy = RetryPolicy { max_attempts: 2, backoff_ms: 0 };
        let mut retrying = Retrying::new(&mut faulty, policy);
        let err = per_sample_grads_with(&mut retrying, &ds, &idx).unwrap_err();
        assert!(
            format!("{err:#}").contains("failed after 2 attempts"),
            "exhaustion must name the attempt budget: {err:#}"
        );
        assert_eq!(retrying.retries, 1);
        assert_eq!(inner.grad_calls, 0);
    }

    #[test]
    fn staging_quarantines_non_finite_rows() {
        let (h, c) = (2usize, 3usize);
        let p = h * c + c;
        let ds = toy_dataset(4, vec![2, 0, 1, 2, 0, 1, 2, 0], 3, 29);
        let ground: Vec<usize> = (0..8).collect();
        let mut clean_oracle = SynthGrads::new(4, p);
        let clean =
            stage_class_grads_with(&mut clean_oracle, &ds, &ground, h, c, StageWidth::ClassSlice, true)
                .unwrap();
        let mut inner = SynthGrads::new(4, p);
        let mut plan = crate::fault::FaultPlan::none(3);
        plan.nan_rate = 1.0; // one poisoned row per dispatch
        let mut faulty = crate::fault::FaultyOracle::new(&mut inner, plan);
        let (staged, _, quarantined) = stage_class_grads_reusing(
            &mut faulty, &ds, &ground, h, c, StageWidth::ClassSlice, true, Vec::new(),
        )
        .unwrap();
        assert_eq!(quarantined, 2, "⌈8/4⌉ dispatches, one poisoned row each");
        assert_eq!(faulty.poisoned_rows.len(), 2);
        let staged_rows: usize = staged.iter().map(|s| s.rows.len()).sum();
        assert_eq!(staged_rows, ground.len() - quarantined);
        for stage in &staged {
            assert_eq!(stage.g.rows, stage.rows.len(), "matrices shrink to finite rows");
            assert!(stage.g.data.iter().all(|v| v.is_finite()));
            assert!(stage.target_full.iter().all(|v| v.is_finite()));
            for idx in &stage.rows {
                assert!(
                    !faulty.poisoned_rows.contains(idx),
                    "poisoned row {idx} must never be staged"
                );
            }
        }
        // surviving rows keep their clean gradients, in ground order
        for (cs, fs) in clean.iter().zip(&staged) {
            for (slot, idx) in fs.rows.iter().enumerate() {
                let clean_slot = cs
                    .rows
                    .iter()
                    .position(|r| r == idx)
                    .expect("surviving row present in the clean stage");
                assert_eq!(fs.g.row(slot), cs.g.row(clean_slot));
            }
        }
    }

    #[test]
    fn per_batch_grads_averages_rows() {
        let g = Matrix::from_vec(5, 2, vec![1., 1., 3., 3., 5., 5., 7., 7., 9., 9.]);
        let store = GradientStore { g, rows: vec![10, 11, 12, 13, 14] };
        let (bg, members) = per_batch_grads(&store, 2);
        assert_eq!(bg.rows, 3);
        assert_eq!(bg.row(0), &[2.0, 2.0]); // mean of rows 0,1
        assert_eq!(bg.row(2), &[9.0, 9.0]); // lone last row
        assert_eq!(members[0], vec![10, 11]);
        assert_eq!(members[2], vec![14]);
    }

    #[test]
    fn gradient_error_zero_for_exact_match() {
        let g = Matrix::from_vec(2, 3, vec![1., 0., 0., 0., 1., 0.]);
        let target = [2.0f32, 3.0, 0.0];
        let err = gradient_error(&g, &[2.0, 3.0], &target);
        assert!(err < 1e-6);
        let err2 = gradient_error(&g, &[0.0, 0.0], &target);
        assert!((err2 - (13.0f32).sqrt()).abs() < 1e-5);
    }

    #[test]
    fn match_cosine_signs() {
        let g = Matrix::from_vec(1, 2, vec![1.0, 0.0]);
        assert!((match_cosine(&g, &[1.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!((match_cosine(&g, &[-1.0], &[1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(match_cosine(&g, &[0.0], &[1.0, 0.0]), 0.0);
    }
}
