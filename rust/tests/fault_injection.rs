//! Fault-injection contracts for the fault-tolerance layer, pinned
//! device-free on the synthetic gradient oracle wrapped in
//! [`FaultyOracle`]:
//!
//! - **transparency** — a zero-fault plan is bit-for-bit invisible:
//!   identical selections, identical inner dispatch counts, fault-free
//!   round stats;
//! - **retry** — a deterministic transient-failure schedule under the
//!   default [`RetryPolicy`] yields the *identical* subset to a clean
//!   run for EVERY `strategy_specs()` spec: retries absorb the faults,
//!   the degradation ladder never engages;
//! - **quarantine** — non-finite gradient rows injected into the staged
//!   pass are never selected, and the round reports exactly how many
//!   rows it quarantined;
//! - **degradation ladder** — when the retry budget drains (hard
//!   outage), the engine serves the last round's subset; with no prior
//!   subset it serves a deterministic seeded random one.  Never a panic.

use gradmatch::data::Dataset;
use gradmatch::engine::{Degradation, SelectionEngine, SelectionRequest};
use gradmatch::fault::{FaultPlan, FaultyOracle};
use gradmatch::grads::SynthGrads;
use gradmatch::rng::Rng;
use gradmatch::selection::strategy_specs;
use gradmatch::tensor::Matrix;

const CHUNK: usize = 8;
const BATCH: usize = 4;

/// Imbalanced synthetic dataset: heavy head, long tail, every class
/// populated.
fn imbalanced(seed: u64, classes: usize, d: usize) -> Dataset {
    let mut y: Vec<i32> = Vec::new();
    for cls in 0..classes {
        let n_c = match cls % 3 {
            0 => 37,
            1 => 11,
            _ => 4,
        };
        y.extend(std::iter::repeat(cls as i32).take(n_c));
    }
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut y);
    let n = y.len();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

fn request(strategy: &str, ground: Vec<usize>, budget: usize) -> SelectionRequest {
    SelectionRequest {
        strategy: strategy.into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: 7,
        ground,
        shards: None,
        sketch: None,
    }
}

#[test]
fn zero_fault_plan_is_invisible_to_an_engine_round() {
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(61, classes, d);
    let val = imbalanced(62, classes, d);
    let n = train.len();
    let req = request("gradmatch", (0..n).collect(), n / 4);

    let mut bare = SynthGrads::with_batch(CHUNK, p, BATCH);
    let want = {
        let engine = SelectionEngine::with_oracle(&mut bare, &train, &val, h, classes);
        engine.select(&req).unwrap()
    };

    let mut inner = SynthGrads::with_batch(CHUNK, p, BATCH);
    let mut faulty = FaultyOracle::new(&mut inner, FaultPlan::none(9));
    let got = {
        let engine = SelectionEngine::with_oracle(&mut faulty, &train, &val, h, classes);
        engine.select(&req).unwrap()
    };
    assert_eq!(faulty.injected_failures, 0);
    assert_eq!(faulty.injected_nan_rows, 0);
    assert!(faulty.poisoned_rows.is_empty());

    assert_eq!(got.selection, want.selection, "zero-fault wrapper must be bit-for-bit");
    assert_eq!(got.stats.retries, 0);
    assert_eq!(got.stats.quarantined, 0);
    assert_eq!(got.stats.degradation, Degradation::None);
    assert_eq!(inner.grad_calls, bare.grad_calls);
    assert_eq!(inner.mean_calls, bare.mean_calls);
}

#[test]
fn transient_failures_retry_to_the_identical_subset_for_every_spec() {
    // fail every 5th dispatch attempt: the default retry policy's second
    // attempt can never land on the schedule again, so every dispatch
    // eventually succeeds and the round must equal a clean run exactly —
    // the acceptance contract "dispatch failures complete via retry with
    // no degradation"
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(71, classes, d);
    let val = imbalanced(72, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let budget = n / 4;

    let mut total_retries = 0usize;
    let mut total_injected = 0usize;
    for spec in strategy_specs() {
        let req = request(spec, ground.clone(), budget);

        let mut clean = SynthGrads::with_batch(CHUNK, p, BATCH);
        let want = {
            let engine = SelectionEngine::with_oracle(&mut clean, &train, &val, h, classes);
            engine.select(&req).unwrap()
        };

        let mut inner = SynthGrads::with_batch(CHUNK, p, BATCH);
        let mut plan = FaultPlan::none(13);
        plan.fail_every = 5;
        let mut faulty = FaultyOracle::new(&mut inner, plan);
        let got = {
            let engine = SelectionEngine::with_oracle(&mut faulty, &train, &val, h, classes);
            engine.select(&req).unwrap()
        };
        total_injected += faulty.injected_failures;

        assert_eq!(
            got.selection, want.selection,
            "{spec}: retried round must equal the clean run"
        );
        assert_eq!(got.stats.degradation, Degradation::None, "{spec}: retries absorb the faults");
        assert_eq!(
            got.stats.retries, faulty.injected_failures,
            "{spec}: every injected failure costs exactly one retry"
        );
        // failed attempts never reach the inner oracle, so retry-then-
        // success leaves its counters identical to the clean run
        assert_eq!(
            (inner.grad_calls, inner.mean_calls, inner.gradsum_calls, inner.eval_calls),
            (clean.grad_calls, clean.mean_calls, clean.gradsum_calls, clean.eval_calls),
            "{spec}: inner dispatch counts"
        );
        total_retries += got.stats.retries;
    }
    assert!(total_retries > 0, "the schedule must actually fire somewhere");
    assert_eq!(total_retries, total_injected);
}

#[test]
fn poisoned_gradient_rows_are_quarantined_and_never_selected() {
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(81, classes, d);
    let val = imbalanced(82, classes, d);
    let n = train.len();
    let req = request("gradmatch", (0..n).collect(), n / 4);

    let mut inner = SynthGrads::with_batch(CHUNK, p, BATCH);
    let mut plan = FaultPlan::none(17);
    plan.nan_rate = 1.0; // one corrupted row per staged chunk
    let mut faulty = FaultyOracle::new(&mut inner, plan);
    let got = {
        let engine = SelectionEngine::with_oracle(&mut faulty, &train, &val, h, classes);
        engine.select(&req).unwrap()
    };

    assert_eq!(
        faulty.injected_nan_rows,
        n.div_ceil(CHUNK),
        "nan_rate=1.0 corrupts one live row per gradient chunk"
    );
    assert_eq!(
        got.stats.quarantined, faulty.injected_nan_rows,
        "the round reports exactly the injected corruption"
    );
    for idx in &got.selection.indices {
        assert!(
            !faulty.poisoned_rows.contains(idx),
            "poisoned row {idx} must never be selected"
        );
    }
    assert!(!got.selection.indices.is_empty(), "surviving rows still fill the budget");
    assert_eq!(got.stats.degradation, Degradation::None, "quarantine is not a degradation");

    // same plan, same workload → same quarantine ledger (determinism)
    let mut inner2 = SynthGrads::with_batch(CHUNK, p, BATCH);
    let mut faulty2 = FaultyOracle::new(&mut inner2, plan);
    let again = {
        let engine = SelectionEngine::with_oracle(&mut faulty2, &train, &val, h, classes);
        engine.select(&req).unwrap()
    };
    assert_eq!(faulty.poisoned_rows, faulty2.poisoned_rows);
    assert_eq!(got.selection, again.selection);
}

#[test]
fn exhausted_retries_reuse_the_last_rounds_subset() {
    // round one is clean; from round two on the oracle is a dead
    // accelerator (every attempt fails, retries included) — the ladder
    // serves round one's subset and records the rung, never panicking
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(91, classes, d);
    let val = imbalanced(92, classes, d);
    let n = train.len();
    let req = request("gradmatch", (0..n).collect(), n / 4);

    // deterministic workload → a clean probe run measures exactly how
    // many dispatch attempts one round costs
    let attempts_per_round = {
        let mut inner = SynthGrads::with_batch(CHUNK, p, BATCH);
        let mut probe = FaultyOracle::new(&mut inner, FaultPlan::none(19));
        {
            let engine = SelectionEngine::with_oracle(&mut probe, &train, &val, h, classes);
            engine.select(&req).unwrap();
        }
        probe.attempts
    };

    let mut inner = SynthGrads::with_batch(CHUNK, p, BATCH);
    let mut plan = FaultPlan::none(19);
    plan.fail_from = attempts_per_round + 1;
    let mut faulty = FaultyOracle::new(&mut inner, plan);
    let mut engine = SelectionEngine::with_oracle(&mut faulty, &train, &val, h, classes);

    let clean = engine.select(&req).unwrap();
    assert_eq!(clean.stats.degradation, Degradation::None);

    engine.reset_round(None);
    let degraded = engine.select(&req).unwrap();
    assert_eq!(degraded.stats.degradation, Degradation::ReusedLastRound);
    assert_eq!(
        degraded.selection.indices, clean.selection.indices,
        "the ladder's first rung serves the previous subset"
    );
    assert_eq!(degraded.selection.weights, clean.selection.weights);

    // the outage persists: round three degrades the same way
    engine.reset_round(None);
    let again = engine.select(&req).unwrap();
    assert_eq!(again.stats.degradation, Degradation::ReusedLastRound);
    assert_eq!(again.selection.indices, clean.selection.indices);
}

#[test]
fn total_outage_with_no_history_falls_back_to_a_seeded_random_subset() {
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(101, classes, d);
    let val = imbalanced(102, classes, d);
    let n = train.len();
    let budget = n / 4;
    let req = request("gradmatch", (0..n).collect(), budget);

    let run = || {
        let mut inner = SynthGrads::with_batch(CHUNK, p, BATCH);
        let mut plan = FaultPlan::none(23);
        plan.dispatch_fail = 1.0; // every attempt fails, retries included
        let mut faulty = FaultyOracle::new(&mut inner, plan);
        let engine = SelectionEngine::with_oracle(&mut faulty, &train, &val, h, classes);
        engine.select(&req).unwrap()
    };

    let got = run();
    assert_eq!(got.stats.degradation, Degradation::RandomFallback);
    assert_eq!(got.selection.indices.len(), budget, "the floor still fills the budget");
    assert!(got.selection.indices.iter().all(|&i| i < n), "picks stay inside the ground set");
    let mut sorted = got.selection.indices.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), budget, "picks are distinct");
    assert!(got.selection.weights.iter().all(|&w| w == 1.0), "uniform fallback weights");
    assert_eq!(got.selection.grad_error, None);

    // deterministic in (seed, rng_tag): a second identical run picks the
    // same subset — a degraded round is as reproducible as a normal one
    let again = run();
    assert_eq!(got.selection, again.selection);

    // and a different round tag draws a different subset
    let mut other_req = req.clone();
    other_req.rng_tag = 8;
    let mut inner = SynthGrads::with_batch(CHUNK, p, BATCH);
    let mut plan = FaultPlan::none(23);
    plan.dispatch_fail = 1.0;
    let mut faulty = FaultyOracle::new(&mut inner, plan);
    let other = {
        let engine = SelectionEngine::with_oracle(&mut faulty, &train, &val, h, classes);
        engine.select(&other_req).unwrap()
    };
    assert_eq!(other.stats.degradation, Degradation::RandomFallback);
    assert_ne!(other.selection.indices, got.selection.indices);
}
