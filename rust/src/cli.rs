//! Hand-rolled CLI (clap is not in the offline vendor set).
//!
//! ```text
//! gradmatch train   [--config f.toml] [--set k=v]... [--dataset d] [--strategy s]
//!                   [--budget 0.1] [--epochs N] [--model m] [--seed n] [--runs n]
//! gradmatch sweep   [--config f.toml] [--datasets a,b] [--strategies x,y]
//!                   [--budgets 0.05,0.1,...]
//! gradmatch select  one-shot engine round; [--strategies a,b,c] batches
//!                   requests over one shared staging pass; dumps
//!                   SelectionReport JSON (selection + observability)
//! gradmatch serve   selection-as-a-service daemon over a unix/tcp socket
//!                   (line-delimited JSON; bounded queue + deadlines)
//! gradmatch list-strategies  print every spec with adaptivity/warm flags
//! gradmatch inspect print the artifact manifest summary
//! ```

use anyhow::{anyhow, bail, Result};

use crate::config::{ExperimentConfig, Table};

/// Parsed command line.
#[derive(Clone, Debug)]
pub struct Cli {
    pub command: String,
    /// `--flag value` pairs
    pub flags: Vec<(String, String)>,
    /// bare positional args after the command
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse `args` (excluding argv[0]).
    pub fn parse(args: &[String]) -> Result<Cli> {
        if args.is_empty() {
            bail!("usage: gradmatch <train|sweep|select|serve|inspect> [flags]");
        }
        let command = args[0].clone();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.push((k.to_string(), v.to_string()));
                } else {
                    let v = args
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    flags.push((name.to_string(), v.clone()));
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Cli { command, flags, positional })
    }

    /// Last value of a flag (repeats allowed: later wins), if present.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// All values of a repeatable flag (e.g. `--set`).
    pub fn flag_all(&self, name: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    /// Comma-separated list flag.
    pub fn flag_list(&self, name: &str) -> Option<Vec<String>> {
        self.flag(name)
            .map(|v| v.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect())
    }

    /// Build the experiment config: file (if given) → `--set` overrides →
    /// dedicated convenience flags.
    pub fn experiment_config(&self) -> Result<ExperimentConfig> {
        let mut table = match self.flag("config") {
            Some(path) => Table::from_file(std::path::Path::new(path))?,
            None => Table::default(),
        };
        for ov in self.flag_all("set") {
            table.set(ov)?;
        }
        // convenience flags map onto table keys
        let map: &[(&str, &str)] = &[
            ("dataset", "experiment.dataset"),
            ("model", "experiment.model"),
            ("strategy", "experiment.strategy"),
            ("budget", "experiment.budget_frac"),
            ("epochs", "experiment.epochs"),
            ("r", "experiment.r_interval"),
            ("lr0", "experiment.lr0"),
            ("seed", "experiment.seed"),
            ("runs", "experiment.runs"),
            ("eval-every", "experiment.eval_every"),
            ("n-train", "experiment.n_train"),
            ("lambda", "selection.lambda"),
            ("kappa", "selection.kappa"),
            ("imbalance", "selection.is_valid"),
            ("max-staged-rows", "selection.max_staged_rows"),
            ("sketch-width", "selection.sketch_width"),
            ("reuse-subsets", "selection.reuse_across_arms"),
            ("overlap", "experiment.overlap"),
            ("label-noise", "selection.label_noise"),
            ("artifacts", "paths.artifacts"),
            ("out", "paths.out"),
        ];
        for (flag, key) in map {
            if let Some(v) = self.flag(flag) {
                // strings need quoting for the table parser unless numeric/bool
                let needs_quotes = v.parse::<f64>().is_err() && v != "true" && v != "false";
                let spec = if needs_quotes {
                    format!("{key}=\"{v}\"")
                } else {
                    format!("{key}={v}")
                };
                table.set(&spec)?;
            }
        }
        let mut cfg = ExperimentConfig::from_table(&table)?;
        // default the model variant from the dataset card when the user
        // picked a dataset but no model
        if self.flag("model").is_none() && table.get("experiment.model").is_none() {
            if let Some(card) = crate::data::DatasetCard::by_name(&cfg.dataset) {
                cfg.model = card.model.to_string();
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Top-level usage text.
pub fn usage() -> &'static str {
    "gradmatch — GRAD-MATCH data subset selection (ICML 2021 reproduction)

USAGE:
  gradmatch train   [--config exp.toml] [--dataset synmnist] [--model lenet_s]
                    [--strategy gradmatch-pb-warm] [--budget 0.1] [--epochs 60]
                    [--r 20] [--seed 42] [--runs 1] [--eval-every 5]
                    [--imbalance true] [--max-staged-rows N] [--sketch-width K]
                    [--set section.key=value]...
                    --max-staged-rows N bounds selection-round memory by
                    sharding the ground set (two-level hierarchical OMP)
                    so no staged gradient matrix exceeds N rows
                    --sketch-width K runs Batch-OMP on a seeded JL
                    projection of the staged gradients ([n,P] -> [n,K],
                    K < P) with a full-width weight re-fit on the selected
                    support; composes with sharding (per-shard solves
                    sketch, the merge re-fit stays full width)
                    --reuse-subsets true memoizes solved selection rounds
                    in a cross-arm SelectionCache keyed by (dataset
                    fingerprint, strategy spec, round signature): later
                    sweep arms sharing a signature replay the subset with
                    zero staging dispatches (off by default; see the
                    sweep_transfer bench before flipping it)
  gradmatch sweep   [--datasets synmnist,syncifar10] [--strategies random,gradmatch-pb]
                    [--budgets 0.05,0.1,0.3] [--epochs 60] [--reuse-subsets true] ...
  gradmatch select  one-shot engine selection round; prints SelectionReport
                    JSON (indices+weights plus staging/solve observability
                    and the engine-reuse counters).  --strategies a,b,c
                    batches the round: one staged-gradient pass shared by
                    every request (SelectionEngine cache).  Every listed
                    strategy — including the -pb variants, entropy and
                    forgetting — also runs device-free through the engine's
                    oracle backend (tests/benches)
  gradmatch serve   selection-as-a-service daemon.  Line-delimited JSON over
                    --socket /path.sock (unix) or --tcp host:port; per-run
                    engine pool (--engines N, LRU), bounded admission
                    (--queue-cap N → typed `overloaded` when full),
                    per-request deadlines (--deadline-ms D default, typed
                    `deadline_exceeded`), slow/oversized client shedding
                    (--read-timeout-ms, --max-request-bytes), optional fault
                    injection under every engine (--fault-plan \"spec\"),
                    a daemon-wide cross-arm selection cache
                    (--selection-cache-cap N rounds, LRU; depth + hit
                    counters in `stats`),
                    graceful drain on SIGTERM/SIGINT or a shutdown request.
                    --smoke=true runs a self-contained daemon+client
                    round-trip on an ephemeral socket and exits (CI hook)
  gradmatch list-strategies  print every strategy spec + adaptive/warm flags
  gradmatch inspect print artifact manifest summary

Strategies: random, full, full-earlystop, glister, craig[-pb], gradmatch,
            gradmatch-pb, gradmatch-perclass, entropy, forgetting, featurefl
            — append -warm for the κ warm-start variants
            (`gradmatch list-strategies` prints the full table).
Datasets:   synmnist, syncifar10, syncifar100, synsvhn, synimagenet
"
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_both_styles() {
        let c = Cli::parse(&args(&["train", "--budget", "0.1", "--epochs=30"])).unwrap();
        assert_eq!(c.command, "train");
        assert_eq!(c.flag("budget"), Some("0.1"));
        assert_eq!(c.flag("epochs"), Some("30"));
        assert_eq!(c.flag("nope"), None);
    }

    #[test]
    fn repeated_set_flags_collected() {
        let c = Cli::parse(&args(&[
            "train",
            "--set",
            "experiment.epochs=5",
            "--set",
            "selection.lambda=0.1",
        ]))
        .unwrap();
        assert_eq!(c.flag_all("set").len(), 2);
    }

    #[test]
    fn flag_needs_value() {
        assert!(Cli::parse(&args(&["train", "--budget"])).is_err());
    }

    #[test]
    fn empty_args_error() {
        assert!(Cli::parse(&[]).is_err());
    }

    #[test]
    fn experiment_config_from_flags() {
        let c = Cli::parse(&args(&[
            "train",
            "--dataset",
            "syncifar10",
            "--model",
            "resnet_s",
            "--strategy",
            "craig-pb-warm",
            "--budget",
            "0.3",
            "--epochs",
            "7",
            "--lambda",
            "0.25",
        ]))
        .unwrap();
        let cfg = c.experiment_config().unwrap();
        assert_eq!(cfg.dataset, "syncifar10");
        assert_eq!(cfg.strategy, "craig-pb-warm");
        assert_eq!(cfg.epochs, 7);
        assert!((cfg.lambda - 0.25).abs() < 1e-12);
    }

    #[test]
    fn set_overrides_beat_convenience_order() {
        let c = Cli::parse(&args(&["train", "--epochs", "9"])).unwrap();
        let cfg = c.experiment_config().unwrap();
        assert_eq!(cfg.epochs, 9);
    }

    #[test]
    fn flag_list_splits() {
        let c = Cli::parse(&args(&["sweep", "--budgets", "0.05, 0.1,0.3"])).unwrap();
        assert_eq!(
            c.flag_list("budgets").unwrap(),
            vec!["0.05".to_string(), "0.1".into(), "0.3".into()]
        );
    }

    #[test]
    fn sketch_width_flag_maps_and_zero_is_rejected() {
        let c = Cli::parse(&args(&["train", "--sketch-width", "128"])).unwrap();
        assert_eq!(c.experiment_config().unwrap().sketch_width, 128);
        for bad in ["0", "-4"] {
            let c = Cli::parse(&args(&["train", "--sketch-width", bad])).unwrap();
            let msg = c.experiment_config().unwrap_err().to_string();
            assert!(msg.contains("selection.sketch_width"), "{msg}");
        }
        let c = Cli::parse(&args(&["train", "--max-staged-rows", "0"])).unwrap();
        let msg = c.experiment_config().unwrap_err().to_string();
        assert!(msg.contains("selection.max_staged_rows"), "{msg}");
    }

    #[test]
    fn reuse_subsets_flag_maps_and_defaults_off() {
        let c = Cli::parse(&args(&["sweep"])).unwrap();
        assert!(!c.experiment_config().unwrap().reuse_across_arms);
        let c = Cli::parse(&args(&["sweep", "--reuse-subsets", "true"])).unwrap();
        assert!(c.experiment_config().unwrap().reuse_across_arms);
        let c = Cli::parse(&args(&["sweep", "--reuse-subsets=false"])).unwrap();
        assert!(!c.experiment_config().unwrap().reuse_across_arms);
    }

    #[test]
    fn last_flag_wins() {
        let c = Cli::parse(&args(&["train", "--budget", "0.1", "--budget", "0.2"])).unwrap();
        assert_eq!(c.flag("budget"), Some("0.2"));
    }
}
