//! Contracts of the parallel selection-round engine, pinned host-side on
//! the synthetic gradient oracle (no PJRT / HLO artifacts needed — these
//! run everywhere `cargo test` runs):
//!
//! - the staged single-pass gradient stage reproduces the serial
//!   per-class acquisition exactly (rows, slices, targets);
//! - the runtime dispatch count drops from
//!   `Σ_c ⌈n_c/chunk⌉ (grads) + Σ_c ⌈n_c/chunk⌉ (mean)` to
//!   `⌈|ground|/chunk⌉` on the train-target path (counting oracle);
//! - the class-level fan-out merges bit-identically to the serial solve
//!   order across variants, class counts, and imbalanced budget shapes;
//! - the NaN-safe ranking used by the score baselines never panics and
//!   never lets a NaN win.

use gradmatch::data::Dataset;
use gradmatch::grads::{
    class_columns, class_mean_gradients_with, mean_gradient_with, per_sample_grads_with,
    score_grads_with, stage_class_grads_with, ClassStage, StageWidth, SynthGrads,
};
use gradmatch::rng::Rng;
use gradmatch::selection::{solve_classes_omp, split_budget, top_k_desc};
use gradmatch::tensor::Matrix;
use gradmatch::testutil::{forall, Gen};

/// Random dataset with an explicitly imbalanced class histogram: a few
/// heavy classes, a long tail, and (sometimes) empty classes.
fn imbalanced_dataset(g: &mut Gen, classes: usize, d: usize) -> Dataset {
    let mut y: Vec<i32> = Vec::new();
    for cls in 0..classes {
        let n_c = match cls % 4 {
            0 => g.int(20, 60),     // heavy
            1 => g.int(5, 15),      // mid
            2 => g.int(1, 4),       // tail
            _ => g.int(0, 2),       // sometimes empty
        };
        y.extend(std::iter::repeat(cls as i32).take(n_c));
    }
    // interleave classes like a real shuffled dataset
    let mut rng = Rng::new(g.case as u64 + 7777);
    rng.shuffle(&mut y);
    let n = y.len();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

fn ground_rows_per_class(ds: &Dataset, ground: &[usize]) -> Vec<Vec<usize>> {
    let mut per = vec![Vec::new(); ds.classes];
    for &i in ground {
        per[ds.y[i] as usize].push(i);
    }
    per
}

#[test]
fn staged_pass_reproduces_serial_per_class_acquisition() {
    forall(12, |g| {
        let classes = g.int(2, 8);
        let h = g.int(2, 6);
        let p = h * classes + classes;
        let d = g.int(3, 10);
        let chunk = *g.choose(&[4usize, 16, 64]);
        let ds = imbalanced_dataset(g, classes, d);
        if ds.len() == 0 {
            return;
        }
        // ground set: a subset of rows, in shuffled order
        let take = g.int(1, ds.len());
        let mut ground: Vec<usize> = (0..ds.len()).collect();
        let mut rng = Rng::new(g.case as u64 + 31);
        rng.shuffle(&mut ground);
        ground.truncate(take);

        for width in [StageWidth::ClassSlice, StageWidth::Full] {
            let mut oracle = SynthGrads::new(chunk, p);
            let stages =
                stage_class_grads_with(&mut oracle, &ds, &ground, h, classes, width, true).unwrap();
            assert_eq!(stages.len(), classes);
            let per_class = ground_rows_per_class(&ds, &ground);
            for (cls, stage) in stages.iter().enumerate() {
                // rows land per class in ground order
                assert_eq!(stage.rows, per_class[cls], "cls {cls}");
                if stage.rows.is_empty() {
                    assert_eq!(stage.g.rows, 0);
                    continue;
                }
                // staged slice == serial per-class pass (+ gather_cols)
                let mut serial = SynthGrads::new(chunk, p);
                let store = per_sample_grads_with(&mut serial, &ds, &stage.rows).unwrap();
                let want = match width {
                    StageWidth::ClassSlice => store.g.gather_cols(&class_columns(h, classes, cls)),
                    StageWidth::Full => store.g,
                };
                assert_eq!(stage.g.data, want.data, "cls {cls} {width:?}");
                // staged target == serial per-class mean pass
                let mut serial_mean = SynthGrads::new(chunk, p);
                let want_t = mean_gradient_with(&mut serial_mean, &ds, &stage.rows).unwrap();
                for (a, b) in stage.target_full.iter().zip(&want_t) {
                    assert!(
                        (a - b).abs() <= 1e-4 * (1.0 + b.abs()),
                        "cls {cls} target: {a} vs {b}"
                    );
                }
            }
        }
    });
}

#[test]
fn dispatch_count_drops_to_one_ground_pass() {
    // the acceptance contract: the staged train-target path costs exactly
    // ⌈|ground|/chunk⌉ grads dispatches and ZERO mean dispatches, vs the
    // serial path's Σ_c ⌈n_c/chunk⌉ (grads) + Σ_c ⌈n_c/chunk⌉ (mean)
    forall(10, |g| {
        let classes = g.int(2, 10);
        let h = 3usize;
        let p = h * classes + classes;
        let chunk = *g.choose(&[8usize, 32]);
        let ds = imbalanced_dataset(g, classes, 6);
        if ds.len() == 0 {
            return;
        }
        let ground: Vec<usize> = (0..ds.len()).collect();

        let mut staged = SynthGrads::new(chunk, p);
        let stages =
            stage_class_grads_with(&mut staged, &ds, &ground, h, classes, StageWidth::ClassSlice, true)
                .unwrap();
        assert_eq!(staged.grad_calls, ds.len().div_ceil(chunk), "one padded ground pass");
        assert_eq!(staged.mean_calls, 0, "train targets are free — no mean pass");

        // the serial reference costs strictly more dispatches whenever
        // more than one class is populated
        let mut serial = SynthGrads::new(chunk, p);
        let mut want_grads = 0usize;
        let mut want_means = 0usize;
        for stage in &stages {
            if stage.rows.is_empty() {
                continue;
            }
            per_sample_grads_with(&mut serial, &ds, &stage.rows).unwrap();
            want_grads += stage.rows.len().div_ceil(chunk);
            mean_gradient_with(&mut serial, &ds, &stage.rows).unwrap();
            want_means += stage.rows.len().div_ceil(chunk);
        }
        assert_eq!(serial.grad_calls, want_grads);
        assert_eq!(serial.mean_calls, want_means);
        let populated = stages.iter().filter(|s| !s.rows.is_empty()).count();
        if populated > 1 {
            assert!(
                staged.grad_calls < serial.grad_calls + serial.mean_calls,
                "staged {} vs serial {}+{}",
                staged.grad_calls,
                serial.grad_calls,
                serial.mean_calls
            );
        }
    });
}

#[test]
fn class_mean_gradients_is_a_single_correct_pass() {
    // the one-pass per-class mean utility (host-side oracles; the live
    // GRAD-MATCH val path keeps fused [P]-readback means — see its docs)
    let classes = 5usize;
    let h = 2usize;
    let p = h * classes + classes;
    let chunk = 8usize;
    let mut g = Gen { rng: Rng::new(404), case: 0 };
    let val = imbalanced_dataset(&mut g, classes, 4);
    let rows: Vec<usize> = (0..val.len()).collect();
    let mut oracle = SynthGrads::new(chunk, p);
    let means = class_mean_gradients_with(&mut oracle, &val, &rows, classes).unwrap();
    assert_eq!(oracle.grad_calls, val.len().div_ceil(chunk));
    assert_eq!(oracle.mean_calls, 0);
    // per-class means agree with filtered serial means
    for cls in 0..classes {
        let class_rows: Vec<usize> =
            rows.iter().copied().filter(|&i| val.y[i] as usize == cls).collect();
        match &means[cls] {
            None => assert!(class_rows.is_empty()),
            Some(got) => {
                let mut serial = SynthGrads::new(chunk, p);
                let want = mean_gradient_with(&mut serial, &val, &class_rows).unwrap();
                for (a, b) in got.iter().zip(&want) {
                    assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "cls {cls}");
                }
            }
        }
    }
}

#[test]
fn streamed_scores_match_materialized_store() {
    // GLISTER's streaming score pass: same values as scoring the full
    // per-sample store, one padded pass, no [n, P] materialization
    forall(10, |g| {
        let classes = g.int(2, 6);
        let h = g.int(2, 5);
        let p = h * classes + classes;
        let chunk = *g.choose(&[4usize, 16, 64]);
        let ds = imbalanced_dataset(g, classes, 7);
        if ds.len() == 0 {
            return;
        }
        let ground: Vec<usize> = (0..ds.len()).collect();
        let v = g.gauss_vec(p);
        let mut stream_oracle = SynthGrads::new(chunk, p);
        let got = score_grads_with(&mut stream_oracle, &ds, &ground, &v).unwrap();
        assert_eq!(stream_oracle.grad_calls, ds.len().div_ceil(chunk), "one padded pass");
        assert_eq!(stream_oracle.mean_calls, 0);
        let mut store_oracle = SynthGrads::new(chunk, p);
        let store = per_sample_grads_with(&mut store_oracle, &ds, &ground).unwrap();
        assert_eq!(got.len(), ground.len());
        for (i, &s) in got.iter().enumerate() {
            let want = gradmatch::par::dot(store.g.row(i), &v);
            assert!((s - want).abs() <= 1e-4 * (1.0 + want.abs()), "row {i}: {s} vs {want}");
        }
    });
}

#[test]
fn fanout_solves_match_serial_solves_end_to_end() {
    // full pipeline over the synthetic oracle: stage → budgets → targets
    // → solve, serial vs fan-out, across imbalanced split_budget shapes
    forall(10, |g| {
        let classes = g.int(2, 9);
        let h = g.int(2, 5);
        let p = h * classes + classes;
        let chunk = 16usize;
        let ds = imbalanced_dataset(g, classes, 5);
        if ds.len() == 0 {
            return;
        }
        let ground: Vec<usize> = (0..ds.len()).collect();
        let mut oracle = SynthGrads::new(chunk, p);
        let stages =
            stage_class_grads_with(&mut oracle, &ds, &ground, h, classes, StageWidth::ClassSlice, true)
                .unwrap();
        let sizes: Vec<usize> = stages.iter().map(|s| s.rows.len()).collect();
        let budget = (ds.len() / 3).max(1);
        let budgets = split_budget(budget, &sizes);
        let targets: Vec<Vec<f32>> = stages
            .iter()
            .enumerate()
            .map(|(cls, s)| {
                class_columns(h, classes, cls).iter().map(|&j| s.target_full[j]).collect()
            })
            .collect();
        let serial = solve_classes_omp(&stages, &budgets, &targets, 0.5, 1e-10, false).unwrap();
        let fanout = solve_classes_omp(&stages, &budgets, &targets, 0.5, 1e-10, true).unwrap();
        assert_eq!(serial.indices, fanout.indices, "merge order must be bit-identical");
        for (a, b) in serial.weights.iter().zip(&fanout.weights) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
        // selections stay inside the ground set, no duplicates
        let mut seen = serial.indices.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), serial.indices.len());
        assert!(serial.indices.iter().all(|&i| i < ds.len()));
    });
}

#[test]
fn fanout_merge_is_in_class_order() {
    // stages with disjoint, class-contiguous row ranges: the merged
    // selection's rows must be non-decreasing in class
    let mut g = Gen { rng: Rng::new(777), case: 0 };
    let width = 6usize;
    let mut next = 0usize;
    let stages: Vec<ClassStage> = (0..6)
        .map(|_| {
            let n_c = g.int(3, 20);
            let rows: Vec<usize> = (next..next + n_c).collect();
            next += n_c;
            ClassStage { g: g.matrix(n_c, width), rows, target_full: g.gauss_vec(width) }
        })
        .collect();
    let sizes: Vec<usize> = stages.iter().map(|s| s.rows.len()).collect();
    let budgets = split_budget(next / 2, &sizes);
    let targets: Vec<Vec<f32>> = stages.iter().map(|s| s.target_full.clone()).collect();
    let sel = solve_classes_omp(&stages, &budgets, &targets, 0.5, 1e-12, true).unwrap();
    assert!(!sel.indices.is_empty());
    // row ranges are class-contiguous, so class order == range order
    let class_of = |row: usize| stages.iter().position(|s| s.rows.contains(&row)).unwrap();
    let classes_seen: Vec<usize> = sel.indices.iter().map(|&r| class_of(r)).collect();
    for w in classes_seen.windows(2) {
        assert!(w[0] <= w[1], "merge must walk classes in order: {classes_seen:?}");
    }
}

#[test]
fn nan_scores_never_panic_or_win_the_ranking() {
    // regression for the Glister/Entropy/Forgetting footgun: the old
    // sort_by(partial_cmp().unwrap()) ranking aborted on any NaN score
    let scores = vec![0.5, f32::NAN, 2.0, -1.0, f32::NAN, 1.5];
    let top = top_k_desc(&scores, 3);
    assert_eq!(top, vec![2, 5, 0]);
    assert!(top.iter().all(|&j| !scores[j].is_nan()));
    // ranking degrades gracefully when NaNs outnumber the budget shortfall
    let top_all = top_k_desc(&scores, scores.len());
    assert_eq!(top_all.len(), scores.len());
    assert_eq!(&top_all[..4], &[2, 5, 0, 3], "finite scores rank first");
}
