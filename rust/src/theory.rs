//! Empirical verification of the paper's theory (Section 2/3 + Appendix B).
//!
//! The convergence claims (Theorems 1–3) and the weak-submodularity bound
//! (Theorem 2 on `F_λ`) are stated for convex losses, so they can be
//! *checked numerically* on small convex problems where everything —
//! optimal loss, Lipschitz constants, the `Err` terms — is computable
//! exactly.  This module implements:
//!
//! - a pure-Rust **L2-regularized logistic regression** substrate (strongly
//!   convex ⇒ unique θ*, computable σ_T and μ),
//! - an **adaptive-selection gradient-descent runner** that trains on a
//!   weighted subset re-selected every R steps while recording the exact
//!   `Err(w^t, X^t, L, L_T, θ_t)` sequence,
//! - the **Theorem-1 bound evaluators** (cases 1 and 3),
//! - a **γ-weak-submodularity estimator** for `F_λ(X) = L_max − E_λ(X)`
//!   that empirically tests `F(j|S) ≥ γ·F(j|T)` for nested `S ⊆ T` and
//!   compares with the Theorem-2 lower bound `λ/(λ + k·∇²_max)`,
//! - a **Johnson–Lindenstrauss distortion evaluator** pinning the sketched
//!   correlation path (`crate::sketch`): empirical pairwise-distance
//!   distortion of the seeded projection vs the `(1 ± ε)` bound at the
//!   prescribed width `k = ⌈8·ln(n)/ε²⌉`.
//!
//! The property tests in this module are the reproduction of the paper's
//! theoretical contribution; `rust/benches` covers the empirical one.

use crate::linalg::ridge_weights;
use crate::rng::Rng;
use crate::tensor::{axpy, dot, norm2, Matrix};

/// Binary logistic regression with L2 regularization:
/// `L_T(θ) = (1/n) Σ log(1 + exp(−y_i x_iᵀθ)) + (μ/2)‖θ‖²`, y ∈ {−1, +1}.
#[derive(Clone, Debug)]
pub struct Logistic {
    pub x: Matrix,
    /// labels in {−1.0, +1.0}
    pub y: Vec<f32>,
    /// strong-convexity parameter (L2 coefficient)
    pub mu: f32,
}

impl Logistic {
    /// Random linearly-separable-ish instance.
    pub fn random(n: usize, d: usize, rng: &mut Rng, mu: f32) -> Logistic {
        let teacher: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
        let mut x = Matrix::zeros(n, d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let row = x.row_mut(i);
            for v in row.iter_mut() {
                *v = rng.gaussian_f32();
            }
            let margin = dot(row, &teacher) + 0.3 * rng.gaussian_f32();
            y.push(if margin >= 0.0 { 1.0 } else { -1.0 });
        }
        Logistic { x, y, mu }
    }

    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn d(&self) -> usize {
        self.x.cols
    }

    /// Per-sample loss ℓ_i(θ) = log(1 + exp(−y_i x_iᵀθ)) (no regularizer).
    pub fn sample_loss(&self, theta: &[f32], i: usize) -> f64 {
        let m = (self.y[i] * dot(self.x.row(i), theta)) as f64;
        // numerically stable log(1 + exp(-m))
        if m > 0.0 {
            (-m).exp().ln_1p()
        } else {
            -m + m.exp().ln_1p()
        }
    }

    /// Full loss L_T(θ).
    pub fn loss(&self, theta: &[f32]) -> f64 {
        let data: f64 = (0..self.n()).map(|i| self.sample_loss(theta, i)).sum::<f64>()
            / self.n() as f64;
        data + 0.5 * self.mu as f64 * dot(theta, theta) as f64
    }

    /// Per-sample gradient ∇ℓ_i(θ) (no regularizer) — row in the gradient
    /// ground set the selection matches.
    pub fn sample_grad(&self, theta: &[f32], i: usize) -> Vec<f32> {
        let m = self.y[i] * dot(self.x.row(i), theta);
        let s = sigmoid(-m); // = 1 − σ(m)
        let coef = -self.y[i] * s;
        self.x.row(i).iter().map(|&v| coef * v).collect()
    }

    /// Full gradient ∇L_T(θ) (with regularizer).
    pub fn grad(&self, theta: &[f32]) -> Vec<f32> {
        let mut g = vec![0.0f32; self.d()];
        for i in 0..self.n() {
            axpy(1.0 / self.n() as f32, &self.sample_grad(theta, i), &mut g);
        }
        axpy(self.mu, theta, &mut g);
        g
    }

    /// Solve to near-optimality with plain GD (convex ⇒ global optimum).
    pub fn solve(&self, steps: usize, lr: f32) -> Vec<f32> {
        let mut theta = vec![0.0f32; self.d()];
        for _ in 0..steps {
            let g = self.grad(&theta);
            axpy(-lr, &g, &mut theta);
        }
        theta
    }

    /// Upper bound σ_T on per-sample gradient norms over observed iterates
    /// (Lipschitz-continuity constant of the data term).
    pub fn sigma_bound(&self, theta: &[f32]) -> f32 {
        (0..self.n())
            .map(|i| norm2(&self.sample_grad(theta, i)))
            .fold(0.0f32, f32::max)
    }
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// One run of adaptive-selection gradient descent (the Theorem-1 regime:
/// full GD on the weighted subset, re-selected every `r` steps with OMP).
#[derive(Clone, Debug)]
pub struct AdaptiveRun {
    /// loss L(θ_t) per step
    pub losses: Vec<f64>,
    /// exact Err(w^t, X^t, L, L_T, θ_t) per step
    pub errs: Vec<f64>,
    /// min_t L(θ_t)
    pub best_loss: f64,
}

/// Options for [`adaptive_gd`].
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveOpts {
    pub steps: usize,
    pub r: usize,
    pub k: usize,
    pub lambda: f32,
    pub lr: f32,
}

/// Run adaptive data selection + GD on a logistic problem, recording the
/// exact gradient-matching error sequence of Theorem 1.
pub fn adaptive_gd(problem: &Logistic, opts: &AdaptiveOpts) -> AdaptiveRun {
    let n = problem.n();
    let mut theta = vec![0.0f32; problem.d()];
    let mut losses = Vec::with_capacity(opts.steps);
    let mut errs = Vec::with_capacity(opts.steps);
    let mut subset: Vec<usize> = (0..opts.k.min(n)).collect();
    let mut weights = vec![n as f32 / opts.k as f32; subset.len()];

    for t in 0..opts.steps {
        if t % opts.r == 0 {
            // per-sample gradient ground set at the current θ
            let mut g = Matrix::zeros(n, problem.d());
            for i in 0..n {
                g.row_mut(i).copy_from_slice(&problem.sample_grad(&theta, i));
            }
            // target: SUM of gradients (matches the paper's Err definition
            // over the unnormalized training loss)
            let mut target = vec![0.0f32; problem.d()];
            for i in 0..n {
                axpy(1.0, g.row(i), &mut target);
            }
            let res = crate::omp::omp_select_rust(
                &g,
                &target,
                crate::omp::OmpOpts { k: opts.k, lambda: opts.lambda, eps: 1e-12 },
            )
            .expect("omp");
            if !res.selected.is_empty() {
                subset = res.selected;
                weights = res.weights;
            }
        }

        losses.push(problem.loss(&theta));

        // weighted subset gradient (normalized to the mean-loss scale) +
        // regularizer, exactly the update Algorithm 1 line 9 performs
        let mut gw = vec![0.0f32; problem.d()];
        for (slot, &i) in subset.iter().enumerate() {
            axpy(weights[slot] / n as f32, &problem.sample_grad(&theta, i), &mut gw);
        }
        axpy(problem.mu, &theta, &mut gw);

        // exact Err term (mean-loss scale)
        let full = problem.grad(&theta);
        let diff = crate::tensor::sub(&gw, &full);
        errs.push(norm2(&diff) as f64);

        axpy(-opts.lr, &gw, &mut theta);
    }
    let best_loss = losses.iter().cloned().fold(f64::INFINITY, f64::min);
    AdaptiveRun { losses, errs, best_loss }
}

/// Theorem 1 case (3) bound (strongly convex):
/// `2σ_T²/(μ(T+1)) + Σ_t 2Dt/(T(T+1)) · Err_t`.
pub fn theorem1_strongly_convex_bound(
    sigma: f64,
    mu: f64,
    d_bound: f64,
    errs: &[f64],
) -> f64 {
    let t_total = errs.len() as f64;
    let mut err_term = 0.0;
    for (t, e) in errs.iter().enumerate() {
        err_term += 2.0 * d_bound * (t as f64 + 1.0) / (t_total * (t_total + 1.0)) * e;
    }
    2.0 * sigma * sigma / (mu * (t_total + 1.0)) + err_term
}

/// Theorem 1 case (1) bound (Lipschitz-continuous, convex):
/// `Dσ_T/√T + (D/T)·Σ_t Err_t`.
pub fn theorem1_lipschitz_bound(sigma: f64, d_bound: f64, errs: &[f64]) -> f64 {
    let t_total = errs.len() as f64;
    let err_sum: f64 = errs.iter().sum();
    d_bound * sigma / t_total.sqrt() + d_bound / t_total * err_sum
}

// ---------------------------------------------------------------------------
// weak submodularity of F_λ (Theorem 2)
// ---------------------------------------------------------------------------

/// `E_λ(X) = min_w ‖ G_Xᵀ w − target ‖² + λ‖w‖²` (squared-error form used
/// in the weak-submodularity analysis).
pub fn e_lambda(g: &Matrix, subset: &[usize], target: &[f32], lambda: f32) -> f64 {
    if subset.is_empty() {
        return dot(target, target) as f64;
    }
    let sub = g.gather_rows(subset);
    let w = match ridge_weights(&sub, target, lambda) {
        Ok(w) => w,
        Err(_) => return dot(target, target) as f64,
    };
    let r = crate::linalg::residual(&sub, &w, target);
    (dot(&r, &r) + lambda * dot(&w, &w)) as f64
}

/// `F_λ(X) = L_max − E_λ(X)` with `L_max = E_λ(∅) = ‖target‖²`.
pub fn f_lambda(g: &Matrix, subset: &[usize], target: &[f32], lambda: f32) -> f64 {
    dot(target, target) as f64 - e_lambda(g, subset, target, lambda)
}

/// Empirical submodularity ratio (Das & Kempe / Elenberg): the minimum
/// over sampled disjoint pairs `(S, T)` of
/// `Σ_{j∈T} F(j|S)  /  (F(S∪T) − F(S))` — the quantity the RSC argument
/// of Theorem 2 actually lower-bounds by `m/M = λ/(λ + k·∇²_max)`.
/// Pairs whose joint gain sits below the f32 noise floor are skipped.
pub fn estimate_gamma(
    g: &Matrix,
    target: &[f32],
    lambda: f32,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let n = g.rows;
    let mut gamma: f64 = 1.0;
    for _ in 0..trials {
        // disjoint S, T sampled together
        let total = 2 + rng.usize((n - 1).max(1));
        let pool = rng.sample_indices(n, total.min(n));
        let s_size = rng.usize(pool.len() - 1);
        let s_set: Vec<usize> = pool[..s_size].to_vec();
        let t_set: Vec<usize> = pool[s_size..].to_vec();
        if t_set.is_empty() {
            continue;
        }
        let f_s = f_lambda(g, &s_set, target, lambda);
        let mut union = s_set.clone();
        union.extend_from_slice(&t_set);
        let joint_gain = f_lambda(g, &union, target, lambda) - f_s;
        if joint_gain <= 1e-3 {
            continue; // below the f32 noise floor — no information
        }
        let mut single_sum = 0.0f64;
        for &j in &t_set {
            let mut s_j = s_set.clone();
            s_j.push(j);
            single_sum += (f_lambda(g, &s_j, target, lambda) - f_s).max(0.0);
        }
        gamma = gamma.min((single_sum / joint_gain).clamp(0.0, 1.0));
    }
    gamma
}

/// Theorem 2's lower bound on γ: `λ / (λ + k·∇²_max)`.
pub fn gamma_lower_bound(g: &Matrix, k: usize, lambda: f32) -> f64 {
    let max_norm2 = (0..g.rows)
        .map(|i| dot(g.row(i), g.row(i)))
        .fold(0.0f32, f32::max) as f64;
    lambda as f64 / (lambda as f64 + k as f64 * max_norm2)
}

// ---------------------------------------------------------------------------
// Johnson–Lindenstrauss distortion (the sketch subsystem's correctness pin)
// ---------------------------------------------------------------------------

/// Empirical max pairwise-distance distortion of the seeded projection the
/// sketched selection path uses ([`crate::sketch::Sketcher`]) on the rows
/// of `g`, at sketch width `width`: `max |‖Sx−Sy‖²/‖x−y‖² − 1|` over at
/// most `max_pairs` deterministically-strided row pairs.
pub fn jl_max_distortion(
    g: &Matrix,
    width: usize,
    seed: u64,
    salt: u64,
    max_pairs: usize,
) -> f64 {
    let sk = crate::sketch::Sketcher::new(width, seed, salt);
    let cols: Vec<usize> = (0..g.cols).collect();
    crate::sketch::pairwise_distortion(g, &sk.sketch_matrix(g, &cols), max_pairs)
}

/// Evaluate the `(1 ± ε)` JL guarantee at the width
/// [`crate::sketch::jl_width_for`] prescribes for `g.rows` points.
/// Returns `(width, distortion)`; the guarantee holds when
/// `distortion <= eps` — with high probability over `(seed, salt)`, which
/// is exactly what the lemma promises.
pub fn jl_bound_check(
    g: &Matrix,
    eps: f64,
    seed: u64,
    salt: u64,
    max_pairs: usize,
) -> (usize, f64) {
    let width = crate::sketch::jl_width_for(g.rows, eps);
    (width, jl_max_distortion(g, width, seed, salt, max_pairs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::forall;

    fn problem(seed: u64, n: usize, d: usize) -> Logistic {
        let mut rng = Rng::new(seed);
        Logistic::random(n, d, &mut rng, 0.1)
    }

    #[test]
    fn logistic_gradient_matches_finite_differences() {
        let p = problem(1, 30, 6);
        let mut rng = Rng::new(2);
        let theta: Vec<f32> = (0..6).map(|_| 0.5 * rng.gaussian_f32()).collect();
        let g = p.grad(&theta);
        let eps = 1e-3f32;
        for j in 0..6 {
            let mut tp = theta.clone();
            tp[j] += eps;
            let mut tm = theta.clone();
            tm[j] -= eps;
            let fd = (p.loss(&tp) - p.loss(&tm)) / (2.0 * eps as f64);
            assert!(
                (fd - g[j] as f64).abs() < 2e-3,
                "coord {j}: fd {fd} vs analytic {}",
                g[j]
            );
        }
    }

    #[test]
    fn gd_converges_to_low_gradient_norm() {
        let p = problem(3, 60, 8);
        let theta = p.solve(3000, 0.5);
        assert!(norm2(&p.grad(&theta)) < 1e-3);
    }

    #[test]
    fn full_budget_adaptive_run_has_small_err_and_descends() {
        // with budget = n, OMP finds a (sparse) exact fit at each selection
        // point; the Err terms between re-selections come only from θ
        // drifting — they stay small and the loss descends
        let p = problem(4, 40, 6);
        let opts = AdaptiveOpts { steps: 100, r: 10, k: 40, lambda: 1e-4, lr: 0.5 };
        let run = adaptive_gd(&p, &opts);
        let max_err = run.errs.iter().cloned().fold(0.0, f64::max);
        assert!(max_err < 0.15, "max err {max_err}");
        // err is ~0 right after each re-selection
        assert!(run.errs[0] < 1e-3, "post-selection err {}", run.errs[0]);
        assert!(run.losses.last().unwrap() < &run.losses[0]);
    }

    #[test]
    fn theorem1_strongly_convex_bound_holds() {
        // the paper's headline guarantee: min_t L(θ_t) − L(θ*) is bounded
        // by the optimization term + the gradient-matching error term
        let p = problem(5, 60, 8);
        let theta_star = p.solve(4000, 0.5);
        let l_star = p.loss(&theta_star);
        for k in [6usize, 15, 30] {
            let opts = AdaptiveOpts { steps: 120, r: 10, k, lambda: 0.1, lr: 0.2 };
            let run = adaptive_gd(&p, &opts);
            let sigma = p.sigma_bound(&theta_star).max(p.sigma_bound(&vec![0.0; 8])) as f64 + 1.0;
            let d_bound = 2.0 * (norm2(&theta_star) as f64 + 1.0);
            let bound = theorem1_strongly_convex_bound(sigma, p.mu as f64, d_bound, &run.errs);
            let gap = run.best_loss - l_star;
            assert!(
                gap <= bound + 1e-6,
                "k={k}: gap {gap} exceeds Theorem-1 bound {bound}"
            );
            assert!(gap >= -1e-6, "optimum is optimal");
        }
    }

    #[test]
    fn theorem1_lipschitz_bound_holds() {
        let p = problem(6, 50, 6);
        let theta_star = p.solve(4000, 0.5);
        let l_star = p.loss(&theta_star);
        let opts = AdaptiveOpts { steps: 100, r: 5, k: 12, lambda: 0.1, lr: 0.2 };
        let run = adaptive_gd(&p, &opts);
        let sigma = p.sigma_bound(&theta_star) as f64 + 1.0;
        let d_bound = 2.0 * (norm2(&theta_star) as f64 + 1.0);
        let bound = theorem1_lipschitz_bound(sigma, d_bound, &run.errs);
        assert!(run.best_loss - l_star <= bound + 1e-6);
    }

    #[test]
    fn larger_budget_gives_smaller_err_terms() {
        let p = problem(7, 60, 8);
        let mut means = Vec::new();
        for k in [5usize, 20, 60] {
            let opts = AdaptiveOpts { steps: 40, r: 5, k, lambda: 0.1, lr: 0.2 };
            let run = adaptive_gd(&p, &opts);
            means.push(run.errs.iter().sum::<f64>() / run.errs.len() as f64);
        }
        assert!(means[2] <= means[0] + 1e-9, "{means:?}");
    }

    #[test]
    fn e_lambda_monotone_nonincreasing_in_subset() {
        // adding elements can only improve the best fit (E_λ decreases)
        forall(20, |gen| {
            let n = gen.int(4, 16);
            let d = gen.int(2, 6);
            let g = gen.matrix(n, d);
            let target = gen.gauss_vec(d);
            let k1 = gen.int(1, n / 2 + 1);
            let s1 = gen.subset(n, k1);
            let mut s2 = s1.clone();
            for j in 0..n {
                if !s2.contains(&j) {
                    s2.push(j);
                    break;
                }
            }
            let e1 = e_lambda(&g, &s1, &target, 0.5);
            let e2 = e_lambda(&g, &s2, &target, 0.5);
            assert!(e2 <= e1 + 1e-4, "E_λ must not grow: {e1} -> {e2}");
        });
    }

    #[test]
    fn f_lambda_nonnegative_and_zero_on_empty() {
        forall(20, |gen| {
            let n = gen.int(3, 12);
            let d = gen.int(2, 5);
            let g = gen.matrix(n, d);
            let target = gen.gauss_vec(d);
            assert_eq!(f_lambda(&g, &[], &target, 0.5), 0.0);
            let ks = gen.int(1, n);
            let s = gen.subset(n, ks);
            assert!(f_lambda(&g, &s, &target, 0.5) >= -1e-4);
        });
    }

    #[test]
    fn empirical_gamma_respects_theorem2_lower_bound() {
        // Theorem 2: γ ≥ λ/(λ + k·∇²max).  The empirical γ over sampled
        // nested pairs must sit above that bound.
        let mut rng = Rng::new(11);
        for trial in 0..5 {
            let n = 10;
            let d = 6;
            let mut grng = Rng::new(100 + trial);
            let g = Matrix::from_vec(n, d, (0..n * d).map(|_| grng.gaussian_f32()).collect());
            let target: Vec<f32> = (0..d).map(|_| grng.gaussian_f32()).collect();
            // λ large enough that the gains sit well above f32 noise and
            // the Theorem-2 bound is non-vacuous
            let lambda = 5.0f32;
            let gamma = estimate_gamma(&g, &target, lambda, 200, &mut rng);
            let lb = gamma_lower_bound(&g, n, lambda);
            assert!(
                gamma >= lb - 1e-3,
                "trial {trial}: empirical γ {gamma} below Theorem-2 bound {lb}"
            );
            assert!(gamma <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn gamma_bound_increases_with_lambda() {
        let mut rng = Rng::new(12);
        let g = Matrix::from_vec(8, 4, (0..32).map(|_| rng.gaussian_f32()).collect());
        let lb_small = gamma_lower_bound(&g, 8, 0.01);
        let lb_big = gamma_lower_bound(&g, 8, 10.0);
        assert!(lb_big > lb_small);
        assert!(lb_small > 0.0 && lb_big < 1.0);
    }

    /// Per-sample gradient ground set of a logistic problem at θ = 0 —
    /// the same kind of `[n, P]` matrix the sketched selection path
    /// projects, so the JL pin runs on the actual object of interest.
    fn gradient_ground_set(seed: u64, n: usize, d: usize) -> Matrix {
        let p = problem(seed, n, d);
        let theta = vec![0.0f32; d];
        let mut g = Matrix::zeros(n, d);
        for i in 0..n {
            g.row_mut(i).copy_from_slice(&p.sample_grad(&theta, i));
        }
        g
    }

    #[test]
    fn jl_distortion_respects_epsilon_at_prescribed_width() {
        // The JL lemma is a with-high-probability statement over the
        // projection draw, so the pin mirrors it: at the prescribed
        // k = ⌈8·ln(n)/ε²⌉, a majority of independent salts must land
        // within ε, and every one of them within the coarse 2ε ceiling.
        let g = gradient_ground_set(21, 64, 256);
        let eps = 0.5;
        let mut width_seen = 0;
        let mut hits = 0;
        for salt in 0..3u64 {
            let (width, dist) = jl_bound_check(&g, eps, 1234, salt, 64);
            width_seen = width;
            if dist <= eps {
                hits += 1;
            }
            assert!(
                dist <= 2.0 * eps,
                "salt {salt}: distortion {dist} far outside the (1±ε) bound at k={width}"
            );
        }
        assert!(
            width_seen > 8 && width_seen < g.cols,
            "prescribed width {width_seen} should be a real reduction of P={}",
            g.cols
        );
        assert!(
            hits >= 2,
            "JL (1±ε) bound must hold w.h.p. at prescribed width {width_seen}: {hits}/3 salts within ε={eps}"
        );
    }

    #[test]
    fn jl_distortion_is_deterministic_and_decays_with_width() {
        let g = gradient_ground_set(22, 48, 192);
        let a = jl_max_distortion(&g, 96, 77, 5, 300);
        let b = jl_max_distortion(&g, 96, 77, 5, 300);
        assert_eq!(a, b, "fixed (seed, salt) must reproduce the distortion exactly");
        let narrow = jl_max_distortion(&g, 12, 77, 5, 300);
        assert!(
            a < narrow,
            "k=96 must distort less than k=12: {a} vs {narrow}"
        );
        assert!(narrow > 0.0, "a 12-wide sketch of 192 dims cannot be exact");
    }
}
