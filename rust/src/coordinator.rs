//! Experiment coordinator: config → dataset → strategy → trainer, plus the
//! multi-run / sweep drivers behind the CLI, the examples, and every bench.
//!
//! A [`Coordinator`] owns one PJRT runtime (compiled executables are cached
//! across runs) and a cache of full-training baselines so speedups and
//! relative errors are computed against the *same* skyline the paper uses
//! (FULL for accuracy, RANDOM/FULL time for efficiency).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::config::ExperimentConfig;
use crate::data::{imbalance_indices, DatasetCard, Splits};
use crate::engine::{
    scope_fingerprint, Degradation, SelectionCache, SelectionEngine, SelectionReport,
    SelectionRequest,
};
use crate::jsonlite::{arr, num, obj, s, Json};
use crate::metrics::Phase;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::selection::parse_strategy;
use crate::stats;
use crate::trainer::{TrainOpts, TrainOutcome};

/// Summary of one (strategy × budget × seed) run.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub dataset: String,
    pub model: String,
    pub strategy: String,
    pub budget_frac: f64,
    pub seed: u64,
    pub test_acc: f64,
    pub train_secs: f64,
    pub select_secs: f64,
    pub total_secs: f64,
    pub energy_kwh: f64,
    pub selections: usize,
    pub steps: usize,
    pub mean_grad_error: Option<f64>,
    /// engine observability: total seconds the applied rounds spent
    /// staging gradients vs solving (SelectionReport aggregates)
    pub select_stage_secs: f64,
    pub select_solve_secs: f64,
    /// padded runtime dispatches the staging passes issued across rounds
    pub stage_dispatches: usize,
    /// rounds whose staged gradients came from the engine's shared cache
    pub stage_shared_rounds: usize,
    /// rounds served by an already-used engine (`engine_round > 0`) — with
    /// the one-engine-per-run contract this is at least `selections - 1`
    /// (exactly, when every due round produced a non-empty selection;
    /// empty rounds advance the engine without being recorded here)
    pub engine_reused_rounds: usize,
    /// rounds whose staging pass recycled a previous round's buffers
    pub stage_buffer_reuses: usize,
    /// chunk dispatches retried under the round retry policy across all
    /// applied rounds (0 on a fault-free run)
    pub select_retries: usize,
    /// non-finite gradient rows quarantined by staging across all rounds
    pub quarantined_rows: usize,
    /// rounds answered through the degradation ladder (reused subset or
    /// random fallback) instead of a completed solve
    pub degraded_rounds: usize,
    /// rounds an overlapped run executed synchronously (worker death or
    /// staleness rejection)
    pub sync_fallback_rounds: usize,
    /// overlapped subsets rejected by the staleness probe
    pub stale_rejections: usize,
    /// rounds that ran the two-level sharded OMP path (shards > 1)
    pub sharded_rounds: usize,
    /// most gradient rows any round held staged simultaneously (the
    /// `max_staged_rows` memory-budget check)
    pub peak_staged_rows: usize,
    /// shard winners re-staged by merge rounds, summed across rounds
    pub merge_candidates: usize,
    /// rounds that solved against a JL-sketched problem (sketch_width > 0)
    pub sketched_rounds: usize,
    /// seconds sketched rounds spent projecting staged gradients, summed
    pub sketch_secs: f64,
    /// seconds sketched rounds spent on full-width weight re-fits, summed
    pub refit_secs: f64,
    /// rounds replayed from the cross-arm `SelectionCache` — each cost
    /// zero staging dispatches and built no engine
    pub cache_hit_rounds: usize,
    /// rounds whose solved selection was memoized for later arms
    pub cache_store_rounds: usize,
    /// wall-clock seconds the hits saved (the original solves' recorded
    /// stage+solve cost, summed)
    pub cache_hit_secs_saved: f64,
    /// fraction of training rows never selected (Table 10)
    pub redundant_frac: f64,
    /// (epoch, cum_secs, test_acc) convergence points (Fig. 3j/k)
    pub convergence: Vec<(usize, f64, f64)>,
}

impl RunSummary {
    fn from_outcome(cfg_like: &RunKey, seed: u64, o: &TrainOutcome) -> RunSummary {
        let never = o.ever_selected.iter().filter(|&&b| !b).count();
        let conv = o
            .history
            .iter()
            .filter_map(|h| h.test_acc.map(|a| (h.epoch, h.cum_secs, a as f64)))
            .collect();
        RunSummary {
            dataset: cfg_like.dataset.clone(),
            model: cfg_like.model.clone(),
            strategy: cfg_like.strategy.clone(),
            budget_frac: cfg_like.budget_frac,
            seed,
            test_acc: o.final_test_acc as f64,
            train_secs: o.clock.secs(Phase::Train),
            select_secs: o.clock.secs(Phase::Select),
            total_secs: o.clock.secs(Phase::Train) + o.clock.secs(Phase::Select),
            energy_kwh: o.energy_kwh,
            selections: o.selections,
            steps: o.steps,
            mean_grad_error: if o.grad_errors.is_empty() {
                None
            } else {
                Some(o.grad_errors.iter().map(|&e| e as f64).sum::<f64>() / o.grad_errors.len() as f64)
            },
            select_stage_secs: o.round_stats.iter().map(|r| r.stage_secs).sum(),
            select_solve_secs: o.round_stats.iter().map(|r| r.solve_secs).sum(),
            stage_dispatches: o.round_stats.iter().map(|r| r.stage_dispatches).sum(),
            stage_shared_rounds: o.round_stats.iter().filter(|r| r.stage_shared).count(),
            engine_reused_rounds: o.round_stats.iter().filter(|r| r.engine_round > 0).count(),
            stage_buffer_reuses: o.round_stats.iter().filter(|r| r.stage_reused_buffers).count(),
            select_retries: o.round_stats.iter().map(|r| r.retries).sum(),
            quarantined_rows: o.round_stats.iter().map(|r| r.quarantined).sum(),
            degraded_rounds: o
                .round_stats
                .iter()
                .filter(|r| r.degradation != Degradation::None)
                .count(),
            sync_fallback_rounds: o.sync_fallback_rounds,
            stale_rejections: o.stale_rejections,
            sharded_rounds: o.round_stats.iter().filter(|r| r.shards > 1).count(),
            peak_staged_rows: o.round_stats.iter().map(|r| r.peak_staged_rows).max().unwrap_or(0),
            merge_candidates: o.round_stats.iter().map(|r| r.merge_candidates).sum(),
            sketched_rounds: o.round_stats.iter().filter(|r| r.sketch_width > 0).count(),
            sketch_secs: o.round_stats.iter().map(|r| r.sketch_secs).sum(),
            refit_secs: o.round_stats.iter().map(|r| r.refit_secs).sum(),
            cache_hit_rounds: o.round_stats.iter().filter(|r| r.cache_hit).count(),
            cache_store_rounds: o.round_stats.iter().filter(|r| r.cache_stored).count(),
            cache_hit_secs_saved: o.round_stats.iter().map(|r| r.cache_saved_secs).sum(),
            redundant_frac: never as f64 / o.ever_selected.len().max(1) as f64,
            convergence: conv,
        }
    }

    /// Serialize for the results directory.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dataset", s(&self.dataset)),
            ("model", s(&self.model)),
            ("strategy", s(&self.strategy)),
            ("budget_frac", num(self.budget_frac)),
            ("seed", num(self.seed as f64)),
            ("test_acc", num(self.test_acc)),
            ("train_secs", num(self.train_secs)),
            ("select_secs", num(self.select_secs)),
            ("total_secs", num(self.total_secs)),
            ("energy_kwh_simulated", num(self.energy_kwh)),
            ("selections", num(self.selections as f64)),
            ("steps", num(self.steps as f64)),
            ("redundant_frac", num(self.redundant_frac)),
            (
                "mean_grad_error",
                self.mean_grad_error.map(num).unwrap_or(Json::Null),
            ),
            ("select_stage_secs", num(self.select_stage_secs)),
            ("select_solve_secs", num(self.select_solve_secs)),
            ("stage_dispatches", num(self.stage_dispatches as f64)),
            ("stage_shared_rounds", num(self.stage_shared_rounds as f64)),
            ("engine_reused_rounds", num(self.engine_reused_rounds as f64)),
            ("stage_buffer_reuses", num(self.stage_buffer_reuses as f64)),
            ("select_retries", num(self.select_retries as f64)),
            ("quarantined_rows", num(self.quarantined_rows as f64)),
            ("degraded_rounds", num(self.degraded_rounds as f64)),
            ("sync_fallback_rounds", num(self.sync_fallback_rounds as f64)),
            ("stale_rejections", num(self.stale_rejections as f64)),
            ("sharded_rounds", num(self.sharded_rounds as f64)),
            ("peak_staged_rows", num(self.peak_staged_rows as f64)),
            ("merge_candidates", num(self.merge_candidates as f64)),
            ("sketched_rounds", num(self.sketched_rounds as f64)),
            ("sketch_secs", num(self.sketch_secs)),
            ("refit_secs", num(self.refit_secs)),
            ("cache_hit_rounds", num(self.cache_hit_rounds as f64)),
            ("cache_store_rounds", num(self.cache_store_rounds as f64)),
            ("cache_hit_secs_saved", num(self.cache_hit_secs_saved)),
            (
                "convergence",
                arr(self
                    .convergence
                    .iter()
                    .map(|&(e, t, a)| arr(vec![num(e as f64), num(t), num(a)]))
                    .collect()),
            ),
        ])
    }
}

#[derive(Clone, Debug)]
struct RunKey {
    dataset: String,
    model: String,
    strategy: String,
    budget_frac: f64,
}

/// How many solved rounds the coordinator's cross-arm [`SelectionCache`]
/// retains (LRU past this).  A sweep arm re-selects every `R` epochs, so
/// this covers hundreds of arms' worth of round signatures.
const SELECTION_CACHE_ROUNDS: usize = 512;

/// Fingerprint of every config field that shapes a *full-training*
/// baseline run: the dataset/model pair, the epoch budget, and the
/// split/optimizer/imbalance knobs (`n_train`, `lr0`, `eval_every`,
/// `is_valid`, imbalance fractions, `label_noise`, the data seed) plus
/// the run seed.  [`Coordinator::full_baseline`] keys its skyline cache
/// on this — the old `(dataset, model, epochs, seed)` tuple silently
/// served a stale skyline to sweeps varying any of the other knobs.
pub fn baseline_fingerprint(cfg: &ExperimentConfig, seed: u64) -> u64 {
    scope_fingerprint(
        &format!("{}|{}", cfg.dataset, cfg.model),
        &[
            cfg.epochs as u64,
            cfg.n_train as u64,
            cfg.lr0.to_bits(),
            cfg.eval_every as u64,
            cfg.is_valid as u64,
            cfg.imbalance_frac.to_bits(),
            cfg.imbalance_keep.to_bits(),
            cfg.label_noise.to_bits(),
            cfg.seed,
            seed,
        ],
    )
}

/// The dataset-scope half of a cross-arm cache key: everything that pins
/// the *rows a ground index refers to* — the card, the split seed and
/// size override, label noise, and the imbalance transform.  Two arms
/// sharing this scope (and a round signature) see identical data, so
/// replaying a subset between them is sound.
fn dataset_scope(cfg: &ExperimentConfig) -> u64 {
    scope_fingerprint(
        &cfg.dataset,
        &[
            cfg.seed,
            cfg.n_train as u64,
            cfg.label_noise.to_bits(),
            cfg.is_valid as u64,
            cfg.imbalance_frac.to_bits(),
            cfg.imbalance_keep.to_bits(),
        ],
    )
}

/// Orchestrates runs over one shared runtime.
pub struct Coordinator {
    pub rt: Runtime,
    /// dataset cache keyed by (card, seed, n_override)
    splits: HashMap<(String, u64, usize), Splits>,
    /// full-training baselines keyed by [`baseline_fingerprint`]
    full_cache: HashMap<u64, RunSummary>,
    /// full-training runs actually executed (cache misses) — lets tests
    /// pin that a sweep computes its skyline exactly once
    baseline_solves: usize,
    /// cross-arm selection memoization, built lazily the first time a
    /// run with `reuse_across_arms` executes (coordinator-lifetime, so
    /// `sweep` and `run_multi` arms share it)
    sel_cache: Option<SelectionCache>,
}

impl Coordinator {
    pub fn new(artifacts_dir: &str) -> Result<Coordinator> {
        Ok(Coordinator {
            rt: Runtime::load(artifacts_dir)?,
            splits: HashMap::new(),
            full_cache: HashMap::new(),
            baseline_solves: 0,
            sel_cache: None,
        })
    }

    /// Full-training baseline runs actually executed so far (skyline
    /// cache misses).
    pub fn baseline_solves(&self) -> usize {
        self.baseline_solves
    }

    /// `(depth, hits, stores, evictions)` of the cross-arm selection
    /// cache; zeros when no reuse-enabled run has executed yet.
    pub fn selection_cache_stats(&self) -> (usize, u64, u64, u64) {
        self.sel_cache.as_ref().map(|c| c.stats()).unwrap_or((0, 0, 0, 0))
    }

    /// Generate (or fetch cached) splits for a dataset card.
    pub fn splits(&mut self, dataset: &str, seed: u64, n_override: usize) -> Result<&Splits> {
        let key = (dataset.to_string(), seed, n_override);
        if !self.splits.contains_key(&key) {
            let card = DatasetCard::by_name(dataset)
                .ok_or_else(|| anyhow!("unknown dataset card '{dataset}'"))?;
            self.splits.insert(key.clone(), card.generate(seed, n_override));
        }
        Ok(self.splits.get(&key).unwrap())
    }

    /// Run one experiment configuration for one seed.
    pub fn run_one(&mut self, cfg: &ExperimentConfig, seed: u64) -> Result<RunSummary> {
        cfg.validate().map_err(|e| anyhow!("config: {e}"))?;
        let meta = self.rt.model(&cfg.model)?.clone();
        let card = DatasetCard::by_name(&cfg.dataset)
            .ok_or_else(|| anyhow!("unknown dataset card '{}'", cfg.dataset))?;
        if card.d != meta.d {
            return Err(anyhow!(
                "dataset '{}' (d={}) incompatible with model '{}' (d={})",
                cfg.dataset, card.d, cfg.model, meta.d
            ));
        }
        if card.classes > meta.c {
            return Err(anyhow!(
                "dataset '{}' has {} classes but model '{}' only {}",
                cfg.dataset, card.classes, cfg.model, meta.c
            ));
        }
        // dataset seed is decoupled from run seed so every strategy sees
        // identical data for a given cfg.seed
        let mut splits = self.splits(&cfg.dataset, cfg.seed, cfg.n_train)?.clone();
        if cfg.label_noise > 0.0 {
            let mut nrng = Rng::new(cfg.seed ^ 0x2077);
            crate::data::apply_label_noise(&mut splits.train, cfg.label_noise, &mut nrng);
        }

        // ground set: optionally imbalanced
        let ground: Vec<usize> = if cfg.is_valid {
            let mut rng = Rng::new(cfg.seed ^ 0x1337);
            imbalance_indices(&splits.train, cfg.imbalance_frac, cfg.imbalance_keep, &mut rng)
        } else {
            (0..splits.train.len()).collect()
        };

        let (mut strategy, warm) = parse_strategy(&cfg.strategy, meta.batch)?;
        let is_early_stop = cfg.strategy.starts_with("full-earlystop")
            || (cfg.strategy == "full" && cfg.budget_frac < 1.0);
        let opts = TrainOpts {
            epochs: cfg.epochs,
            r_interval: cfg.r_interval,
            budget_frac: if is_early_stop { 1.0 } else { cfg.budget_frac },
            lr0: cfg.lr0 as f32,
            lambda: cfg.lambda as f32,
            eps: cfg.eps as f32,
            kappa: cfg.kappa,
            warm,
            eval_every: cfg.eval_every,
            is_valid: cfg.is_valid,
            seed,
            early_stop_frac: if is_early_stop { Some(cfg.budget_frac) } else { None },
            overlap: cfg.overlap,
            stale_tol: 2.0,
            overlap_wait_ms: 2_000,
            max_staged_rows: cfg.max_staged_rows,
            sketch_width: cfg.sketch_width,
        };
        let st = self.rt.init(&cfg.model, seed as i32)?;
        let key = RunKey {
            dataset: cfg.dataset.clone(),
            model: cfg.model.clone(),
            strategy: cfg.strategy.clone(),
            budget_frac: cfg.budget_frac,
        };
        let mut selector = if cfg.overlap && !is_early_stop {
            let budget =
                ((opts.budget_frac * ground.len() as f64).round() as usize).clamp(1, ground.len());
            let request = SelectionRequest {
                strategy: cfg.strategy.trim_end_matches("-warm").to_string(),
                budget,
                lambda: cfg.lambda as f32,
                eps: cfg.eps as f32,
                is_valid: cfg.is_valid,
                seed,
                rng_tag: 0,
                ground: ground.clone(),
                shards: (cfg.max_staged_rows > 0).then(|| crate::engine::ShardPlan {
                    shards: 0,
                    max_staged_rows: cfg.max_staged_rows,
                }),
                sketch: (cfg.sketch_width > 0).then(|| crate::engine::SketchPlan {
                    width: cfg.sketch_width,
                    ..Default::default()
                }),
            };
            Some(crate::overlap::AsyncSelector::spawn(
                crate::overlap::SelectorConfig {
                    artifacts_dir: cfg.artifacts_dir.clone(),
                    request,
                },
                splits.train.clone(),
                splits.val.clone(),
            )?)
        } else {
            None
        };
        if cfg.reuse_across_arms && self.sel_cache.is_none() {
            self.sel_cache = Some(SelectionCache::new(SELECTION_CACHE_ROUNDS));
        }
        let cache = if cfg.reuse_across_arms {
            self.sel_cache.as_ref().map(|c| (c, dataset_scope(cfg)))
        } else {
            None
        };
        let (_st, outcome) = crate::trainer::train_with_cache(
            &self.rt,
            st,
            &splits,
            &ground,
            strategy.as_mut(),
            &opts,
            selector.as_mut(),
            cache,
        )?;
        Ok(RunSummary::from_outcome(&key, seed, &outcome))
    }

    /// One selection round, many strategies, one staged pass: initialize
    /// a model state for `cfg`, build a round-scoped [`SelectionEngine`]
    /// over it, and issue one batched request per spec — every strategy
    /// that stages at the same `(width, ground)` key shares the single
    /// staging pass (the reports' `stage_shared` flags show the reuse).
    /// The front-end of `gradmatch select --strategies a,b,c` and the
    /// engine benches.
    pub fn selection_round(
        &mut self,
        cfg: &ExperimentConfig,
        specs: &[&str],
    ) -> Result<Vec<SelectionReport>> {
        cfg.validate().map_err(|e| anyhow!("config: {e}"))?;
        let splits = self.splits(&cfg.dataset, cfg.seed, cfg.n_train)?.clone();
        let ground: Vec<usize> = if cfg.is_valid {
            let mut rng = Rng::new(cfg.seed ^ 0x1337);
            imbalance_indices(&splits.train, cfg.imbalance_frac, cfg.imbalance_keep, &mut rng)
        } else {
            (0..splits.train.len()).collect()
        };
        let st = self.rt.init(&cfg.model, cfg.seed as i32)?;
        let base = SelectionRequest::from_config(cfg, ground);
        let reqs: Vec<SelectionRequest> = specs
            .iter()
            .map(|spec| {
                let mut r = base.clone();
                r.strategy = spec.to_string();
                r
            })
            .collect();
        let engine = SelectionEngine::new(&self.rt, st, &splits.train, &splits.val);
        engine.select_batch(&reqs)
    }

    /// Run `cfg.runs` seeds; returns all summaries.
    pub fn run_multi(&mut self, cfg: &ExperimentConfig) -> Result<Vec<RunSummary>> {
        (0..cfg.runs.max(1))
            .map(|r| self.run_one(cfg, cfg.seed + r as u64))
            .collect()
    }

    /// Full-training skyline for a config + seed — cached under
    /// [`baseline_fingerprint`], so sweeps varying `n_train`/`lr0`/
    /// imbalance knobs each get their own skyline instead of silently
    /// reusing the first one computed.
    pub fn full_baseline(&mut self, cfg: &ExperimentConfig, seed: u64) -> Result<RunSummary> {
        let key = baseline_fingerprint(cfg, seed);
        if let Some(hit) = self.full_cache.get(&key) {
            return Ok(hit.clone());
        }
        let mut full_cfg = cfg.clone();
        full_cfg.strategy = "full".into();
        full_cfg.budget_frac = 1.0;
        let summary = self.run_one(&full_cfg, seed)?;
        self.baseline_solves += 1;
        self.full_cache.insert(key, summary.clone());
        Ok(summary)
    }

    /// Sweep strategies × budgets on one dataset — the Fig. 3 scatter data.
    /// Returns rows (summary, rel_err_pct, speedup, energy_ratio).
    pub fn sweep(
        &mut self,
        base: &ExperimentConfig,
        strategies: &[&str],
        budgets: &[f64],
    ) -> Result<Vec<SweepRow>> {
        let full = self.full_baseline(base, base.seed)?;
        let mut rows = Vec::new();
        for &b in budgets {
            for &strat in strategies {
                let mut cfg = base.clone();
                cfg.strategy = strat.to_string();
                cfg.budget_frac = b;
                let runs = self.run_multi(&cfg)?;
                rows.push(SweepRow::from_runs(&runs, &full));
            }
        }
        Ok(rows)
    }
}

/// One row of a Fig.3-style sweep.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub summary: RunSummary,
    pub acc_mean: f64,
    pub acc_std: f64,
    pub rel_err_pct: f64,
    pub speedup: f64,
    pub energy_ratio: f64,
    pub full_acc: f64,
}

impl SweepRow {
    /// Assemble one sweep row from a finished arm's seed-runs and the
    /// full-training skyline — the Fig. 3 math in one place so tests can
    /// pin it against hand-computed values: `rel_err_pct` is the
    /// accuracy gap relative to FULL (in percent of FULL's accuracy),
    /// `speedup` is FULL's wall-clock over the arm's mean, and
    /// `energy_ratio` is FULL's simulated energy over the arm's mean.
    pub fn from_runs(runs: &[RunSummary], full: &RunSummary) -> SweepRow {
        let accs: Vec<f64> = runs.iter().map(|r| r.test_acc).collect();
        let times: Vec<f64> = runs.iter().map(|r| r.total_secs).collect();
        let energies: Vec<f64> = runs.iter().map(|r| r.energy_kwh).collect();
        SweepRow {
            summary: runs[0].clone(),
            acc_mean: stats::mean(&accs),
            acc_std: stats::stddev(&accs),
            rel_err_pct: stats::relative_error_pct(
                stats::mean(&accs) * 100.0,
                full.test_acc * 100.0,
            ),
            speedup: stats::speedup(stats::mean(&times), full.total_secs),
            energy_ratio: full.energy_kwh / stats::mean(&energies).max(1e-12),
            full_acc: full.test_acc,
        }
    }

    /// Paper-shaped table line.
    pub fn format(&self) -> String {
        format!(
            "{:<22} {:>5.0}% | acc {:>6.2}% (±{:.2}) | rel-err {:>6.2}% | speedup {:>5.2}x | energy-gain {:>5.2}x | sel {:>5.1}s",
            self.summary.strategy,
            self.summary.budget_frac * 100.0,
            self.acc_mean * 100.0,
            self.acc_std * 100.0,
            self.rel_err_pct,
            self.speedup,
            self.energy_ratio,
            self.summary.select_secs,
        )
    }
}

/// Write summaries to `<out_dir>/<name>.json`.
pub fn write_results(out_dir: &str, name: &str, rows: &[RunSummary]) -> Result<String> {
    std::fs::create_dir_all(out_dir)?;
    let path = format!("{out_dir}/{name}.json");
    let doc = arr(rows.iter().map(|r| r.to_json()).collect());
    std::fs::write(&path, doc.dump())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_summary_json_roundtrips() {
        let r = RunSummary {
            dataset: "synmnist".into(),
            model: "lenet_s".into(),
            strategy: "gradmatch-pb".into(),
            budget_frac: 0.1,
            seed: 1,
            test_acc: 0.93,
            train_secs: 10.0,
            select_secs: 2.0,
            total_secs: 12.0,
            energy_kwh: 0.001,
            selections: 3,
            steps: 480,
            mean_grad_error: Some(0.05),
            select_stage_secs: 0.75,
            select_solve_secs: 1.25,
            stage_dispatches: 12,
            stage_shared_rounds: 1,
            engine_reused_rounds: 2,
            stage_buffer_reuses: 2,
            select_retries: 4,
            quarantined_rows: 7,
            degraded_rounds: 1,
            sync_fallback_rounds: 2,
            stale_rejections: 1,
            sharded_rounds: 2,
            peak_staged_rows: 150,
            merge_candidates: 40,
            sketched_rounds: 2,
            sketch_secs: 0.125,
            refit_secs: 0.0625,
            cache_hit_rounds: 2,
            cache_store_rounds: 1,
            cache_hit_secs_saved: 1.5,
            redundant_frac: 0.7,
            convergence: vec![(4, 1.0, 0.8), (9, 2.0, 0.9)],
        };
        let j = r.to_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("strategy").unwrap().as_str(), Some("gradmatch-pb"));
        assert_eq!(parsed.get("selections").unwrap().as_usize(), Some(3));
        assert_eq!(parsed.get("stage_dispatches").unwrap().as_usize(), Some(12));
        assert_eq!(parsed.get("select_stage_secs").unwrap().as_f64(), Some(0.75));
        assert_eq!(parsed.get("engine_reused_rounds").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("stage_buffer_reuses").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("select_retries").unwrap().as_usize(), Some(4));
        assert_eq!(parsed.get("quarantined_rows").unwrap().as_usize(), Some(7));
        assert_eq!(parsed.get("degraded_rounds").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("sync_fallback_rounds").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("stale_rejections").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("sharded_rounds").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("peak_staged_rows").unwrap().as_usize(), Some(150));
        assert_eq!(parsed.get("merge_candidates").unwrap().as_usize(), Some(40));
        assert_eq!(parsed.get("sketched_rounds").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("sketch_secs").unwrap().as_f64(), Some(0.125));
        assert_eq!(parsed.get("refit_secs").unwrap().as_f64(), Some(0.0625));
        assert_eq!(parsed.get("cache_hit_rounds").unwrap().as_usize(), Some(2));
        assert_eq!(parsed.get("cache_store_rounds").unwrap().as_usize(), Some(1));
        assert_eq!(parsed.get("cache_hit_secs_saved").unwrap().as_f64(), Some(1.5));
        assert_eq!(
            parsed.get("convergence").unwrap().as_arr().unwrap().len(),
            2
        );
    }

    /// Minimal summary for the device-free sweep-math tests below.
    fn summary(acc: f64, total_secs: f64, energy_kwh: f64) -> RunSummary {
        RunSummary {
            dataset: "synmnist".into(),
            model: "lenet_s".into(),
            strategy: "gradmatch".into(),
            budget_frac: 0.1,
            seed: 42,
            test_acc: acc,
            train_secs: total_secs * 0.8,
            select_secs: total_secs * 0.2,
            total_secs,
            energy_kwh,
            selections: 3,
            steps: 100,
            mean_grad_error: None,
            select_stage_secs: 0.0,
            select_solve_secs: 0.0,
            stage_dispatches: 0,
            stage_shared_rounds: 0,
            engine_reused_rounds: 0,
            stage_buffer_reuses: 0,
            select_retries: 0,
            quarantined_rows: 0,
            degraded_rounds: 0,
            sync_fallback_rounds: 0,
            stale_rejections: 0,
            sharded_rounds: 0,
            peak_staged_rows: 0,
            merge_candidates: 0,
            sketched_rounds: 0,
            sketch_secs: 0.0,
            refit_secs: 0.0,
            cache_hit_rounds: 0,
            cache_store_rounds: 0,
            cache_hit_secs_saved: 0.0,
            redundant_frac: 0.0,
            convergence: Vec::new(),
        }
    }

    #[test]
    fn sweep_row_math_matches_hand_computed_values() {
        // FULL skyline: 90% accuracy, 100s, 0.02 kWh
        let full = summary(0.90, 100.0, 0.02);
        // arm: two seed-runs, accs 0.80/0.84, times 20s/30s, 0.004/0.006 kWh
        let runs = vec![summary(0.80, 20.0, 0.004), summary(0.84, 30.0, 0.006)];
        let row = SweepRow::from_runs(&runs, &full);
        // mean acc = 0.82; rel-err = 100·(90 − 82)/90 = 8.888…%
        assert!((row.acc_mean - 0.82).abs() < 1e-12);
        assert!((row.rel_err_pct - 100.0 * (90.0 - 82.0) / 90.0).abs() < 1e-9);
        // stddev (n−1): |0.80 − 0.82| = 0.02 ⇒ √(2·0.0004/1) … = 0.02828…
        assert!((row.acc_std - (0.0008f64).sqrt()).abs() < 1e-12);
        // speedup = 100 / mean(20, 30) = 4.0
        assert!((row.speedup - 4.0).abs() < 1e-12);
        // energy ratio = 0.02 / mean(0.004, 0.006) = 4.0
        assert!((row.energy_ratio - 4.0).abs() < 1e-12);
        assert_eq!(row.full_acc, 0.90);
        // the row's headline summary is the FIRST seed-run
        assert_eq!(row.summary.total_secs, 20.0);
        // a single run pins the degenerate stats: std 0, mean = the run
        let solo = SweepRow::from_runs(&[summary(0.9, 50.0, 0.01)], &full);
        assert_eq!(solo.acc_std, 0.0);
        assert!((solo.rel_err_pct - 0.0).abs() < 1e-9);
        assert!((solo.speedup - 2.0).abs() < 1e-12);
    }

    #[test]
    fn baseline_fingerprint_separates_configs() {
        let base = ExperimentConfig::default();
        let key = baseline_fingerprint(&base, 42);
        assert_eq!(key, baseline_fingerprint(&base.clone(), 42), "deterministic");
        // the PR-10 regression: two configs differing ONLY in n_train
        // must produce distinct skylines
        let mut n_train = base.clone();
        n_train.n_train = 512;
        assert_ne!(key, baseline_fingerprint(&n_train, 42));
        // and the other knobs the old (dataset, model, epochs, seed)
        // tuple ignored
        let mut lr = base.clone();
        lr.lr0 = 0.01;
        assert_ne!(key, baseline_fingerprint(&lr, 42));
        let mut valid = base.clone();
        valid.is_valid = true;
        assert_ne!(key, baseline_fingerprint(&valid, 42));
        let mut imb = base.clone();
        imb.imbalance_keep = 0.2;
        assert_ne!(key, baseline_fingerprint(&imb, 42));
        let mut noise = base.clone();
        noise.label_noise = 0.1;
        assert_ne!(key, baseline_fingerprint(&noise, 42));
        let mut data_seed = base.clone();
        data_seed.seed = 7;
        assert_ne!(key, baseline_fingerprint(&data_seed, 42));
        // run seed and the original tuple fields still separate
        assert_ne!(key, baseline_fingerprint(&base, 43));
        let mut epochs = base.clone();
        epochs.epochs = base.epochs + 1;
        assert_ne!(key, baseline_fingerprint(&epochs, 42));
        let mut model = base.clone();
        model.model = "lenet_narrow".into();
        assert_ne!(key, baseline_fingerprint(&model, 42));
        // strategy/budget are overridden to full/1.0 by full_baseline, so
        // they deliberately do NOT split the key
        let mut strat = base.clone();
        strat.strategy = "craig".into();
        strat.budget_frac = 0.3;
        assert_eq!(key, baseline_fingerprint(&strat, 42));
    }
}
