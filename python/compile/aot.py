"""AOT pipeline: lower every L2 entry point to HLO **text** artifacts.

``python -m compile.aot --out ../artifacts`` produces::

    artifacts/
      manifest.json                 # shapes/dtypes/constants per entry
      <model>/<entry>.hlo.txt       # one HLO module per entry point

The interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE here, at build time; the Rust coordinator is self-contained
afterwards.  ``make artifacts`` is a no-op while ``manifest.json`` is newer
than the python sources.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(args) -> List[Dict]:
    out = []
    for a in args:
        out.append({"shape": list(a.shape), "dtype": str(a.dtype)})
    return out


def entry_points(spec: M.ModelSpec) -> Dict[str, Tuple[Callable, list]]:
    """(function, example-args) for every AOT entry of one model variant."""
    d, h, c, b, g, p = spec.d, spec.h, spec.c, spec.batch, spec.chunk, spec.p
    params = [_sds((d, h)), _sds((h,)), _sds((h, c)), _sds((c,))]

    def pk(f):
        """Adapt f(spec, params, ...) to flat positional params."""
        def wrapped(w1, b1, w2, b2, *rest):
            return f(spec, (w1, b1, w2, b2), *rest)
        return wrapped

    def tk(w1, b1, w2, b2, m1, mb1, m2, mb2, x, y, w, lr):
        return M.train_step(spec, (w1, b1, w2, b2), (m1, mb1, m2, mb2), x, y, w, lr)

    s_size = M.state_size(spec)

    def tf(state, x, y, w, lr):
        return M.train_step_fused(spec, state, x, y, w, lr)

    return {
        "init": (lambda seed: M.init(spec, seed), [_sds((), jnp.int32)]),
        "train_step": (
            tk,
            params + params + [_sds((b, d)), _sds((b,), jnp.int32), _sds((b,)), _sds(())],
        ),
        "train_step_fused": (
            tf,
            [_sds((s_size,)), _sds((b, d)), _sds((b,), jnp.int32), _sds((b,)), _sds(())],
        ),
        "eval_chunk": (
            pk(M.eval_chunk),
            params + [_sds((g, d)), _sds((g,), jnp.int32), _sds((g,))],
        ),
        "grads_chunk": (
            pk(M.grads_chunk),
            params + [_sds((g, d)), _sds((g,), jnp.int32), _sds((g,))],
        ),
        "mean_grad_chunk": (
            pk(M.mean_grad_chunk),
            params + [_sds((g, d)), _sds((g,), jnp.int32), _sds((g,))],
        ),
        "batch_gradsum_chunk": (
            pk(M.batch_gradsum_chunk),
            params + [_sds((g, d)), _sds((g,), jnp.int32), _sds((g,))],
        ),
        "corr_chunk": (
            lambda gm, r: M.corr_chunk(spec, gm, r),
            [_sds((g, p)), _sds((p,))],
        ),
        "sqdist_chunk": (
            lambda a, bb: M.sqdist_chunk(spec, a, bb),
            [_sds((g, p)), _sds((g, p))],
        ),
    }


def lower_model(spec: M.ModelSpec, out_dir: str) -> Dict:
    """Lower all entries of one model; returns its manifest fragment."""
    mdir = os.path.join(out_dir, spec.name)
    os.makedirs(mdir, exist_ok=True)
    entries = {}
    for name, (fn, args) in entry_points(spec).items():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        rel = f"{spec.name}/{name}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        entries[name] = {
            "path": rel,
            "inputs": _shape_entry(args),
            "outputs": _shape_entry(outs),
        }
        print(f"  {rel}: {len(text)} chars, {len(args)} in / {len(outs)} out")
    return {
        "d": spec.d,
        "h": spec.h,
        "c": spec.c,
        "batch": spec.batch,
        "chunk": spec.chunk,
        "p": spec.p,
        "state_size": M.state_size(spec),
        "momentum": M.MOMENTUM,
        "weight_decay": M.WEIGHT_DECAY,
        "grad_layout": "w2_row_major_hc_then_bias",
        "entries": entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--models",
        default=",".join(M.MODELS),
        help="comma-separated model variant names",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"format": 1, "interchange": "hlo-text", "models": {}}
    for name in args.models.split(","):
        spec = M.MODELS[name]
        print(f"lowering {name} (d={spec.d} h={spec.h} c={spec.c} p={spec.p})")
        manifest["models"][name] = lower_model(spec, args.out)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}/manifest.json")


if __name__ == "__main__":
    main()
