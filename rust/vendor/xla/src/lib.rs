//! Pure-host stand-in for the vendored `xla` FFI crate (xla_extension
//! bindings).
//!
//! The coordinator is written against a small slice of the `xla-rs` API:
//! [`Literal`] construction/readback, and the PJRT compile/execute
//! handles.  This crate implements the *host-side* half (literals are
//! plain buffers with shapes — fully functional, used by
//! `FusedState::pack`/`unpack` and the runtime tests) and stubs the
//! *device* half: [`PjRtClient::cpu`] returns a descriptive error, so
//! `Runtime::load` fails fast and artifact-dependent tests/benches skip
//! gracefully instead of segfaulting into a missing shared library.
//!
//! Swapping in the real bindings is a Cargo-level operation (point the
//! `xla` path dependency at the vendored FFI tree); no coordinator code
//! changes.

use std::fmt;
use std::path::Path;

/// Stub error (the real crate's error is also Debug+Display).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    let hint = if cfg!(feature = "pjrt") {
        "the `pjrt` feature is on but this build links the pure-host stub — vendor the xla_extension FFI tree"
    } else {
        "built with the pure-host `xla` stub (vendor/xla); PJRT execution needs the real xla_extension bindings"
    };
    Error(format!("{what} unavailable: {hint}"))
}

// ---------------------------------------------------------------------------
// literals (fully functional host-side)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side typed buffer + shape (row-major), mirroring `xla::Literal`.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    buf: Buf,
    dims: Vec<i64>,
}

/// Element types the coordinator marshals.
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Buf;
    fn unwrap(b: &Buf) -> Option<Vec<Self>>;
    const NAME: &'static str;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Buf {
        Buf::F32(v)
    }
    fn unwrap(b: &Buf) -> Option<Vec<Self>> {
        match b {
            Buf::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const NAME: &'static str = "f32";
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Buf {
        Buf::I32(v)
    }
    fn unwrap(b: &Buf) -> Option<Vec<Self>> {
        match b {
            Buf::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const NAME: &'static str = "i32";
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { buf: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { buf: T::wrap(vec![v]), dims: Vec::new() }
    }

    /// Tuple literal (what AOT'd entry points return).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        let n = elems.len() as i64;
        Literal { buf: Buf::Tuple(elems), dims: vec![n] }
    }

    fn len(&self) -> usize {
        match &self.buf {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
            Buf::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.len() || dims.iter().any(|&d| d < 0) {
            return Err(Error(format!(
                "reshape: {} elements into shape {:?}",
                self.len(),
                dims
            )));
        }
        Ok(Literal { buf: self.buf.clone(), dims: dims.to_vec() })
    }

    /// Read back as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.buf)
            .ok_or_else(|| Error(format!("to_vec::<{}>: literal holds a different type", T::NAME)))
    }

    /// Flatten a tuple literal into its elements.  Non-tuple literals
    /// yield themselves (matches the lenient readback the runtime uses).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.buf {
            Buf::Tuple(v) => Ok(v),
            _ => Ok(vec![self]),
        }
    }

    /// Shape accessor (row-major dims).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// PJRT handles (stubbed)
// ---------------------------------------------------------------------------

/// Parsed HLO module handle.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HLO parser for {}",
            path.as_ref().display()
        )))
    }
}

/// Computation handle.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PJRT compile"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device readback"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_scalar_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert_eq!(l.dims(), &[3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(l.to_vec::<i32>().is_err());
        let s = Literal::scalar(7i32);
        assert_eq!(s.dims(), &[] as &[i64]);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[0.0f32; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
        assert_eq!(l.reshape(&[3, 2]).unwrap().dims(), &[3, 2]);
    }

    #[test]
    fn tuple_flattens() {
        let t = Literal::tuple(vec![Literal::scalar(1i32), Literal::vec1(&[2.0f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        // non-tuples yield themselves
        let lone = Literal::scalar(5i32).to_tuple().unwrap();
        assert_eq!(lone.len(), 1);
    }

    #[test]
    fn pjrt_paths_error_descriptively() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("PJRT"), "{e}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
