"""L2 correctness: model semantics that the Rust coordinator depends on."""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M

SPEC = M.ModelSpec("tiny", d=12, h=8, c=4, batch=16, chunk=16)


@pytest.fixture(scope="module")
def params():
    return M.init(SPEC, jnp.int32(7))


def _batch(rng, n, spec=SPEC, balanced=True):
    x = jnp.asarray(rng.normal(size=(n, spec.d)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, spec.c, size=n).astype(np.int32))
    return x, y


def test_init_shapes_and_determinism():
    p1 = M.init(SPEC, jnp.int32(3))
    p2 = M.init(SPEC, jnp.int32(3))
    p3 = M.init(SPEC, jnp.int32(4))
    assert [a.shape for a in p1] == [(12, 8), (8,), (8, 4), (4,)]
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(a, b)
    assert not np.allclose(p1[0], p3[0])


def test_train_step_decreases_loss_on_fixed_batch(params):
    rng = np.random.default_rng(0)
    x, y = _batch(rng, 16)
    w = jnp.ones((16,), jnp.float32)
    momenta = tuple(jnp.zeros_like(p) for p in params)
    p = params
    losses = []
    for _ in range(30):
        out = M.train_step(SPEC, p, momenta, x, y, w, jnp.float32(0.05))
        p, momenta, loss = out[:4], out[4:8], out[8]
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_weighted_loss_ignores_zero_weight_rows(params):
    rng = np.random.default_rng(1)
    x, y = _batch(rng, 16)
    w = np.ones(16, np.float32)
    w[8:] = 0.0
    full = M.weighted_loss(params, x[:8], y[:8], jnp.ones((8,), jnp.float32))
    masked = M.weighted_loss(params, x, y, jnp.asarray(w))
    np.testing.assert_allclose(float(full), float(masked), rtol=1e-5)


def test_weighted_loss_weight_scale_invariance(params):
    """Normalized weighting: scaling all weights by a constant is a no-op."""
    rng = np.random.default_rng(2)
    x, y = _batch(rng, 16)
    w = jnp.asarray(rng.uniform(0.1, 2.0, size=16).astype(np.float32))
    a = M.weighted_loss(params, x, y, w)
    b = M.weighted_loss(params, x, y, 3.7 * w)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


def test_grads_chunk_matches_autodiff_per_sample(params):
    """The L1 kernel output must equal per-sample autodiff last-layer grads."""
    rng = np.random.default_rng(3)
    x, y = _batch(rng, 16)
    mask = jnp.ones((16,), jnp.float32)
    g = M.grads_chunk(SPEC, params, x, y, mask)

    def single_loss(w2, b2, xi, yi):
        h = jax.nn.relu(xi @ params[0] + params[1])
        logits = h @ w2 + b2
        return M.per_sample_ce(logits[None, :], yi[None])[0]

    gw2, gb2 = jax.vmap(
        jax.grad(single_loss, argnums=(0, 1)), in_axes=(None, None, 0, 0)
    )(params[2], params[3], x, y)
    want = np.concatenate(
        [np.asarray(gw2).reshape(16, -1), np.asarray(gb2)], axis=1
    )
    np.testing.assert_allclose(np.asarray(g), want, rtol=1e-4, atol=1e-5)


def test_grads_chunk_mask_zeroes_rows(params):
    rng = np.random.default_rng(4)
    x, y = _batch(rng, 16)
    mask = np.ones(16, np.float32)
    mask[5] = 0.0
    g = np.asarray(M.grads_chunk(SPEC, params, x, y, jnp.asarray(mask)))
    np.testing.assert_allclose(g[5], 0.0, atol=1e-7)
    assert np.abs(g[4]).sum() > 0


def test_mean_grad_chunk_equals_sum_of_per_sample(params):
    rng = np.random.default_rng(5)
    x, y = _batch(rng, 16)
    mask = jnp.asarray((rng.uniform(size=16) > 0.3).astype(np.float32))
    g = np.asarray(M.grads_chunk(SPEC, params, x, y, mask))
    mg = np.asarray(M.mean_grad_chunk(SPEC, params, x, y, mask))
    np.testing.assert_allclose(mg, g.sum(axis=0), rtol=1e-4, atol=1e-5)


def test_eval_chunk_counts(params):
    rng = np.random.default_rng(6)
    x, y = _batch(rng, 16)
    mask = np.ones(16, np.float32)
    mask[12:] = 0.0
    sloss, scorrect, correct, entropy = M.eval_chunk(
        SPEC, params, x, y, jnp.asarray(mask)
    )
    _, logits = M.forward(params, x)
    pred = np.argmax(np.asarray(logits), axis=1)
    want_correct = ((pred == np.asarray(y)) & (mask > 0)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(correct), want_correct)
    np.testing.assert_allclose(float(scorrect), want_correct.sum())
    assert float(sloss) > 0
    # entropy of a C-class distribution is in [0, log C]; masked rows are 0.
    e = np.asarray(entropy)
    assert np.all(e >= -1e-6) and np.all(e <= np.log(SPEC.c) + 1e-5)
    np.testing.assert_allclose(e[12:], 0.0, atol=1e-7)


def test_train_step_weight_zero_rows_do_not_affect_update(params):
    rng = np.random.default_rng(7)
    x, y = _batch(rng, 16)
    momenta = tuple(jnp.zeros_like(p) for p in params)
    w = np.ones(16, np.float32)
    w[8:] = 0.0
    out_masked = M.train_step(SPEC, params, momenta, x, y, jnp.asarray(w), jnp.float32(0.1))
    # corrupt the padded rows wildly — update must not change
    x2 = np.asarray(x).copy()
    x2[8:] = 1e3
    out_masked2 = M.train_step(
        SPEC, params, momenta, jnp.asarray(x2), y, jnp.asarray(w), jnp.float32(0.1)
    )
    for a, b in zip(out_masked[:8], out_masked2[:8]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_momentum_and_weight_decay_math(params):
    """One step against the hand-written update rule."""
    rng = np.random.default_rng(8)
    x, y = _batch(rng, 16)
    w = jnp.ones((16,), jnp.float32)
    momenta = tuple(jnp.full_like(p, 0.01) for p in params)
    lr = 0.2
    grads = jax.grad(M.weighted_loss)(params, x, y, w)
    out = M.train_step(SPEC, params, momenta, x, y, w, jnp.float32(lr))
    for p, m, g, p_new, m_new in zip(params, momenta, grads, out[:4], out[4:8]):
        m_want = M.MOMENTUM * np.asarray(m) + np.asarray(g) + M.WEIGHT_DECAY * np.asarray(p)
        np.testing.assert_allclose(np.asarray(m_new), m_want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(p_new), np.asarray(p) - lr * m_want, rtol=1e-5, atol=1e-6
        )
