//! Orthogonal Matching Pursuit (Algorithm 2) — the heart of GRAD-MATCH.
//!
//! Minimizes `Errλ(w, X) = ‖ Σ_{i∈X} wᵢ gᵢ − target ‖² + λ‖w‖²` greedily:
//! each round picks the candidate with the largest |correlation| against
//! the current residual, re-fits the ridge weights on the grown support,
//! and stops at the budget `k` or tolerance `ε` (Theorem 3's set-cover
//! stopping rule).
//!
//! The per-round hot spot is the ground-set correlation `G @ r`; it is
//! abstracted behind [`CorrBackend`] so the same solver runs against the
//! XLA/Pallas `corr_chunk` executable (the production path) or a plain
//! Rust GEMV (per-class slices, tests, benches).  The support re-fit uses
//! an incrementally-extended Cholesky factor: O(k²) per round instead of
//! re-factorizing in O(k³).

use anyhow::{anyhow, Result};

use crate::linalg::CholFactor;
use crate::runtime::Runtime;
use crate::tensor::{dot, norm2, Matrix};

/// Correlation oracle: `corr(r)[j] = g_j · r` over the whole ground set.
pub trait CorrBackend {
    fn corr(&mut self, r: &[f32]) -> Result<Vec<f32>>;
    /// number of candidates
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Rust GEMV backend over a borrowed candidate matrix.
pub struct RustCorr<'a> {
    pub g: &'a Matrix,
}

impl CorrBackend for RustCorr<'_> {
    fn corr(&mut self, r: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.g.rows];
        crate::tensor::gemv(self.g, r, &mut out);
        Ok(out)
    }

    fn len(&self) -> usize {
        self.g.rows
    }
}

/// XLA backend: the candidate matrix is padded once into fixed-shape
/// chunks and marshalled into input literals **once**; every OMP round
/// executes the Pallas `corr_chunk` kernel per chunk with only the fresh
/// residual re-marshalled (§Perf: caching the chunk literals removed the
/// dominant per-iteration marshalling cost; device-buffer reuse is not
/// safe with xla_extension 0.5.1 — see `Runtime::exec_ref`).
pub struct XlaCorr<'a> {
    rt: &'a Runtime,
    model: String,
    chunk_lits: Vec<xla::Literal>,
    n: usize,
}

impl<'a> XlaCorr<'a> {
    /// Pad `g` (n×P) into chunk-row blocks for the given model variant.
    pub fn new(rt: &'a Runtime, model: &str, g: &Matrix) -> Result<Self> {
        let meta = rt.model(model)?;
        if g.cols != meta.p {
            return Err(anyhow!(
                "XlaCorr: candidate dim {} != model P {} (per-class slices use RustCorr)",
                g.cols,
                meta.p
            ));
        }
        let rows = meta.chunk;
        let mut chunk_lits = Vec::new();
        let mut i = 0usize;
        while i < g.rows {
            let hi = (i + rows).min(g.rows);
            let mut m = Matrix::zeros(rows, g.cols);
            for (slot, r) in (i..hi).enumerate() {
                m.row_mut(slot).copy_from_slice(g.row(r));
            }
            chunk_lits.push(Runtime::matrix_literal(&m)?);
            i = hi;
        }
        Ok(XlaCorr { rt, model: model.to_string(), chunk_lits, n: g.rows })
    }
}

impl CorrBackend for XlaCorr<'_> {
    fn corr(&mut self, r: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.n);
        for lit in &self.chunk_lits {
            let v = self.rt.corr_chunk_lit(&self.model, lit, r)?;
            out.extend_from_slice(&v);
        }
        out.truncate(self.n);
        Ok(out)
    }

    fn len(&self) -> usize {
        self.n
    }
}

/// Outcome of one OMP run.
#[derive(Clone, Debug)]
pub struct OmpResult {
    /// selected candidate indices (into the ground set), in pick order
    pub selected: Vec<usize>,
    /// matching weights, aligned with `selected` (non-negative)
    pub weights: Vec<f32>,
    /// final ‖residual‖
    pub residual_norm: f32,
    /// rounds executed
    pub iters: usize,
}

/// OMP configuration.
#[derive(Clone, Copy, Debug)]
pub struct OmpOpts {
    /// budget k (max support size)
    pub k: usize,
    /// ridge regularizer λ (Eq. 1; paper default 0.5)
    pub lambda: f32,
    /// tolerance ε: stop once ‖r‖² + λ‖w‖² ≤ ε
    pub eps: f32,
}

/// Run Algorithm 2 against a correlation backend.
///
/// `row` must return the gradient row of candidate `j` (used for the
/// support Gram updates and the residual; only selected rows are fetched,
/// so PB/per-class callers can keep the full matrix wherever it lives).
pub fn omp_select(
    backend: &mut dyn CorrBackend,
    row: &dyn Fn(usize) -> Vec<f32>,
    target: &[f32],
    opts: OmpOpts,
) -> Result<OmpResult> {
    let n = backend.len();
    let k = opts.k.min(n);
    let mut selected: Vec<usize> = Vec::with_capacity(k);
    let mut sel_rows: Vec<Vec<f32>> = Vec::with_capacity(k);
    let mut weights: Vec<f32> = Vec::new();
    let mut taken = vec![false; n];
    let mut chol = CholFactor::empty();
    let mut rhs: Vec<f64> = Vec::with_capacity(k);
    let mut residual = target.to_vec();
    let mut iters = 0usize;

    while selected.len() < k {
        // E_λ stopping rule (Algorithm 2's `while E_λ(X) ≥ ε`)
        let e_lambda = dot(&residual, &residual)
            + opts.lambda * weights.iter().map(|w| w * w).sum::<f32>();
        if e_lambda <= opts.eps {
            break;
        }
        iters += 1;

        // argmax_j |g_j · r| over un-selected candidates
        let corr = backend.corr(&residual)?;
        let mut best = usize::MAX;
        let mut best_v = 0.0f32;
        for (j, &c) in corr.iter().enumerate() {
            let a = c.abs();
            if !taken[j] && a > best_v {
                best = j;
                best_v = a;
            }
        }
        if best == usize::MAX || best_v <= 1e-12 {
            break; // nothing correlates with the residual
        }
        taken[best] = true;
        let g_new = row(best);

        // extend (G_S G_Sᵀ + λI) Cholesky by the new candidate
        let mut new_row: Vec<f64> = sel_rows.iter().map(|r| dot(r, &g_new) as f64).collect();
        new_row.push(dot(&g_new, &g_new) as f64 + opts.lambda as f64);
        if chol.extend(&new_row).is_err() {
            // numerically dependent candidate — skip it and continue
            continue;
        }
        rhs.push(dot(&g_new, target) as f64);
        selected.push(best);
        sel_rows.push(g_new);

        // re-fit weights on the grown support, recompute residual
        let w64 = chol.solve(&rhs)?;
        weights = w64.iter().map(|&v| v as f32).collect();
        residual.copy_from_slice(target);
        for (r, &w) in sel_rows.iter().zip(&weights) {
            crate::tensor::axpy(-w, r, &mut residual);
        }
    }

    // final non-negativity fixup (CORDS-style): iterated clamp + re-solve
    if weights.iter().any(|&w| w < 0.0) {
        let mut g_sel = Matrix::zeros(sel_rows.len(), target.len());
        for (slot, r) in sel_rows.iter().enumerate() {
            g_sel.row_mut(slot).copy_from_slice(r);
        }
        weights = crate::linalg::ridge_weights_nonneg(&g_sel, target, opts.lambda)
            .map_err(|e| anyhow!("omp nonneg re-solve: {e}"))?;
        residual.copy_from_slice(target);
        for (r, &w) in sel_rows.iter().zip(&weights) {
            crate::tensor::axpy(-w, r, &mut residual);
        }
    }

    Ok(OmpResult {
        selected,
        weights,
        residual_norm: norm2(&residual),
        iters,
    })
}

/// Convenience: run OMP over an in-memory candidate matrix with RustCorr.
pub fn omp_select_rust(g: &Matrix, target: &[f32], opts: OmpOpts) -> Result<OmpResult> {
    let mut backend = RustCorr { g };
    omp_select(&mut backend, &|j| g.row(j).to_vec(), target, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::forall;

    fn opts(k: usize) -> OmpOpts {
        OmpOpts { k, lambda: 1e-4, eps: 1e-12 }
    }

    #[test]
    fn recovers_sparse_combination_of_orthogonal_rows() {
        // rows = scaled identity; target = 2 e0 + 5 e3
        let mut g = Matrix::zeros(6, 6);
        for i in 0..6 {
            g.set(i, i, 1.0);
        }
        let mut target = vec![0.0f32; 6];
        target[0] = 2.0;
        target[3] = 5.0;
        let r = omp_select_rust(&g, &target, opts(2)).unwrap();
        let mut sel = r.selected.clone();
        sel.sort_unstable();
        assert_eq!(sel, vec![0, 3]);
        assert!(r.residual_norm < 1e-3, "{}", r.residual_norm);
        // weights align with the picks
        for (j, &s) in r.selected.iter().enumerate() {
            let want = if s == 0 { 2.0 } else { 5.0 };
            assert!((r.weights[j] - want).abs() < 0.01, "{:?}", r.weights);
        }
    }

    #[test]
    fn respects_budget() {
        let mut rng = Rng::new(1);
        let g = Matrix::from_vec(50, 8, (0..400).map(|_| rng.gaussian_f32()).collect());
        let target: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
        let r = omp_select_rust(&g, &target, opts(5)).unwrap();
        assert!(r.selected.len() <= 5);
        assert_eq!(r.selected.len(), r.weights.len());
    }

    #[test]
    fn no_duplicate_selections() {
        forall(20, |gen| {
            let n = gen.int(3, 30);
            let p = gen.int(2, 10);
            let g = gen.matrix(n, p);
            let target = gen.gauss_vec(p);
            let r = omp_select_rust(&g, &target, opts(n)).unwrap();
            let mut s = r.selected.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), r.selected.len());
        });
    }

    #[test]
    fn weights_nonnegative() {
        forall(30, |gen| {
            let n = gen.int(4, 40);
            let p = gen.int(3, 12);
            let g = gen.matrix(n, p);
            let target = gen.gauss_vec(p);
            let k = gen.int(1, n.min(8));
            let r = omp_select_rust(&g, &target, OmpOpts { k, lambda: 0.5, eps: 1e-12 }).unwrap();
            assert!(r.weights.iter().all(|&w| w >= 0.0), "{:?}", r.weights);
        });
    }

    #[test]
    fn residual_never_exceeds_target_norm_much() {
        // with λ small, fitted residual must not be (meaningfully) worse
        // than the empty solution
        forall(30, |gen| {
            let n = gen.int(4, 30);
            let p = gen.int(2, 10);
            let g = gen.matrix(n, p);
            let target = gen.gauss_vec(p);
            let r = omp_select_rust(&g, &target, opts(n.min(6))).unwrap();
            assert!(r.residual_norm <= norm2(&target) * 1.01 + 1e-4);
        });
    }

    #[test]
    fn larger_budget_fits_at_least_as_well() {
        let mut rng = Rng::new(5);
        let g = Matrix::from_vec(40, 10, (0..400).map(|_| rng.gaussian_f32()).collect());
        let target: Vec<f32> = (0..10).map(|_| rng.gaussian_f32()).collect();
        let r2 = omp_select_rust(&g, &target, opts(2)).unwrap();
        let r8 = omp_select_rust(&g, &target, opts(8)).unwrap();
        assert!(r8.residual_norm <= r2.residual_norm + 1e-4);
    }

    #[test]
    fn eps_stopping_selects_fewer() {
        let mut g = Matrix::zeros(4, 4);
        for i in 0..4 {
            g.set(i, i, 1.0);
        }
        let target = [10.0f32, 0.01, 0.0, 0.0];
        // generous eps: should stop after the big coordinate is matched
        let r = omp_select_rust(
            &g,
            &target,
            OmpOpts { k: 4, lambda: 1e-6, eps: 0.01 },
        )
        .unwrap();
        assert_eq!(r.selected, vec![0]);
    }

    #[test]
    fn zero_target_selects_nothing() {
        let mut rng = Rng::new(6);
        let g = Matrix::from_vec(10, 5, (0..50).map(|_| rng.gaussian_f32()).collect());
        let r = omp_select_rust(&g, &[0.0; 5], opts(5)).unwrap();
        assert!(r.selected.is_empty());
        assert_eq!(r.residual_norm, 0.0);
    }

    #[test]
    fn duplicate_rows_are_skippable() {
        // ground set of identical rows: OMP must not crash on the singular
        // support; one row suffices
        let g = Matrix::from_vec(5, 3, vec![1.0, 2.0, 3.0].repeat(5));
        let target = [2.0f32, 4.0, 6.0];
        let r = omp_select_rust(&g, &target, opts(5)).unwrap();
        assert!(r.residual_norm < 1e-2, "{}", r.residual_norm);
        assert!(!r.selected.is_empty());
    }

    #[test]
    fn lambda_extremes_fig4g_semantics() {
        // Fig. 4g: λ=0 is allowed and fits tightly on an easy problem;
        // huge λ crushes the weights so the fit degenerates toward the
        // empty solution — both ends of the paper's λ sweep.
        let mut rng = Rng::new(7);
        let g = Matrix::from_vec(20, 6, (0..120).map(|_| rng.gaussian_f32()).collect());
        // target is a positive combination of rows, so it is representable
        // under the non-negative weight constraint
        let mut target = vec![0.0f32; 6];
        for i in [1usize, 4, 9] {
            crate::tensor::axpy(0.5 + i as f32 * 0.2, g.row(i), &mut target);
        }
        // λ=0 must run without error and beat the empty fit (the greedy
        // support under the non-negativity constraint need not be exact)
        let r0 = omp_select_rust(&g, &target, OmpOpts { k: 8, lambda: 0.0, eps: 1e-12 }).unwrap();
        assert!(r0.residual_norm < 0.75 * norm2(&target), "{}", r0.residual_norm);
        let rbig =
            omp_select_rust(&g, &target, OmpOpts { k: 8, lambda: 1e6, eps: 1e-12 }).unwrap();
        assert!(rbig.residual_norm > 0.9 * norm2(&target), "{}", rbig.residual_norm);
        let wnorm: f32 = rbig.weights.iter().map(|w| w * w).sum::<f32>().sqrt();
        assert!(wnorm < 1e-2, "weights should be crushed: {wnorm}");
    }
}
