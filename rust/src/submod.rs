//! Submodular maximization substrate: facility location + lazy greedy.
//!
//! Powers the CRAIG baseline (Mirzasoleiman et al. 2020 — facility location
//! over gradient-space distances, medoid-count weights; §3.2 / Appendix B.7
//! of the paper) and the feature-space facility-location baseline of
//! Table 12.  The lazy greedy implementation exploits submodularity: stale
//! upper bounds sit in a max-heap and are only refreshed when popped
//! (Minoux's accelerated greedy), which in practice evaluates a small
//! fraction of the O(n·k) gains the naive greedy needs.
//!
//! [`FacilityLocation`] reads similarities from either a precomputed
//! similarity matrix ([`FacilityLocation::new`]) or directly from a
//! squared-distance matrix ([`FacilityLocation::from_sqdist`], the CRAIG
//! kernelization `sim = d_max − dist` applied per access) — the latter
//! skips the n² similarity copy that [`sim_from_sqdist`] materializes.
//! Coverage commits and medoid-weight votes run on the parallel blocked
//! layer ([`crate::par`]) and degrade to serial inside the selection
//! round's class-level fan-out.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::tensor::Matrix;

/// Where similarities come from (borrowed; both variants are O(1) per
/// access).
enum SimSource<'a> {
    /// precomputed `[n, n]` similarity matrix (entries must be ≥ 0)
    Sim(&'a Matrix),
    /// `[n, n]` squared distances (entries ≥ 0 up to numerical noise —
    /// tiny device-computed negatives are tolerated); similarity is
    /// `d_max − dist[i][j]`, computed on the fly
    Dist { dist: &'a Matrix, d_max: f32 },
}

impl SimSource<'_> {
    #[inline]
    fn at(&self, i: usize, j: usize) -> f32 {
        match *self {
            SimSource::Sim(m) => m.data[i * m.cols + j],
            SimSource::Dist { dist, d_max } => d_max - dist.data[i * dist.cols + j],
        }
    }

    fn n(&self) -> usize {
        match *self {
            SimSource::Sim(m) => m.rows,
            SimSource::Dist { dist, .. } => dist.rows,
        }
    }
}

/// Facility-location objective `F(S) = Σ_i max_{j∈S} sim[i][j]` (sims
/// must be ≥ 0 — guaranteed by construction on the distance-backed path).
pub struct FacilityLocation<'a> {
    src: SimSource<'a>,
    /// best coverage per element under the current selection
    cover: Vec<f32>,
}

impl<'a> FacilityLocation<'a> {
    /// Over a precomputed similarity matrix.
    pub fn new(sim: &'a Matrix) -> Self {
        assert_eq!(sim.rows, sim.cols, "facility location needs square sims");
        FacilityLocation { src: SimSource::Sim(sim), cover: vec![0.0; sim.rows] }
    }

    /// Directly over a squared-distance matrix (entries ≥ 0 up to
    /// numerical noise): similarities are `d_max − dist[i][j]`, computed
    /// per access — no n² copy.
    pub fn from_sqdist(dist: &'a Matrix) -> Self {
        assert_eq!(dist.rows, dist.cols, "facility location needs square dists");
        let d_max = dist.data.iter().cloned().fold(0.0f32, f32::max);
        FacilityLocation { src: SimSource::Dist { dist, d_max }, cover: vec![0.0; dist.rows] }
    }

    /// Number of ground-set elements.
    pub fn n(&self) -> usize {
        self.src.n()
    }

    /// Similarity of elements `i`, `j`.
    #[inline]
    pub fn sim(&self, i: usize, j: usize) -> f32 {
        self.src.at(i, j)
    }

    /// Marginal gain of adding `j` to the current selection.
    pub fn gain(&self, j: usize) -> f64 {
        let n = self.n();
        let mut g = 0.0f64;
        match self.src {
            SimSource::Sim(m) => {
                for i in 0..n {
                    let s = m.data[i * n + j];
                    let c = self.cover[i];
                    if s > c {
                        g += (s - c) as f64;
                    }
                }
            }
            SimSource::Dist { dist, d_max } => {
                for i in 0..n {
                    let s = d_max - dist.data[i * n + j];
                    let c = self.cover[i];
                    if s > c {
                        g += (s - c) as f64;
                    }
                }
            }
        }
        g
    }

    /// Gains of every element under the empty selection — the clamped
    /// column sums of the similarity source, all n at once on the
    /// parallel blocked layer (the heap-seeding pass of [`lazy_greedy`]).
    pub fn initial_gains(&self) -> Vec<f64> {
        match self.src {
            SimSource::Sim(m) => crate::par::colsum_pos(m),
            SimSource::Dist { dist, d_max } => {
                // sims are d_max − dist ≥ 0 by construction, so the
                // empty-cover gain of j is n·d_max − Σ_i dist[i][j].  The
                // sum must be *unclamped*: device-computed squared
                // distances can come back as tiny negatives, and clamping
                // them would understate the heap seed — lazy greedy
                // requires these keys to be upper bounds of gain(j).
                let n = dist.rows as f64;
                crate::par::colsum(dist)
                    .into_iter()
                    .map(|s| n * d_max as f64 - s)
                    .collect()
            }
        }
    }

    /// Commit element `j` (update coverage) — parallel over coverage
    /// blocks when n is large enough to pay for it.
    pub fn commit(&mut self, j: usize) {
        let src = &self.src;
        let work = self.cover.len();
        crate::par::for_chunks(&mut self.cover, work, |lo, chunk| {
            for (off, c) in chunk.iter_mut().enumerate() {
                let s = src.at(lo + off, j);
                if s > *c {
                    *c = s;
                }
            }
        });
    }

    /// Current objective value.
    pub fn value(&self) -> f64 {
        self.cover.iter().map(|&v| v as f64).sum()
    }

    /// Medoid-count weights for a selection: `w_j = |{i : j = argmax_{s∈S}
    /// sim[i][s]}|` — CRAIG's weights (Lemma 2).  Every element votes for
    /// its best-covering selected medoid.  Policy-parallel over voter
    /// blocks; see [`Self::medoid_weights_threads`].
    pub fn medoid_weights(&self, selected: &[usize]) -> Vec<f32> {
        let threads = crate::par::policy_threads(self.n() * selected.len().max(1));
        self.medoid_weights_threads(selected, threads)
    }

    /// [`Self::medoid_weights`] with an explicit worker count.  Each
    /// worker tallies a disjoint block of voters into a local count
    /// vector; partials are summed in block order (counts are small
    /// integers in f32, so the reduction is exact and order-independent).
    pub fn medoid_weights_threads(&self, selected: &[usize], threads: usize) -> Vec<f32> {
        let n = self.n();
        let mut w = vec![0.0f32; selected.len()];
        if selected.is_empty() || n == 0 {
            return w;
        }
        let vote_block = |lo: usize, hi: usize| -> Vec<f32> {
            let mut local = vec![0.0f32; selected.len()];
            for i in lo..hi {
                let mut best = 0usize;
                let mut best_s = f32::NEG_INFINITY;
                for (slot, &j) in selected.iter().enumerate() {
                    let s = self.src.at(i, j);
                    if s > best_s {
                        best_s = s;
                        best = slot;
                    }
                }
                local[best] += 1.0;
            }
            local
        };
        let threads = threads.clamp(1, n);
        if threads == 1 {
            return vote_block(0, n);
        }
        let per = n.div_ceil(threads);
        let blocks: Vec<(usize, usize)> =
            (0..threads).map(|b| (b * per, ((b + 1) * per).min(n))).collect();
        let partials = crate::par::map_tasks_threads(&blocks, threads, |&(lo, hi)| vote_block(lo, hi));
        for local in partials {
            for (acc, v) in w.iter_mut().zip(local) {
                *acc += v;
            }
        }
        w
    }
}

#[derive(PartialEq)]
struct HeapItem {
    gain: f64,
    item: usize,
    /// round when this gain was computed (staleness marker)
    round: usize,
}

impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        self.gain
            .partial_cmp(&other.gain)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.item.cmp(&self.item))
    }
}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Result of a greedy maximization.
#[derive(Clone, Debug)]
pub struct GreedyResult {
    pub selected: Vec<usize>,
    /// objective value after each pick (monotone nondecreasing)
    pub values: Vec<f64>,
    /// total gain evaluations performed (lazy-greedy efficiency metric)
    pub evals: usize,
}

/// Lazy (accelerated) greedy under a cardinality constraint `k`.
pub fn lazy_greedy(fl: &mut FacilityLocation<'_>, k: usize) -> GreedyResult {
    let n = fl.n();
    let k = k.min(n);
    let mut heap = BinaryHeap::with_capacity(n);
    let mut evals = 0usize;
    // Under the empty selection `cover` is all-zero, so every initial
    // gain is the clamped column sum Σ_i max(sim[i][j], 0) — computed for
    // all n columns at once on the parallel blocked layer (the O(n²)
    // heap-seeding pass that used to dominate small-k builds).
    for (j, g) in fl.initial_gains().into_iter().enumerate() {
        evals += 1;
        heap.push(HeapItem { gain: g, item: j, round: 0 });
    }
    let mut selected = Vec::with_capacity(k);
    let mut values = Vec::with_capacity(k);
    let mut taken = vec![false; n];
    let mut round = 0usize;
    while selected.len() < k {
        let top = match heap.pop() {
            Some(t) => t,
            None => break,
        };
        if taken[top.item] {
            continue;
        }
        if top.round == round {
            // fresh bound — by submodularity it is the true max
            fl.commit(top.item);
            taken[top.item] = true;
            selected.push(top.item);
            values.push(fl.value());
            round += 1;
        } else {
            let g = fl.gain(top.item);
            evals += 1;
            heap.push(HeapItem { gain: g, item: top.item, round });
        }
    }
    GreedyResult { selected, values, evals }
}

/// Naive greedy (reference for tests; O(n·k) gain evaluations).
pub fn naive_greedy(fl: &mut FacilityLocation<'_>, k: usize) -> GreedyResult {
    let n = fl.n();
    let k = k.min(n);
    let mut selected = Vec::with_capacity(k);
    let mut values = Vec::with_capacity(k);
    let mut taken = vec![false; n];
    let mut evals = 0usize;
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_g = f64::NEG_INFINITY;
        for j in 0..n {
            if taken[j] {
                continue;
            }
            let g = fl.gain(j);
            evals += 1;
            if g > best_g {
                best_g = g;
                best = j;
            }
        }
        if best == usize::MAX {
            break;
        }
        fl.commit(best);
        taken[best] = true;
        selected.push(best);
        values.push(fl.value());
    }
    GreedyResult { selected, values, evals }
}

/// Greedy set cover (Theorem 3 regime): select until the objective reaches
/// `target_value` or the ground set is exhausted.
pub fn greedy_cover(fl: &mut FacilityLocation<'_>, target_value: f64) -> GreedyResult {
    let n = fl.n();
    let mut res = GreedyResult { selected: Vec::new(), values: Vec::new(), evals: 0 };
    let mut taken = vec![false; n];
    while fl.value() < target_value && res.selected.len() < n {
        let mut best = usize::MAX;
        let mut best_g = 0.0f64;
        for j in 0..n {
            if taken[j] {
                continue;
            }
            let g = fl.gain(j);
            res.evals += 1;
            if g > best_g {
                best_g = g;
                best = j;
            }
        }
        if best == usize::MAX || best_g <= 0.0 {
            break;
        }
        fl.commit(best);
        taken[best] = true;
        res.selected.push(best);
        res.values.push(fl.value());
    }
    res
}

/// Build a similarity matrix from squared distances:
/// `sim[i][j] = d_max − dist[i][j]` (the CRAIG kernelization — constant
/// shift makes similarities non-negative without changing the argmax
/// structure).  This *materializes* the n² similarity copy; the selection
/// hot paths use [`FacilityLocation::from_sqdist`] instead, which applies
/// the same kernelization per access.  Kept as the reference the
/// equivalence tests and micro benches compare against.
pub fn sim_from_sqdist(dist: &Matrix) -> Matrix {
    let d_max = dist.data.iter().cloned().fold(0.0f32, f32::max);
    let mut sim = Matrix::zeros(dist.rows, dist.cols);
    for i in 0..dist.data.len() {
        sim.data[i] = d_max - dist.data[i];
    }
    sim
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::forall;

    fn random_sim(n: usize, rng: &mut Rng) -> Matrix {
        // symmetric nonneg similarities with self-similarity maximal
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..i {
                let v = rng.f32();
                m.set(i, j, v);
                m.set(j, i, v);
            }
            m.set(i, i, 1.5);
        }
        m
    }

    #[test]
    fn lazy_equals_naive_greedy() {
        forall(15, |g| {
            let n = g.int(3, 25);
            let mut rng = Rng::new(g.case as u64 + 100);
            let sim = random_sim(n, &mut rng);
            let k = g.int(1, n);
            let lazy = lazy_greedy(&mut FacilityLocation::new(&sim), k);
            let naive = naive_greedy(&mut FacilityLocation::new(&sim), k);
            assert_eq!(lazy.selected, naive.selected, "n={n} k={k}");
            assert!(lazy.evals <= naive.evals);
        });
    }

    #[test]
    fn greedy_values_monotone_nondecreasing() {
        let mut rng = Rng::new(3);
        let sim = random_sim(30, &mut rng);
        let res = lazy_greedy(&mut FacilityLocation::new(&sim), 10);
        for w in res.values.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
    }

    #[test]
    fn diminishing_returns_of_gain() {
        // submodularity: gain(j | S) >= gain(j | S ∪ {e})
        let mut rng = Rng::new(4);
        let sim = random_sim(12, &mut rng);
        let mut fl = FacilityLocation::new(&sim);
        let j = 5;
        let before = fl.gain(j);
        fl.commit(2);
        let after = fl.gain(j);
        assert!(after <= before + 1e-9);
    }

    #[test]
    fn medoid_weights_sum_to_ground_set_size() {
        let mut rng = Rng::new(5);
        let sim = random_sim(20, &mut rng);
        let mut fl = FacilityLocation::new(&sim);
        let res = lazy_greedy(&mut fl, 4);
        let w = fl.medoid_weights(&res.selected);
        let total: f32 = w.iter().sum();
        assert!((total - 20.0).abs() < 1e-5);
        assert!(w.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn every_element_covers_itself_when_selected() {
        let mut rng = Rng::new(6);
        let sim = random_sim(8, &mut rng);
        let mut fl = FacilityLocation::new(&sim);
        let res = lazy_greedy(&mut fl, 8);
        // selecting everything covers every row at its self-similarity
        assert_eq!(res.selected.len(), 8);
        assert!((fl.value() - 8.0 * 1.5) < 1e-4);
    }

    #[test]
    fn greedy_cover_reaches_target_or_exhausts() {
        let mut rng = Rng::new(7);
        let sim = random_sim(15, &mut rng);
        let full_value = {
            let mut fl = FacilityLocation::new(&sim);
            lazy_greedy(&mut fl, 15);
            fl.value()
        };
        let mut fl = FacilityLocation::new(&sim);
        let res = greedy_cover(&mut fl, 0.8 * full_value);
        assert!(fl.value() >= 0.8 * full_value);
        assert!(res.selected.len() < 15, "cover should need fewer than all");
    }

    #[test]
    fn sim_from_sqdist_properties() {
        let d = Matrix::from_vec(2, 2, vec![0.0, 4.0, 4.0, 0.0]);
        let s = sim_from_sqdist(&d);
        // self-sim maximal, all entries nonneg
        assert_eq!(s.at(0, 0), 4.0);
        assert_eq!(s.at(0, 1), 0.0);
        assert!(s.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn first_pick_is_global_best() {
        let mut rng = Rng::new(8);
        let sim = random_sim(20, &mut rng);
        let res = lazy_greedy(&mut FacilityLocation::new(&sim), 1);
        let mut fl2 = FacilityLocation::new(&sim);
        let best = (0..20)
            .max_by(|&a, &b| fl2.gain(a).partial_cmp(&fl2.gain(b)).unwrap())
            .unwrap();
        let _ = &mut fl2;
        assert_eq!(res.selected[0], best);
    }

    fn random_sqdist(n: usize, rng: &mut Rng) -> Matrix {
        // symmetric nonneg squared distances with zero diagonal
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..i {
                let v = rng.f32() * 3.0;
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        m
    }

    #[test]
    fn distance_backed_fl_matches_sim_copy_path() {
        // from_sqdist must reproduce the sim_from_sqdist + new() pipeline:
        // same gains, same greedy selection, same medoid weights
        forall(15, |g| {
            let n = g.int(2, 30);
            let mut rng = Rng::new(g.case as u64 + 500);
            let dist = random_sqdist(n, &mut rng);
            let sim = sim_from_sqdist(&dist);
            let k = g.int(1, n);

            let fl_d = FacilityLocation::from_sqdist(&dist);
            let fl_s = FacilityLocation::new(&sim);
            for j in 0..n {
                let (gd, gs) = (fl_d.gain(j), fl_s.gain(j));
                assert!((gd - gs).abs() <= 1e-4 * (1.0 + gs.abs()), "gain {j}: {gd} vs {gs}");
            }
            let ig_d = fl_d.initial_gains();
            let ig_s = fl_s.initial_gains();
            for j in 0..n {
                assert!(
                    (ig_d[j] - ig_s[j]).abs() <= 1e-4 * (1.0 + ig_s[j].abs()),
                    "initial gain {j}: {} vs {}",
                    ig_d[j],
                    ig_s[j]
                );
            }

            let mut fl_d = FacilityLocation::from_sqdist(&dist);
            let mut fl_s = FacilityLocation::new(&sim);
            let rd = lazy_greedy(&mut fl_d, k);
            let rs = lazy_greedy(&mut fl_s, k);
            assert_eq!(rd.selected, rs.selected, "n={n} k={k}");
            let wd = fl_d.medoid_weights(&rd.selected);
            let ws = fl_s.medoid_weights(&rs.selected);
            assert_eq!(wd, ws);
        });
    }

    #[test]
    fn parallel_medoid_weights_match_serial() {
        forall(10, |g| {
            let n = g.int(3, 40);
            let mut rng = Rng::new(g.case as u64 + 900);
            let sim = random_sim(n, &mut rng);
            let mut fl = FacilityLocation::new(&sim);
            let k = g.int(1, n.min(6));
            let res = lazy_greedy(&mut fl, k);
            let want = fl.medoid_weights_threads(&res.selected, 1);
            for threads in [2usize, 4, 7] {
                let got = fl.medoid_weights_threads(&res.selected, threads);
                assert_eq!(got, want, "threads={threads}");
            }
        });
    }

    #[test]
    fn parallel_commit_matches_serial_coverage() {
        // commit through the policy path (serial at this size) vs a
        // hand-rolled serial update
        let mut rng = Rng::new(12);
        let sim = random_sim(25, &mut rng);
        let mut fl = FacilityLocation::new(&sim);
        let mut cover = vec![0.0f32; 25];
        for &j in &[3usize, 11, 19] {
            fl.commit(j);
            for (i, c) in cover.iter_mut().enumerate() {
                let s = sim.at(i, j);
                if s > *c {
                    *c = s;
                }
            }
        }
        let want: f64 = cover.iter().map(|&v| v as f64).sum();
        assert!((fl.value() - want).abs() < 1e-9);
    }
}
