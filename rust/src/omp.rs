//! Orthogonal Matching Pursuit (Algorithm 2) — the heart of GRAD-MATCH.
//!
//! Minimizes `Errλ(w, X) = ‖ Σ_{i∈X} wᵢ gᵢ − target ‖² + λ‖w‖²` greedily:
//! each round picks the candidate with the largest |correlation| against
//! the current residual, re-fits the ridge weights on the grown support,
//! and stops at the budget `k` or tolerance `ε` (Theorem 3's set-cover
//! stopping rule).
//!
//! # Batch-OMP correlation recurrence
//!
//! The classic formulation recomputes the full ground-set correlation
//! `G·r` every round — an O(n·P) GEMV per *round*.  [`omp_select`]
//! instead uses the Batch-OMP recurrence (Rubinstein et al. 2008): with
//! residual `r = target − Σ_{s∈S} w_s g_s`, linearity gives
//!
//! ```text
//!   G·r  =  G·target − Σ_{s∈S} w_s (G·g_s)  =  c₀ − Σ_s w_s κ_s
//! ```
//!
//! so the solver computes `c₀ = G·target` once, caches the Gram column
//! `κ_s = G·g_s` when atom `s` joins the support, and *reconstructs* the
//! correlation each round from cheap n-space axpys (f64 accumulated, so
//! the reconstruction does not drift from the direct product).
//!
//! ## Cost model (n candidates, P dims, k picks, support size s ≤ k)
//!
//! | per round            | per-round GEMV (seed)    | Batch-OMP              |
//! |----------------------|--------------------------|------------------------|
//! | correlation          | O(n·P) GEMV on `r`       | O(n·s) axpy rebuild    |
//! | new-atom Gram column | —                        | O(n·P) GEMV on `g_new` |
//! | argmax + refit       | O(n) + O(s·P + s²)       | same                   |
//!
//! Totals: seed `O(k·n·P)` with the GEMV paid *every* round (including
//! rounds that skip a numerically dependent atom); Batch-OMP
//! `O(k·n·P + k²·n)` with the GEMV paid once per *accepted* atom and the
//! `k²·n` term negligible while `k ≤ P` (the per-class budgets here).
//! The GEMVs themselves run on the parallel blocked layer
//! ([`crate::par::gemv`]), which is where the wall-clock win lands.  On
//! the XLA path the per-GEMV marshalled operand becomes the fixed atom
//! row `g_new` instead of a fresh residual every round, and skip rounds
//! touch the device not at all.
//!
//! ## Gram-update batching
//!
//! The Cholesky-extend step needs the dots of every support row against
//! the candidate atom (`sel_rows[i]·g_new`, `O(s·P)` per round).  The
//! support is stored as one *growing row-major matrix* (`sel_mat`, rows
//! appended contiguously per accepted atom), so those dots are a single
//! [`crate::par::gemv`] over the support instead of a serial per-row
//! loop — row-parallel once `s·P` crosses the flop floor, and exactly the
//! same per-row `par::dot` arithmetic either way.  Under the selection
//! round's class-level fan-out the GEMV degrades to serial per the
//! [`crate::par`] depth guard, so class tasks never nest spawns.
//!
//! The per-round hot spot stays abstracted behind [`CorrBackend`] so the
//! same solver runs against the XLA/Pallas `corr_chunk` executable (the
//! production path) or the parallel Rust GEMV (per-class slices, tests,
//! benches).  The support re-fit uses an incrementally-extended Cholesky
//! factor: O(k²) per round instead of re-factorizing in O(k³).
//! [`omp_select_ref`] preserves the seed per-round-GEMV solver (with the
//! seed's serial per-row support dots) as the equivalence/benchmark
//! baseline.

use anyhow::{anyhow, Result};

use crate::linalg::CholFactor;
use crate::par;
#[cfg(feature = "xla")]
use crate::runtime::Runtime;
use crate::tensor::{dot, norm2, Matrix};

/// Correlation oracle: `corr(v)[j] = g_j · v` over the whole ground set.
/// Batch-OMP calls it once with the target and once per accepted atom.
pub trait CorrBackend {
    fn corr(&mut self, v: &[f32]) -> Result<Vec<f32>>;
    /// number of candidates
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Rust GEMV backend over a borrowed candidate matrix (row-parallel via
/// the blocked compute layer).
pub struct RustCorr<'a> {
    pub g: &'a Matrix,
}

impl CorrBackend for RustCorr<'_> {
    fn corr(&mut self, v: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.g.rows];
        par::gemv(self.g, v, &mut out);
        Ok(out)
    }

    fn len(&self) -> usize {
        self.g.rows
    }
}

/// XLA backend: the candidate matrix is padded once into fixed-shape
/// chunks and marshalled into input literals **once**; each backend call
/// executes the Pallas `corr_chunk` kernel per chunk with only the fresh
/// operand vector re-marshalled (§Perf: caching the chunk literals
/// removed the dominant per-iteration marshalling cost; device-buffer
/// reuse is not safe with xla_extension 0.5.1 — see `Runtime::exec_ref`).
/// Under Batch-OMP that operand is the fixed atom row `g_new`, once per
/// accepted atom.
#[cfg(feature = "xla")]
pub struct XlaCorr<'a> {
    rt: &'a Runtime,
    model: String,
    chunk_lits: Vec<xla::Literal>,
    /// rows per padded chunk (the model's chunk size)
    rows: usize,
    n: usize,
}

#[cfg(feature = "xla")]
impl<'a> XlaCorr<'a> {
    /// Pad `g` (n×P) into chunk-row blocks for the given model variant.
    pub fn new(rt: &'a Runtime, model: &str, g: &Matrix) -> Result<Self> {
        let meta = rt.model(model)?;
        if g.cols != meta.p {
            return Err(anyhow!(
                "XlaCorr: candidate dim {} != model P {} (per-class slices use RustCorr)",
                g.cols,
                meta.p
            ));
        }
        let rows = meta.chunk;
        let mut chunk_lits = Vec::new();
        let mut i = 0usize;
        while i < g.rows {
            let hi = (i + rows).min(g.rows);
            let mut m = Matrix::zeros(rows, g.cols);
            for (slot, r) in (i..hi).enumerate() {
                m.row_mut(slot).copy_from_slice(g.row(r));
            }
            chunk_lits.push(Runtime::matrix_literal(&m)?);
            i = hi;
        }
        Ok(XlaCorr { rt, model: model.to_string(), chunk_lits, rows, n: g.rows })
    }
}

#[cfg(feature = "xla")]
impl CorrBackend for XlaCorr<'_> {
    fn corr(&mut self, v: &[f32]) -> Result<Vec<f32>> {
        // preallocate at padded capacity and write each chunk's result in
        // place — no grow-reallocations, one truncate to the live rows
        let mut out = vec![0.0f32; self.chunk_lits.len() * self.rows];
        for (ci, lit) in self.chunk_lits.iter().enumerate() {
            let res = self.rt.corr_chunk_lit(&self.model, lit, v)?;
            let take = res.len().min(self.rows);
            out[ci * self.rows..ci * self.rows + take].copy_from_slice(&res[..take]);
        }
        out.truncate(self.n);
        Ok(out)
    }

    fn len(&self) -> usize {
        self.n
    }
}

/// Outcome of one OMP run.
#[derive(Clone, Debug)]
pub struct OmpResult {
    /// selected candidate indices (into the ground set), in pick order
    pub selected: Vec<usize>,
    /// matching weights, aligned with `selected` (non-negative)
    pub weights: Vec<f32>,
    /// final ‖residual‖
    pub residual_norm: f32,
    /// rounds executed
    pub iters: usize,
}

/// OMP configuration.
#[derive(Clone, Copy, Debug)]
pub struct OmpOpts {
    /// budget k (max support size)
    pub k: usize,
    /// ridge regularizer λ (Eq. 1; paper default 0.5)
    pub lambda: f32,
    /// tolerance ε: stop once ‖r‖² + λ‖w‖² ≤ ε
    pub eps: f32,
}

/// Run Algorithm 2 with the Batch-OMP correlation recurrence (see the
/// module docs for the recurrence and cost model).
///
/// `row` must return the gradient row of candidate `j` (used for the
/// support Gram updates and the residual; only selected rows are fetched,
/// so PB/per-class callers can keep the full matrix wherever it lives).
pub fn omp_select(
    backend: &mut dyn CorrBackend,
    row: &dyn Fn(usize) -> Vec<f32>,
    target: &[f32],
    opts: OmpOpts,
) -> Result<OmpResult> {
    let n = backend.len();
    let k = opts.k.min(n);
    let mut selected: Vec<usize> = Vec::with_capacity(k);
    // support rows, stored contiguously row-major so the Cholesky-extend
    // support dots batch through one GEMV (see the module docs)
    let mut sel_mat = Matrix { rows: 0, cols: target.len(), data: Vec::with_capacity(k * target.len()) };
    let mut weights: Vec<f32> = Vec::new();
    let mut taken = vec![false; n];
    let mut chol = CholFactor::empty();
    let mut rhs: Vec<f64> = Vec::with_capacity(k);
    let mut residual = target.to_vec();
    let mut iters = 0usize;

    // Batch-OMP state: c₀ = G·target (computed on first demand so a
    // zero/ε-satisfied target never touches the backend), plus one cached
    // Gram column κ_s = G·g_s per accepted atom.
    let mut c0: Option<Vec<f32>> = None;
    let mut gram_cols: Vec<Vec<f32>> = Vec::with_capacity(k);
    let mut corr = vec![0.0f32; n];
    let mut corr_acc = vec![0.0f64; n];

    while selected.len() < k {
        // E_λ stopping rule (Algorithm 2's `while E_λ(X) ≥ ε`)
        let e_lambda = dot(&residual, &residual)
            + opts.lambda * weights.iter().map(|w| w * w).sum::<f32>();
        if e_lambda <= opts.eps {
            break;
        }
        iters += 1;

        // reconstruct corr = c₀ − Σ_s w_s κ_s (f64 accumulation; s-outer
        // keeps every pass contiguous in memory)
        if c0.is_none() {
            c0 = Some(backend.corr(target)?);
        }
        let c0_ref = c0.as_deref().expect("c0 just initialized");
        for (acc, &c) in corr_acc.iter_mut().zip(c0_ref.iter()) {
            *acc = c as f64;
        }
        for (col, &w) in gram_cols.iter().zip(&weights) {
            let w = w as f64;
            if w != 0.0 {
                for (acc, &kv) in corr_acc.iter_mut().zip(col.iter()) {
                    *acc -= w * kv as f64;
                }
            }
        }
        for (cv, &acc) in corr.iter_mut().zip(corr_acc.iter()) {
            *cv = acc as f32;
        }

        // argmax_j |g_j · r| over un-selected candidates
        let mut best = usize::MAX;
        let mut best_v = 0.0f32;
        for (j, &c) in corr.iter().enumerate() {
            let a = c.abs();
            if !taken[j] && a > best_v {
                best = j;
                best_v = a;
            }
        }
        if best == usize::MAX || best_v <= 1e-12 {
            break; // nothing correlates with the residual
        }
        taken[best] = true;
        let g_new = row(best);

        // extend (G_S G_Sᵀ + λI) Cholesky by the new candidate — the
        // support dots batched as one GEMV over the row-major support
        let mut support_dots = vec![0.0f32; sel_mat.rows];
        par::gemv(&sel_mat, &g_new, &mut support_dots);
        let mut new_row: Vec<f64> = support_dots.iter().map(|&v| v as f64).collect();
        new_row.push(par::dot(&g_new, &g_new) as f64 + opts.lambda as f64);
        if chol.extend(&new_row).is_err() {
            // numerically dependent candidate — skip it and continue (no
            // Gram column cached, no GEMV spent)
            continue;
        }
        rhs.push(par::dot(&g_new, target) as f64);
        selected.push(best);
        // the one GEMV per accepted atom: κ = G·g_new
        gram_cols.push(backend.corr(&g_new)?);
        sel_mat.data.extend_from_slice(&g_new);
        sel_mat.rows += 1;

        // re-fit weights on the grown support, recompute residual
        let w64 = chol.solve(&rhs)?;
        weights = w64.iter().map(|&v| v as f32).collect();
        residual.copy_from_slice(target);
        for (i, &w) in weights.iter().enumerate() {
            crate::tensor::axpy(-w, sel_mat.row(i), &mut residual);
        }
    }

    finish(sel_mat, selected, weights, residual, target, opts, iters)
}

/// Seed solver: the per-round residual GEMV formulation (`corr = G·r`
/// recomputed every round).  Kept as the equivalence baseline — the
/// micro benches and property tests pin [`omp_select`] to it — and as
/// the fallback should a backend ever make residual-space products
/// cheaper than column caching.
pub fn omp_select_ref(
    backend: &mut dyn CorrBackend,
    row: &dyn Fn(usize) -> Vec<f32>,
    target: &[f32],
    opts: OmpOpts,
) -> Result<OmpResult> {
    let n = backend.len();
    let k = opts.k.min(n);
    let mut selected: Vec<usize> = Vec::with_capacity(k);
    // same row-major support storage as the production solver (storage
    // only — the seed's serial per-row support dots are kept below)
    let mut sel_mat = Matrix { rows: 0, cols: target.len(), data: Vec::with_capacity(k * target.len()) };
    let mut weights: Vec<f32> = Vec::new();
    let mut taken = vec![false; n];
    let mut chol = CholFactor::empty();
    let mut rhs: Vec<f64> = Vec::with_capacity(k);
    let mut residual = target.to_vec();
    let mut iters = 0usize;

    while selected.len() < k {
        let e_lambda = dot(&residual, &residual)
            + opts.lambda * weights.iter().map(|w| w * w).sum::<f32>();
        if e_lambda <= opts.eps {
            break;
        }
        iters += 1;

        // the per-round O(n·P) GEMV this module's recurrence eliminates
        let corr = backend.corr(&residual)?;
        let mut best = usize::MAX;
        let mut best_v = 0.0f32;
        for (j, &c) in corr.iter().enumerate() {
            let a = c.abs();
            if !taken[j] && a > best_v {
                best = j;
                best_v = a;
            }
        }
        if best == usize::MAX || best_v <= 1e-12 {
            break;
        }
        taken[best] = true;
        let g_new = row(best);

        // the seed's serial per-row support-dot loop (the batched twin is
        // omp_select's par::gemv — the micro benches compare the two)
        let mut new_row: Vec<f64> =
            (0..sel_mat.rows).map(|i| dot(sel_mat.row(i), &g_new) as f64).collect();
        new_row.push(dot(&g_new, &g_new) as f64 + opts.lambda as f64);
        if chol.extend(&new_row).is_err() {
            continue;
        }
        rhs.push(dot(&g_new, target) as f64);
        selected.push(best);
        sel_mat.data.extend_from_slice(&g_new);
        sel_mat.rows += 1;

        let w64 = chol.solve(&rhs)?;
        weights = w64.iter().map(|&v| v as f32).collect();
        residual.copy_from_slice(target);
        for (i, &w) in weights.iter().enumerate() {
            crate::tensor::axpy(-w, sel_mat.row(i), &mut residual);
        }
    }

    finish(sel_mat, selected, weights, residual, target, opts, iters)
}

/// Shared tail: CORDS-style non-negativity fixup + result assembly.
fn finish(
    sel_mat: Matrix,
    selected: Vec<usize>,
    mut weights: Vec<f32>,
    mut residual: Vec<f32>,
    target: &[f32],
    opts: OmpOpts,
    iters: usize,
) -> Result<OmpResult> {
    if weights.iter().any(|&w| w < 0.0) {
        weights = crate::linalg::ridge_weights_nonneg(&sel_mat, target, opts.lambda)
            .map_err(|e| anyhow!("omp nonneg re-solve: {e}"))?;
        residual.copy_from_slice(target);
        for (i, &w) in weights.iter().enumerate() {
            crate::tensor::axpy(-w, sel_mat.row(i), &mut residual);
        }
    }

    Ok(OmpResult {
        selected,
        weights,
        residual_norm: norm2(&residual),
        iters,
    })
}

/// Convenience: run OMP over an in-memory candidate matrix with RustCorr.
pub fn omp_select_rust(g: &Matrix, target: &[f32], opts: OmpOpts) -> Result<OmpResult> {
    let mut backend = RustCorr { g };
    omp_select(&mut backend, &|j| g.row(j).to_vec(), target, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::forall;

    fn opts(k: usize) -> OmpOpts {
        OmpOpts { k, lambda: 1e-4, eps: 1e-12 }
    }

    #[test]
    fn recovers_sparse_combination_of_orthogonal_rows() {
        // rows = scaled identity; target = 2 e0 + 5 e3
        let mut g = Matrix::zeros(6, 6);
        for i in 0..6 {
            g.set(i, i, 1.0);
        }
        let mut target = vec![0.0f32; 6];
        target[0] = 2.0;
        target[3] = 5.0;
        let r = omp_select_rust(&g, &target, opts(2)).unwrap();
        let mut sel = r.selected.clone();
        sel.sort_unstable();
        assert_eq!(sel, vec![0, 3]);
        assert!(r.residual_norm < 1e-3, "{}", r.residual_norm);
        // weights align with the picks
        for (j, &s) in r.selected.iter().enumerate() {
            let want = if s == 0 { 2.0 } else { 5.0 };
            assert!((r.weights[j] - want).abs() < 0.01, "{:?}", r.weights);
        }
    }

    #[test]
    fn respects_budget() {
        let mut rng = Rng::new(1);
        let g = Matrix::from_vec(50, 8, (0..400).map(|_| rng.gaussian_f32()).collect());
        let target: Vec<f32> = (0..8).map(|_| rng.gaussian_f32()).collect();
        let r = omp_select_rust(&g, &target, opts(5)).unwrap();
        assert!(r.selected.len() <= 5);
        assert_eq!(r.selected.len(), r.weights.len());
    }

    #[test]
    fn no_duplicate_selections() {
        forall(20, |gen| {
            let n = gen.int(3, 30);
            let p = gen.int(2, 10);
            let g = gen.matrix(n, p);
            let target = gen.gauss_vec(p);
            let r = omp_select_rust(&g, &target, opts(n)).unwrap();
            let mut s = r.selected.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), r.selected.len());
        });
    }

    #[test]
    fn weights_nonnegative() {
        forall(30, |gen| {
            let n = gen.int(4, 40);
            let p = gen.int(3, 12);
            let g = gen.matrix(n, p);
            let target = gen.gauss_vec(p);
            let k = gen.int(1, n.min(8));
            let r = omp_select_rust(&g, &target, OmpOpts { k, lambda: 0.5, eps: 1e-12 }).unwrap();
            assert!(r.weights.iter().all(|&w| w >= 0.0), "{:?}", r.weights);
        });
    }

    #[test]
    fn residual_never_exceeds_target_norm_much() {
        // with λ small, fitted residual must not be (meaningfully) worse
        // than the empty solution
        forall(30, |gen| {
            let n = gen.int(4, 30);
            let p = gen.int(2, 10);
            let g = gen.matrix(n, p);
            let target = gen.gauss_vec(p);
            let r = omp_select_rust(&g, &target, opts(n.min(6))).unwrap();
            assert!(r.residual_norm <= norm2(&target) * 1.01 + 1e-4);
        });
    }

    #[test]
    fn larger_budget_fits_at_least_as_well() {
        let mut rng = Rng::new(5);
        let g = Matrix::from_vec(40, 10, (0..400).map(|_| rng.gaussian_f32()).collect());
        let target: Vec<f32> = (0..10).map(|_| rng.gaussian_f32()).collect();
        let r2 = omp_select_rust(&g, &target, opts(2)).unwrap();
        let r8 = omp_select_rust(&g, &target, opts(8)).unwrap();
        assert!(r8.residual_norm <= r2.residual_norm + 1e-4);
    }

    #[test]
    fn eps_stopping_selects_fewer() {
        let mut g = Matrix::zeros(4, 4);
        for i in 0..4 {
            g.set(i, i, 1.0);
        }
        let target = [10.0f32, 0.01, 0.0, 0.0];
        // generous eps: should stop after the big coordinate is matched
        let r = omp_select_rust(
            &g,
            &target,
            OmpOpts { k: 4, lambda: 1e-6, eps: 0.01 },
        )
        .unwrap();
        assert_eq!(r.selected, vec![0]);
    }

    #[test]
    fn zero_target_selects_nothing() {
        let mut rng = Rng::new(6);
        let g = Matrix::from_vec(10, 5, (0..50).map(|_| rng.gaussian_f32()).collect());
        let r = omp_select_rust(&g, &[0.0; 5], opts(5)).unwrap();
        assert!(r.selected.is_empty());
        assert_eq!(r.residual_norm, 0.0);
    }

    #[test]
    fn duplicate_rows_are_skippable() {
        // ground set of identical rows: OMP must not crash on the singular
        // support; one row suffices
        let g = Matrix::from_vec(5, 3, vec![1.0, 2.0, 3.0].repeat(5));
        let target = [2.0f32, 4.0, 6.0];
        let r = omp_select_rust(&g, &target, opts(5)).unwrap();
        assert!(r.residual_norm < 1e-2, "{}", r.residual_norm);
        assert!(!r.selected.is_empty());
    }

    #[test]
    fn lambda_extremes_fig4g_semantics() {
        // Fig. 4g: λ=0 is allowed and fits tightly on an easy problem;
        // huge λ crushes the weights so the fit degenerates toward the
        // empty solution — both ends of the paper's λ sweep.
        let mut rng = Rng::new(7);
        let g = Matrix::from_vec(20, 6, (0..120).map(|_| rng.gaussian_f32()).collect());
        // target is a positive combination of rows, so it is representable
        // under the non-negative weight constraint
        let mut target = vec![0.0f32; 6];
        for i in [1usize, 4, 9] {
            crate::tensor::axpy(0.5 + i as f32 * 0.2, g.row(i), &mut target);
        }
        // λ=0 must run without error and beat the empty fit (the greedy
        // support under the non-negativity constraint need not be exact)
        let r0 = omp_select_rust(&g, &target, OmpOpts { k: 8, lambda: 0.0, eps: 1e-12 }).unwrap();
        assert!(r0.residual_norm < 0.75 * norm2(&target), "{}", r0.residual_norm);
        let rbig =
            omp_select_rust(&g, &target, OmpOpts { k: 8, lambda: 1e6, eps: 1e-12 }).unwrap();
        assert!(rbig.residual_norm > 0.9 * norm2(&target), "{}", rbig.residual_norm);
        let wnorm: f32 = rbig.weights.iter().map(|w| w * w).sum::<f32>().sqrt();
        assert!(wnorm < 1e-2, "weights should be crushed: {wnorm}");
    }

    /// Backend wrapper counting GEMV (corr) calls.
    struct Counting<'a> {
        inner: RustCorr<'a>,
        calls: usize,
    }

    impl CorrBackend for Counting<'_> {
        fn corr(&mut self, v: &[f32]) -> Result<Vec<f32>> {
            self.calls += 1;
            self.inner.corr(v)
        }
        fn len(&self) -> usize {
            self.inner.len()
        }
    }

    // --- Batch-OMP ≡ seed solver -----------------------------------------

    #[test]
    fn batch_omp_equals_reference_solver() {
        // same supports (in pick order), residual norms within 1e-4, and
        // matching weights — across shapes, budgets, and the λ sweep ends
        forall(40, |gen| {
            let n = gen.int(3, 50);
            let p = gen.int(2, 20);
            let g = gen.matrix(n, p);
            let target = gen.gauss_vec(p);
            let k = gen.int(1, n);
            for lambda in [0.0f32, 1e-4, 0.5] {
                let o = OmpOpts { k, lambda, eps: 1e-12 };
                let new = omp_select_rust(&g, &target, o).unwrap();
                let mut backend = RustCorr { g: &g };
                let old =
                    omp_select_ref(&mut backend, &|j| g.row(j).to_vec(), &target, o).unwrap();
                assert_eq!(new.selected, old.selected, "support λ={lambda} n={n} p={p} k={k}");
                assert!(
                    (new.residual_norm - old.residual_norm).abs()
                        <= 1e-4 * (1.0 + old.residual_norm),
                    "residual λ={lambda}: {} vs {}",
                    new.residual_norm,
                    old.residual_norm
                );
                for (a, b) in new.weights.iter().zip(&old.weights) {
                    assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "weights {a} vs {b}");
                }
            }
        });
    }

    #[test]
    fn batch_omp_equals_reference_on_degenerate_supports() {
        // duplicate rows force Cholesky-extend skips; both solvers must
        // walk the identical skip sequence
        let g = Matrix::from_vec(6, 3, vec![1.0, 2.0, 3.0].repeat(6));
        let target = [2.0f32, 4.0, 6.0];
        let o = opts(6);
        let new = omp_select_rust(&g, &target, o).unwrap();
        let mut backend = RustCorr { g: &g };
        let old = omp_select_ref(&mut backend, &|j| g.row(j).to_vec(), &target, o).unwrap();
        assert_eq!(new.selected, old.selected);
        assert_eq!(new.iters, old.iters);
        assert!((new.residual_norm - old.residual_norm).abs() <= 1e-4);
    }

    #[test]
    fn zero_target_never_calls_the_backend() {
        // c₀ is demand-computed: an ε-satisfied start must cost 0 GEMVs
        let mut rng = Rng::new(8);
        let g = Matrix::from_vec(10, 5, (0..50).map(|_| rng.gaussian_f32()).collect());
        let mut backend = Counting { inner: RustCorr { g: &g }, calls: 0 };
        let r = omp_select(&mut backend, &|j| g.row(j).to_vec(), &[0.0; 5], opts(5)).unwrap();
        assert!(r.selected.is_empty());
        assert_eq!(backend.calls, 0);
    }

    #[test]
    fn gemv_count_is_one_per_accepted_atom_plus_target() {
        let mut rng = Rng::new(9);
        let g = Matrix::from_vec(40, 12, (0..480).map(|_| rng.gaussian_f32()).collect());
        let target: Vec<f32> = (0..12).map(|_| rng.gaussian_f32()).collect();
        let mut backend = Counting { inner: RustCorr { g: &g }, calls: 0 };
        let r = omp_select(&mut backend, &|j| g.row(j).to_vec(), &target, opts(8)).unwrap();
        assert_eq!(backend.calls, r.selected.len() + 1, "c₀ + one κ per atom");
    }
}
