//! Selection-as-a-service: a multi-tenant daemon in front of the engine.
//!
//! The ROADMAP's north star is serving GRAD-MATCH selection to many
//! concurrent training runs; MILO (PAPERS.md) argues the selection step
//! should be decoupled from any one training job precisely so it can be
//! amortized as a service.  This module is that client-facing layer, with
//! **robustness as the contract**:
//!
//! - **Engine pool** — per-run [`PooledEngine`]s keyed by run id, LRU-evicted
//!   past a capacity bound.  A run's engine is checked *out* while its round
//!   runs, so one run's rounds can never race (ordering within a run holds),
//!   while independent runs fan out over [`par::map_tasks`].
//! - **Backpressure** — a bounded request queue.  Admission counts queued +
//!   in-flight rounds; past the bound a request is *shed* with a typed
//!   `overloaded` response immediately, never queued unboundedly.
//! - **Deadlines** — every select carries a deadline.  A job that expires
//!   before dispatch is skipped; a round that outlives its budget gets a
//!   typed `deadline_exceeded` reply while the late result is discarded.
//!   The accept loop never stalls on a slow round.
//! - **Isolation** — a malformed payload (see [`crate::jsonlite`]'s hostile
//!   corpus), an oversized line, a slow writer, or a mid-round disconnect
//!   poisons only that connection.  Worker panics are caught and surfaced
//!   as typed `internal` errors; the daemon stays up.
//! - **Cross-arm memoization** — a daemon-wide [`SelectionCache`] keyed by
//!   (tenant dataset fingerprint, strategy, round signature).  Two tenants
//!   (or two sweep arms) issuing signature-identical rounds pay ONE solve:
//!   the second is replayed with zero staging dispatches and never touches
//!   the engine pool.  Bounded LRU (`--selection-cache-cap`, 0 disables);
//!   depth + hit counters surface in `stats`.
//! - **Graceful drain** — SIGTERM/SIGINT or a `shutdown` request stops
//!   admission, finishes every in-flight round, flushes a final stats line,
//!   and returns the run's [`DaemonStats`].
//! - **Observability** — a `stats` request exposes queue depth, in-flight
//!   rounds, per-rung [`Degradation`] counts, and every shed/deadline/error
//!   counter.
//!
//! PR 6's fault layer plugs in underneath: with a [`FaultPlan`]
//! (`serve --fault-plan`), every pooled engine's oracle is wrapped in a
//! [`FaultyOracle`], so the stress bench drives outages through the full
//! daemon path and watches the degradation ladder from the outside.
//!
//! # Wire protocol
//!
//! Line-delimited JSON over a unix or tcp socket; one request per line, one
//! response line per request, in order.  Requests:
//!
//! ```text
//! {"type":"ping"}
//! {"type":"stats"}
//! {"type":"shutdown"}
//! {"type":"select","run_id":"r1","dataset":"synmnist","n_train":256,
//!  "chunk":64,"h":8,"data_seed":"0","deadline_ms":30000,
//!  "request":{ ...SelectionRequest::to_json... }}
//! ```
//!
//! Responses: `{"type":"pong"}`, `{"type":"stats",...}`,
//! `{"type":"ok","draining":true}`,
//! `{"type":"report","run_id":...,"report":{...},"queue_ms":...,"round_ms":...}`,
//! and typed errors `{"type":"error","code":C,"msg":...}` with `C` one of
//! `bad_request` | `overloaded` | `deadline_exceeded` | `shutting_down` |
//! `oversized` | `slow_client` | `internal`.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::DatasetCard;
use crate::engine::{scope_fingerprint, Degradation, PooledEngine, SelectionCache, SelectionRequest};
use crate::fault::{FaultPlan, FaultyOracle};
use crate::grads::GradOracle;
use crate::grads::SynthGrads;
use crate::jsonlite::{num, obj, s, Json};
use crate::par;

// ---------------------------------------------------------------------------
// Options and addressing
// ---------------------------------------------------------------------------

/// Where the daemon listens.
#[derive(Clone, Debug)]
pub enum Bind {
    /// unix-domain socket at this path (created on bind, removed on drain)
    Unix(PathBuf),
    /// tcp address, e.g. `127.0.0.1:7878`
    Tcp(String),
}

/// Daemon configuration (all bounds have safe defaults).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub bind: Bind,
    /// admission bound: queued + in-flight selects past this are shed with
    /// a typed `overloaded` response
    pub queue_cap: usize,
    /// pooled per-run engines kept alive (LRU eviction past this)
    pub engine_cap: usize,
    /// concurrent client connections; later connects get `overloaded`
    pub max_conns: usize,
    /// deadline applied to selects that do not carry `deadline_ms`
    pub default_deadline_ms: u64,
    /// request lines longer than this are rejected (`oversized`) and the
    /// connection closed
    pub max_request_bytes: usize,
    /// per-read socket timeout shedding slow/stalled writers (0 = off)
    pub read_timeout_ms: u64,
    /// daemon-wide cross-arm selection cache: memoized rounds kept (LRU).
    /// 0 disables memoization entirely
    pub selection_cache_cap: usize,
    /// wrap every pooled engine's oracle in a [`FaultyOracle`] with this
    /// plan (the stress bench's outage path)
    pub fault_plan: Option<FaultPlan>,
    /// install SIGTERM/SIGINT handlers that trigger a graceful drain
    /// (process-wide; in-process tests leave this off)
    pub install_signal_handlers: bool,
}

impl ServeOpts {
    /// Defaults for the given address.
    pub fn new(bind: Bind) -> ServeOpts {
        ServeOpts {
            bind,
            queue_cap: 64,
            engine_cap: 8,
            max_conns: 64,
            default_deadline_ms: 30_000,
            max_request_bytes: 1 << 20,
            read_timeout_ms: 30_000,
            selection_cache_cap: 256,
            fault_plan: None,
            install_signal_handlers: false,
        }
    }
}

/// A per-process-unique unix-socket path under the temp dir (smoke mode and
/// the test/bench suites bind here).
pub fn ephemeral_socket_path(tag: &str) -> PathBuf {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let n = COUNTER.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "gradmatch-daemon-{}-{}-{}.sock",
        std::process::id(),
        tag,
        n
    ))
}

// ---------------------------------------------------------------------------
// Listener / stream abstraction (unix or tcp)
// ---------------------------------------------------------------------------

enum Listener {
    Unix(UnixListener, PathBuf),
    Tcp(TcpListener),
}

impl Listener {
    fn bind(bind: &Bind) -> Result<Listener> {
        match bind {
            Bind::Unix(path) => {
                // a stale socket file from a crashed daemon must not block
                // restart
                let _ = std::fs::remove_file(path);
                let l = UnixListener::bind(path)
                    .map_err(|e| anyhow!("binding unix socket {}: {e}", path.display()))?;
                l.set_nonblocking(true)?;
                Ok(Listener::Unix(l, path.clone()))
            }
            Bind::Tcp(addr) => {
                let l = TcpListener::bind(addr)
                    .map_err(|e| anyhow!("binding tcp {addr}: {e}"))?;
                l.set_nonblocking(true)?;
                Ok(Listener::Tcp(l))
            }
        }
    }

    fn accept(&self) -> std::io::Result<Stream> {
        match self {
            Listener::Unix(l, _) => l.accept().map(|(st, _)| Stream::Unix(st)),
            Listener::Tcp(l) => l.accept().map(|(st, _)| Stream::Tcp(st)),
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// A connected client stream (unix or tcp), blocking mode.
pub enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            Stream::Unix(st) => st.try_clone().map(Stream::Unix),
            Stream::Tcp(st) => st.try_clone().map(Stream::Tcp),
        }
    }

    fn set_timeouts(&self, dur: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Unix(st) => {
                st.set_read_timeout(dur)?;
                st.set_write_timeout(dur)
            }
            Stream::Tcp(st) => {
                st.set_read_timeout(dur)?;
                st.set_write_timeout(dur)
            }
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Unix(st) => st.set_nonblocking(nb),
            Stream::Tcp(st) => st.set_nonblocking(nb),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(st) => st.read(buf),
            Stream::Tcp(st) => st.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(st) => st.write(buf),
            Stream::Tcp(st) => st.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Unix(st) => st.flush(),
            Stream::Tcp(st) => st.flush(),
        }
    }
}

fn write_line(w: &mut impl Write, j: &Json) -> std::io::Result<()> {
    let mut line = j.dump();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

fn error_json(code: &str, msg: &str) -> Json {
    obj(vec![
        ("type", s("error")),
        ("code", s(code)),
        ("msg", s(msg)),
    ])
}

// ---------------------------------------------------------------------------
// Per-run engine pool
// ---------------------------------------------------------------------------

/// The dataset/oracle fingerprint of one tenant run.  A `select` naming an
/// existing run id with a different fingerprint rebuilds that run's engine
/// (config change), it never silently serves the old one.
#[derive(Clone, Debug, PartialEq, Eq)]
struct RunCfg {
    dataset: String,
    n_train: usize,
    chunk: usize,
    h: usize,
    data_seed: u64,
}

struct RunSlot {
    engine: PooledEngine,
    cfg: RunCfg,
    /// rounds served by this engine (reset_round between them)
    rounds: u64,
    last_used: u64,
}

struct EnginePool {
    cap: usize,
    tick: u64,
    slots: HashMap<String, RunSlot>,
}

// ---------------------------------------------------------------------------
// Daemon state
// ---------------------------------------------------------------------------

struct Job {
    run_id: String,
    cfg: RunCfg,
    req: SelectionRequest,
    deadline: Instant,
    enqueued: Instant,
    resp: mpsc::Sender<Json>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    /// admitted selects not yet answered (queued + in flight) — the
    /// admission bound counts this, so draining the queue into a dispatch
    /// batch cannot defeat backpressure
    outstanding: usize,
    draining: bool,
}

#[derive(Default)]
struct Counters {
    rounds_served: AtomicU64,
    shed_overloaded: AtomicU64,
    shed_shutting_down: AtomicU64,
    deadline_replies: AtomicU64,
    deadline_skipped: AtomicU64,
    bad_requests: AtomicU64,
    oversized: AtomicU64,
    read_timeouts: AtomicU64,
    internal_errors: AtomicU64,
    dropped_replies: AtomicU64,
    conns_opened: AtomicU64,
    conns_rejected: AtomicU64,
    engines_built: AtomicU64,
    engines_evicted: AtomicU64,
    retries: AtomicU64,
    quarantined: AtomicU64,
    deg_none: AtomicU64,
    deg_reused: AtomicU64,
    deg_random: AtomicU64,
}

/// Final (or point-in-time) daemon statistics — what the `stats` request
/// serializes and what [`serve`] returns after the drain.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DaemonStats {
    pub queue_depth: u64,
    pub inflight_rounds: u64,
    pub engines_pooled: u64,
    pub draining: bool,
    pub rounds_served: u64,
    pub shed_overloaded: u64,
    pub shed_shutting_down: u64,
    /// typed `deadline_exceeded` replies (round outlived its budget)
    pub deadline_replies: u64,
    /// jobs that expired in the queue and were skipped unstarted
    pub deadline_skipped: u64,
    pub bad_requests: u64,
    pub oversized: u64,
    pub read_timeouts: u64,
    pub internal_errors: u64,
    /// round results whose client had already given up or vanished
    pub dropped_replies: u64,
    pub conns_opened: u64,
    pub conns_rejected: u64,
    pub engines_built: u64,
    pub engines_evicted: u64,
    pub retries: u64,
    pub quarantined: u64,
    /// memoized rounds currently held by the cross-arm selection cache
    pub cache_depth: u64,
    /// rounds served straight from the cache (zero staging dispatches)
    pub cache_hits: u64,
    /// clean solves memoized for later signature-identical rounds
    pub cache_stores: u64,
    /// per-rung degradation counts: [none, reused-last-round, random-fallback]
    pub degradation: [u64; 3],
}

impl DaemonStats {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("type", s("stats")),
            ("queue_depth", num(self.queue_depth as f64)),
            ("inflight_rounds", num(self.inflight_rounds as f64)),
            ("engines_pooled", num(self.engines_pooled as f64)),
            ("draining", Json::Bool(self.draining)),
            ("rounds_served", num(self.rounds_served as f64)),
            ("shed_overloaded", num(self.shed_overloaded as f64)),
            ("shed_shutting_down", num(self.shed_shutting_down as f64)),
            ("deadline_replies", num(self.deadline_replies as f64)),
            ("deadline_skipped", num(self.deadline_skipped as f64)),
            ("bad_requests", num(self.bad_requests as f64)),
            ("oversized", num(self.oversized as f64)),
            ("read_timeouts", num(self.read_timeouts as f64)),
            ("internal_errors", num(self.internal_errors as f64)),
            ("dropped_replies", num(self.dropped_replies as f64)),
            ("conns_opened", num(self.conns_opened as f64)),
            ("conns_rejected", num(self.conns_rejected as f64)),
            ("engines_built", num(self.engines_built as f64)),
            ("engines_evicted", num(self.engines_evicted as f64)),
            ("retries", num(self.retries as f64)),
            ("quarantined", num(self.quarantined as f64)),
            ("cache_depth", num(self.cache_depth as f64)),
            ("cache_hits", num(self.cache_hits as f64)),
            ("cache_stores", num(self.cache_stores as f64)),
            (
                "degradation",
                obj(vec![
                    (Degradation::None.as_str(), num(self.degradation[0] as f64)),
                    (Degradation::ReusedLastRound.as_str(), num(self.degradation[1] as f64)),
                    (Degradation::RandomFallback.as_str(), num(self.degradation[2] as f64)),
                ]),
            ),
        ])
    }
}

struct Daemon {
    queue: Mutex<QueueState>,
    queue_cv: Condvar,
    pool: Mutex<EnginePool>,
    /// cross-arm round memoization, daemon-wide (scoped per tenant
    /// fingerprint inside the key, so tenants never cross-contaminate)
    sel_cache: SelectionCache,
    stats: Counters,
    shutdown: AtomicBool,
    opts: ServeOpts,
}

/// The cache scope of one tenant configuration: every RunCfg field folds in,
/// so two tenants share memoized rounds only when their synthetic dataset —
/// and hence their staged gradients — are bit-identical.
fn run_scope(cfg: &RunCfg) -> u64 {
    scope_fingerprint(
        &cfg.dataset,
        &[cfg.n_train as u64, cfg.chunk as u64, cfg.h as u64, cfg.data_seed],
    )
}

impl Daemon {
    fn new(opts: ServeOpts) -> Daemon {
        Daemon {
            queue: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                outstanding: 0,
                draining: false,
            }),
            queue_cv: Condvar::new(),
            pool: Mutex::new(EnginePool {
                cap: opts.engine_cap.max(1),
                tick: 0,
                slots: HashMap::new(),
            }),
            sel_cache: SelectionCache::new(opts.selection_cache_cap),
            stats: Counters::default(),
            shutdown: AtomicBool::new(false),
            opts,
        }
    }

    /// Begin the graceful drain: reject new selects, let the dispatcher
    /// finish what is queued, wake everything that waits.
    fn begin_shutdown(&self) {
        {
            let mut q = self.queue.lock().unwrap();
            q.draining = true;
        }
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue_cv.notify_all();
    }

    fn snapshot(&self) -> DaemonStats {
        let (queued, outstanding, draining) = {
            let q = self.queue.lock().unwrap();
            (q.jobs.len() as u64, q.outstanding as u64, q.draining)
        };
        let pooled = self.pool.lock().unwrap().slots.len() as u64;
        let (cache_depth, cache_hits, cache_stores, _evictions) = self.sel_cache.stats();
        let c = &self.stats;
        let get = |a: &AtomicU64| a.load(Ordering::Relaxed);
        DaemonStats {
            queue_depth: queued,
            inflight_rounds: outstanding.saturating_sub(queued),
            engines_pooled: pooled,
            draining,
            rounds_served: get(&c.rounds_served),
            shed_overloaded: get(&c.shed_overloaded),
            shed_shutting_down: get(&c.shed_shutting_down),
            deadline_replies: get(&c.deadline_replies),
            deadline_skipped: get(&c.deadline_skipped),
            bad_requests: get(&c.bad_requests),
            oversized: get(&c.oversized),
            read_timeouts: get(&c.read_timeouts),
            internal_errors: get(&c.internal_errors),
            dropped_replies: get(&c.dropped_replies),
            conns_opened: get(&c.conns_opened),
            conns_rejected: get(&c.conns_rejected),
            engines_built: get(&c.engines_built),
            engines_evicted: get(&c.engines_evicted),
            retries: get(&c.retries),
            quarantined: get(&c.quarantined),
            cache_depth: cache_depth as u64,
            cache_hits,
            cache_stores,
            degradation: [get(&c.deg_none), get(&c.deg_reused), get(&c.deg_random)],
        }
    }

    // -- engine pool --------------------------------------------------------

    /// Take the run's engine out of the pool (building it on first sight or
    /// on a fingerprint change).  While checked out, no other worker can
    /// touch this run — one run's rounds stay ordered.
    fn checkout(&self, run_id: &str, cfg: &RunCfg) -> Result<RunSlot> {
        let prev = {
            let mut pool = self.pool.lock().unwrap();
            pool.slots.remove(run_id)
        };
        if let Some(slot) = prev {
            if slot.cfg == *cfg {
                return Ok(slot);
            }
            // same tenant, new fingerprint: rebuild below
            self.stats.engines_evicted.fetch_add(1, Ordering::Relaxed);
        }
        // build outside the pool lock — dataset generation is not free and
        // must not block other runs' checkouts
        let engine = self.build_engine(cfg)?;
        self.stats.engines_built.fetch_add(1, Ordering::Relaxed);
        Ok(RunSlot {
            engine,
            cfg: cfg.clone(),
            rounds: 0,
            last_used: 0,
        })
    }

    fn checkin(&self, run_id: String, mut slot: RunSlot) {
        let mut pool = self.pool.lock().unwrap();
        pool.tick += 1;
        slot.last_used = pool.tick;
        pool.slots.insert(run_id, slot);
        while pool.slots.len() > pool.cap {
            let victim = pool
                .slots
                .iter()
                .min_by_key(|(_, sl)| sl.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    pool.slots.remove(&k);
                    self.stats.engines_evicted.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
    }

    fn build_engine(&self, cfg: &RunCfg) -> Result<PooledEngine> {
        let card = DatasetCard::by_name(&cfg.dataset).ok_or_else(|| {
            anyhow!(
                "unknown dataset card '{}' (`gradmatch inspect` lists the catalog)",
                cfg.dataset
            )
        })?;
        let c = card.classes;
        let p = cfg.h * c + c;
        let splits = card.generate(cfg.data_seed, cfg.n_train);
        let synth = SynthGrads::new(cfg.chunk, p);
        let oracle: Box<dyn GradOracle + Send> = match self.opts.fault_plan {
            Some(plan) => Box::new(FaultyOracle::new(synth, plan)),
            None => Box::new(synth),
        };
        PooledEngine::new(oracle, Arc::new(splits.train), Arc::new(splits.val), cfg.h, c)
    }

    // -- the worker side ----------------------------------------------------

    /// Run one admitted job to completion and answer its client.  Never
    /// panics outward: a panicking round is caught and surfaced as a typed
    /// `internal` error (that run's engine is dropped; the next request
    /// rebuilds it).
    fn process(&self, job: &Job) {
        let response = if Instant::now() >= job.deadline {
            self.stats.deadline_skipped.fetch_add(1, Ordering::Relaxed);
            error_json(
                "deadline_exceeded",
                "round deadline expired while queued; skipped unstarted",
            )
        } else {
            let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                self.round(job)
            }));
            match caught {
                Ok(Ok(resp)) => resp,
                Ok(Err(e)) => {
                    self.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                    error_json("bad_request", &format!("{e:#}"))
                }
                Err(_) => {
                    self.stats.internal_errors.fetch_add(1, Ordering::Relaxed);
                    error_json("internal", "selection round panicked; engine discarded")
                }
            }
        };
        if job.resp.send(response).is_err() {
            // client gave up (deadline) or vanished — the daemon is fine
            self.stats.dropped_replies.fetch_add(1, Ordering::Relaxed);
        }
        let mut q = self.queue.lock().unwrap();
        q.outstanding = q.outstanding.saturating_sub(1);
    }

    fn round(&self, job: &Job) -> Result<Json> {
        let queue_ms = job.enqueued.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        // cache consult happens BEFORE the checkout: a signature-identical
        // round served earlier is replayed without touching the engine pool
        let report = self.sel_cache.round(run_scope(&job.cfg), &job.req, || {
            let mut slot = self.checkout(&job.run_id, &job.cfg)?;
            if slot.rounds > 0 {
                slot.engine.reset_round();
            }
            let solved = slot.engine.select(&job.req);
            if solved.is_ok() {
                slot.rounds += 1;
            }
            // an unknown strategy spec etc. leaves the engine healthy: keep
            // it pooled even on error.  A panicking round unwinds past this
            // checkin and drops the slot — that engine IS discarded.
            self.checkin(job.run_id.clone(), slot);
            solved
        })?;
        let c = &self.stats;
        // cache hits still count as served rounds — the client got a report
        c.rounds_served.fetch_add(1, Ordering::Relaxed);
        c.retries.fetch_add(report.stats.retries as u64, Ordering::Relaxed);
        c.quarantined.fetch_add(report.stats.quarantined as u64, Ordering::Relaxed);
        match report.stats.degradation {
            Degradation::None => c.deg_none.fetch_add(1, Ordering::Relaxed),
            Degradation::ReusedLastRound => c.deg_reused.fetch_add(1, Ordering::Relaxed),
            Degradation::RandomFallback => c.deg_random.fetch_add(1, Ordering::Relaxed),
        };
        Ok(obj(vec![
            ("type", s("report")),
            ("run_id", s(&job.run_id)),
            ("report", report.to_json()),
            ("queue_ms", num(queue_ms)),
            ("round_ms", num(t0.elapsed().as_secs_f64() * 1e3)),
        ]))
    }
}

/// The dispatcher: drains the queue in batches, groups jobs by run id
/// (stable order → one run's rounds execute in arrival order), and fans
/// independent runs out over [`par::map_tasks`].  Returns only when the
/// daemon is draining AND the queue is empty — i.e. after every admitted
/// round has been answered.
fn dispatcher(d: &Daemon) {
    loop {
        let batch: Vec<Job> = {
            let mut q = d.queue.lock().unwrap();
            loop {
                if !q.jobs.is_empty() {
                    break;
                }
                if d.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let (guard, _) = d
                    .queue_cv
                    .wait_timeout(q, Duration::from_millis(100))
                    .unwrap();
                q = guard;
            }
            q.jobs.drain(..).collect()
        };
        // group by run id, preserving arrival order within and across runs
        let mut order: Vec<String> = Vec::new();
        let mut groups: HashMap<String, Vec<Job>> = HashMap::new();
        for job in batch {
            if !groups.contains_key(&job.run_id) {
                order.push(job.run_id.clone());
            }
            groups.entry(job.run_id.clone()).or_default().push(job);
        }
        let tasks: Vec<Mutex<Option<Vec<Job>>>> = order
            .iter()
            .map(|rid| Mutex::new(groups.remove(rid)))
            .collect();
        par::map_tasks(&tasks, |cell| {
            let jobs = cell.lock().unwrap().take();
            if let Some(jobs) = jobs {
                for job in &jobs {
                    d.process(job);
                }
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

// -- small jsonlite field readers (daemon envelope) -------------------------

fn field_str(j: &Json, k: &str) -> Option<String> {
    j.get(k).and_then(Json::as_str).map(str::to_string)
}

fn field_usize(j: &Json, k: &str, default: usize) -> Result<usize> {
    match j.get(k) {
        None => Ok(default),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| anyhow!("field '{k}' must be a non-negative integer")),
    }
}

fn field_u64(j: &Json, k: &str, default: u64) -> Result<u64> {
    match j.get(k) {
        None => Ok(default),
        Some(Json::Str(v)) => v.parse::<u64>().map_err(|e| anyhow!("field '{k}': {e}")),
        Some(v) => v
            .as_usize()
            .map(|u| u as u64)
            .ok_or_else(|| anyhow!("field '{k}' must be an integer or decimal string")),
    }
}

/// Parse + validate one select envelope into an admissible job skeleton.
fn parse_select(j: &Json, default_deadline_ms: u64) -> Result<(String, RunCfg, SelectionRequest, Duration)> {
    let run_id = field_str(j, "run_id").ok_or_else(|| anyhow!("select: missing 'run_id'"))?;
    if run_id.is_empty() || run_id.len() > 128 {
        return Err(anyhow!("select: 'run_id' must be 1..=128 bytes"));
    }
    let cfg = RunCfg {
        dataset: field_str(j, "dataset").unwrap_or_else(|| "synmnist".to_string()),
        n_train: field_usize(j, "n_train", 256)?,
        chunk: field_usize(j, "chunk", 64)?,
        h: field_usize(j, "h", 8)?,
        data_seed: field_u64(j, "data_seed", 0)?,
    };
    if cfg.chunk == 0 || cfg.chunk > 4096 {
        return Err(anyhow!("select: 'chunk' must be in 1..=4096"));
    }
    if cfg.h == 0 || cfg.h > 1024 {
        return Err(anyhow!("select: 'h' must be in 1..=1024"));
    }
    if cfg.n_train == 0 || cfg.n_train > 100_000 {
        return Err(anyhow!("select: 'n_train' must be in 1..=100000"));
    }
    let req = SelectionRequest::from_json(
        j.get("request")
            .ok_or_else(|| anyhow!("select: missing 'request'"))?,
    )?;
    if req.ground.is_empty() {
        return Err(anyhow!("select: empty ground set"));
    }
    if req.ground.len() > cfg.n_train {
        return Err(anyhow!("select: ground set larger than the dataset"));
    }
    if let Some(&bad) = req.ground.iter().find(|&&i| i >= cfg.n_train) {
        return Err(anyhow!(
            "select: ground index {bad} out of range (n_train {})",
            cfg.n_train
        ));
    }
    if req.budget == 0 {
        return Err(anyhow!("select: budget must be >= 1"));
    }
    let deadline_ms = field_u64(j, "deadline_ms", default_deadline_ms)?;
    let deadline = Duration::from_millis(deadline_ms.clamp(1, 3_600_000));
    Ok((run_id, cfg, req, deadline))
}

/// Serve one connection until EOF, a fatal read error, or an
/// oversized/stalled request.  Every failure mode answers (when possible)
/// with a typed error and affects only this connection.
fn handle_conn(d: &Arc<Daemon>, stream: Stream) {
    let read_timeout = match d.opts.read_timeout_ms {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    let _ = stream.set_timeouts(read_timeout);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let max = d.opts.max_request_bytes;
    loop {
        let mut line: Vec<u8> = Vec::new();
        let got = (&mut reader).take(max as u64 + 1).read_until(b'\n', &mut line);
        match got {
            Ok(0) => return, // clean EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                d.stats.read_timeouts.fetch_add(1, Ordering::Relaxed);
                let _ = write_line(
                    &mut writer,
                    &error_json("slow_client", "read timed out; closing connection"),
                );
                return;
            }
            Err(_) => return,
        }
        if line.len() > max {
            d.stats.oversized.fetch_add(1, Ordering::Relaxed);
            let _ = write_line(
                &mut writer,
                &error_json("oversized", &format!("request exceeds {max} bytes")),
            );
            return; // the rest of the oversized line is unreadable garbage
        }
        let text = match std::str::from_utf8(&line) {
            Ok(t) => t.trim(),
            Err(_) => {
                d.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                let _ = write_line(&mut writer, &error_json("bad_request", "invalid utf-8"));
                continue;
            }
        };
        if text.is_empty() {
            continue;
        }
        let parsed = match Json::parse(text) {
            Ok(j) => j,
            Err(e) => {
                d.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                let _ = write_line(&mut writer, &error_json("bad_request", &e.to_string()));
                continue;
            }
        };
        match parsed.get("type").and_then(Json::as_str) {
            Some("ping") => {
                let _ = write_line(&mut writer, &obj(vec![("type", s("pong"))]));
            }
            Some("stats") => {
                let _ = write_line(&mut writer, &d.snapshot().to_json());
            }
            Some("shutdown") => {
                d.begin_shutdown();
                let _ = write_line(
                    &mut writer,
                    &obj(vec![("type", s("ok")), ("draining", Json::Bool(true))]),
                );
            }
            Some("select") => {
                let resp = handle_select(d, &parsed);
                if write_line(&mut writer, &resp).is_err() {
                    return; // client vanished; nothing else to do
                }
            }
            _ => {
                d.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
                let _ = write_line(
                    &mut writer,
                    &error_json("bad_request", "unknown or missing 'type'"),
                );
            }
        }
    }
}

/// Admit (or shed) one select and wait — deadline-bounded — for its reply.
fn handle_select(d: &Arc<Daemon>, j: &Json) -> Json {
    let (run_id, cfg, req, deadline) = match parse_select(j, d.opts.default_deadline_ms) {
        Ok(parts) => parts,
        Err(e) => {
            d.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
            return error_json("bad_request", &format!("{e:#}"));
        }
    };
    let (tx, rx) = mpsc::channel::<Json>();
    {
        let mut q = d.queue.lock().unwrap();
        if q.draining || d.shutdown.load(Ordering::SeqCst) {
            d.stats.shed_shutting_down.fetch_add(1, Ordering::Relaxed);
            return error_json("shutting_down", "daemon is draining; not accepting rounds");
        }
        if q.outstanding >= d.opts.queue_cap {
            // backpressure: shed NOW with a typed response — the client
            // learns in O(1) that it must retry/back off, instead of
            // queueing unboundedly behind everyone else
            d.stats.shed_overloaded.fetch_add(1, Ordering::Relaxed);
            return error_json(
                "overloaded",
                &format!(
                    "queue full ({} outstanding rounds >= cap {}); retry later",
                    q.outstanding, d.opts.queue_cap
                ),
            );
        }
        q.outstanding += 1;
        let now = Instant::now();
        q.jobs.push_back(Job {
            run_id,
            cfg,
            req,
            deadline: now + deadline,
            enqueued: now,
            resp: tx,
        });
    }
    d.queue_cv.notify_all();
    // small grace on top of the deadline: the worker checks the deadline
    // too, so the common expiry path is its typed reply, not this timeout
    match rx.recv_timeout(deadline + Duration::from_millis(250)) {
        Ok(resp) => resp,
        Err(RecvTimeoutError::Timeout) => {
            d.stats.deadline_replies.fetch_add(1, Ordering::Relaxed);
            error_json(
                "deadline_exceeded",
                "round still running past its deadline; result discarded",
            )
        }
        Err(RecvTimeoutError::Disconnected) => {
            // worker dropped the sender without answering (should be
            // impossible — process() always sends)
            d.stats.internal_errors.fetch_add(1, Ordering::Relaxed);
            error_json("internal", "round worker vanished")
        }
    }
}

// ---------------------------------------------------------------------------
// Signals + serve loop
// ---------------------------------------------------------------------------

static SIGNALED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_sig: i32) {
    // async-signal-safe: one atomic store
    SIGNALED.store(true, Ordering::SeqCst);
}

fn install_signal_handlers() {
    // libc is already linked by std; declare signal(2) directly rather than
    // adding a dependency.  The returned previous handler is ignored.
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_signal);
        signal(SIGINT, on_signal);
    }
}

/// Run the daemon until a `shutdown` request or SIGTERM/SIGINT, then drain:
/// stop accepting, finish every admitted round, flush a final stats line,
/// and return the final [`DaemonStats`].
pub fn serve(opts: ServeOpts) -> Result<DaemonStats> {
    if opts.install_signal_handlers {
        install_signal_handlers();
    }
    let listener = Listener::bind(&opts.bind)?;
    let max_conns = opts.max_conns.max(1);
    let daemon = Arc::new(Daemon::new(opts));
    let dispatch = {
        let d = daemon.clone();
        std::thread::Builder::new()
            .name("gm-dispatch".into())
            .spawn(move || dispatcher(&d))
            .map_err(|e| anyhow!("spawning dispatcher: {e}"))?
    };
    let conns = Arc::new(AtomicUsize::new(0));
    while !daemon.shutdown.load(Ordering::SeqCst) {
        if SIGNALED.load(Ordering::SeqCst) {
            daemon.begin_shutdown();
            break;
        }
        match listener.accept() {
            Ok(stream) => {
                daemon.stats.conns_opened.fetch_add(1, Ordering::Relaxed);
                if conns.load(Ordering::SeqCst) >= max_conns {
                    daemon.stats.conns_rejected.fetch_add(1, Ordering::Relaxed);
                    let _ = stream.set_nonblocking(false);
                    let mut w = stream;
                    let _ = write_line(
                        &mut w,
                        &error_json("overloaded", "connection limit reached; retry later"),
                    );
                    continue;
                }
                let _ = stream.set_nonblocking(false);
                conns.fetch_add(1, Ordering::SeqCst);
                let d = daemon.clone();
                let cg = conns.clone();
                let spawned = std::thread::Builder::new()
                    .name("gm-conn".into())
                    .spawn(move || {
                        handle_conn(&d, stream);
                        cg.fetch_sub(1, Ordering::SeqCst);
                    });
                if spawned.is_err() {
                    conns.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => {
                // transient accept failure (EMFILE, client reset mid-accept)
                // must not take the daemon down
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    // drain: the listener drops here (unix socket file removed), the
    // dispatcher finishes every admitted round, then the final stats flush
    drop(listener);
    daemon.begin_shutdown();
    let _ = dispatch.join();
    let snap = daemon.snapshot();
    println!("daemon: drained — {}", snap.to_json().dump());
    Ok(snap)
}

// ---------------------------------------------------------------------------
// Client (smoke mode, tests, stress bench)
// ---------------------------------------------------------------------------

/// One `select` envelope as a client builds it.
#[derive(Clone, Debug)]
pub struct SelectSpec {
    pub run_id: String,
    pub dataset: String,
    pub n_train: usize,
    pub chunk: usize,
    pub h: usize,
    pub data_seed: u64,
    /// `None` → the daemon's default deadline
    pub deadline_ms: Option<u64>,
    pub request: SelectionRequest,
}

impl SelectSpec {
    /// A small, fast default tenant configuration around `request`.
    pub fn new(run_id: &str, request: SelectionRequest) -> SelectSpec {
        SelectSpec {
            run_id: run_id.to_string(),
            dataset: "synmnist".to_string(),
            n_train: 256,
            chunk: 64,
            h: 8,
            data_seed: 0,
            deadline_ms: None,
            request,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("type", s("select")),
            ("run_id", s(&self.run_id)),
            ("dataset", s(&self.dataset)),
            ("n_train", num(self.n_train as f64)),
            ("chunk", num(self.chunk as f64)),
            ("h", num(self.h as f64)),
            ("data_seed", s(&self.data_seed.to_string())),
            ("request", self.request.to_json()),
        ];
        if let Some(ms) = self.deadline_ms {
            fields.push(("deadline_ms", num(ms as f64)));
        }
        obj(fields)
    }
}

/// A line-protocol client for the daemon.
pub struct DaemonClient {
    writer: Stream,
    reader: BufReader<Stream>,
}

impl DaemonClient {
    /// Connect once.
    pub fn connect(bind: &Bind) -> Result<DaemonClient> {
        let stream = match bind {
            Bind::Unix(path) => UnixStream::connect(path)
                .map(Stream::Unix)
                .map_err(|e| anyhow!("connecting {}: {e}", path.display()))?,
            Bind::Tcp(addr) => TcpStream::connect(addr)
                .map(Stream::Tcp)
                .map_err(|e| anyhow!("connecting {addr}: {e}"))?,
        };
        let writer = stream.try_clone()?;
        Ok(DaemonClient {
            writer,
            reader: BufReader::new(stream),
        })
    }

    /// Connect with retries while the daemon binds (tests start the daemon
    /// on a thread and race it).
    pub fn connect_retry(bind: &Bind, budget: Duration) -> Result<DaemonClient> {
        let t0 = Instant::now();
        loop {
            match Self::connect(bind) {
                Ok(c) => return Ok(c),
                Err(e) if t0.elapsed() > budget => {
                    return Err(anyhow!("daemon did not come up within {budget:?}: {e}"))
                }
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
    }

    /// Ship one raw line (no newline appended beyond the protocol's).
    pub fn send_raw(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        Ok(())
    }

    pub fn send(&mut self, j: &Json) -> Result<()> {
        self.send_raw(&j.dump())
    }

    /// Read one response line (EOF is an error — the daemon always answers
    /// or closes deliberately).
    pub fn recv(&mut self) -> Result<Json> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        if n == 0 {
            return Err(anyhow!("daemon closed the connection"));
        }
        Json::parse(line.trim()).map_err(|e| anyhow!("bad response line: {e}"))
    }

    pub fn roundtrip(&mut self, j: &Json) -> Result<Json> {
        self.send(j)?;
        self.recv()
    }

    pub fn select(&mut self, spec: &SelectSpec) -> Result<Json> {
        self.roundtrip(&spec.to_json())
    }

    pub fn ping(&mut self) -> Result<Json> {
        self.roundtrip(&obj(vec![("type", s("ping"))]))
    }

    pub fn stats(&mut self) -> Result<Json> {
        self.roundtrip(&obj(vec![("type", s("stats"))]))
    }

    pub fn shutdown(&mut self) -> Result<Json> {
        self.roundtrip(&obj(vec![("type", s("shutdown"))]))
    }
}

// ---------------------------------------------------------------------------
// Smoke mode (ci.sh)
// ---------------------------------------------------------------------------

/// `serve --smoke`: bring the daemon up on an ephemeral unix socket, drive
/// one real client round-trip (ping → two deterministic selects → stats →
/// shutdown), verify the drain, exit.  A watchdog hard-exits after 45s so a
/// wedged daemon fails CI instead of hanging it (ci.sh adds `timeout` on
/// top when available).
pub fn smoke() -> Result<()> {
    std::thread::Builder::new()
        .name("gm-smoke-watchdog".into())
        .spawn(|| {
            std::thread::sleep(Duration::from_secs(45));
            eprintln!("daemon smoke: watchdog fired — daemon wedged");
            std::process::exit(3);
        })
        .ok();
    let path = ephemeral_socket_path("smoke");
    let bind = Bind::Unix(path);
    let mut opts = ServeOpts::new(bind.clone());
    opts.queue_cap = 8;
    opts.engine_cap = 2;
    opts.default_deadline_ms = 20_000;
    let daemon = std::thread::Builder::new()
        .name("gm-smoke-daemon".into())
        .spawn(move || serve(opts))
        .map_err(|e| anyhow!("spawning smoke daemon: {e}"))?;

    let mut client = DaemonClient::connect_retry(&bind, Duration::from_secs(5))?;
    let pong = client.ping()?;
    if pong.get("type").and_then(Json::as_str) != Some("pong") {
        return Err(anyhow!("smoke: bad ping response: {}", pong.dump()));
    }
    let spec = SelectSpec::new(
        "smoke-run",
        SelectionRequest {
            strategy: "gradmatch".to_string(),
            budget: 16,
            lambda: 0.5,
            eps: 1e-10,
            is_valid: false,
            seed: 42,
            rng_tag: 1000,
            ground: (0..128).collect(),
            shards: None,
            sketch: None,
        },
    );
    let mut spec = spec;
    spec.n_train = 128;
    spec.chunk = 32;
    spec.h = 4;
    let first = client.select(&spec)?;
    if first.get("type").and_then(Json::as_str) != Some("report") {
        return Err(anyhow!("smoke: select failed: {}", first.dump()));
    }
    let second = client.select(&spec)?;
    let indices = |resp: &Json| {
        resp.path(&["report", "selection", "indices"]).map(|v| v.dump())
    };
    if indices(&first) != indices(&second) {
        return Err(anyhow!("smoke: same request twice must select identically"));
    }
    let stats = client.stats()?;
    let served = stats.get("rounds_served").and_then(Json::as_usize).unwrap_or(0);
    if served < 2 {
        return Err(anyhow!("smoke: expected >= 2 rounds served, stats: {}", stats.dump()));
    }
    let hits = stats.get("cache_hits").and_then(Json::as_usize).unwrap_or(0);
    if hits < 1 {
        return Err(anyhow!(
            "smoke: the second identical select must hit the selection cache, stats: {}",
            stats.dump()
        ));
    }
    client.shutdown()?;
    let snap = daemon
        .join()
        .map_err(|_| anyhow!("smoke: daemon thread panicked"))??;
    if snap.rounds_served < 2 || snap.queue_depth != 0 {
        return Err(anyhow!("smoke: bad drain snapshot: {snap:?}"));
    }
    println!(
        "daemon smoke: OK ({} rounds served, {} engines built)",
        snap.rounds_served, snap.engines_built
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_spec_roundtrips_through_parse_select() {
        let spec = SelectSpec::new(
            "run-a",
            SelectionRequest {
                strategy: "craig".into(),
                budget: 8,
                lambda: 0.5,
                eps: 1e-10,
                is_valid: false,
                seed: 7,
                rng_tag: 3,
                ground: (0..64).collect(),
                shards: Some(crate::engine::ShardPlan { shards: 2, max_staged_rows: 32 }),
                sketch: Some(crate::engine::SketchPlan {
                    width: 24,
                    refit: true,
                    seed_salt: 9,
                }),
            },
        );
        let j = spec.to_json();
        let (run_id, cfg, req, deadline) = parse_select(&j, 1234).unwrap();
        assert_eq!(run_id, "run-a");
        assert_eq!(cfg.dataset, "synmnist");
        assert_eq!(cfg.n_train, 256);
        assert_eq!(cfg.chunk, 64);
        assert_eq!(cfg.h, 8);
        assert_eq!(req.strategy, "craig");
        assert_eq!(
            req.shards,
            Some(crate::engine::ShardPlan { shards: 2, max_staged_rows: 32 }),
            "shard plan survives the daemon wire format"
        );
        assert_eq!(
            req.sketch,
            Some(crate::engine::SketchPlan { width: 24, refit: true, seed_salt: 9 }),
            "sketch plan survives the daemon wire format"
        );
        assert_eq!(deadline, Duration::from_millis(1234), "daemon default applies");
        let mut with_deadline = spec.clone();
        with_deadline.deadline_ms = Some(50);
        let (_, _, _, d2) = parse_select(&with_deadline.to_json(), 1234).unwrap();
        assert_eq!(d2, Duration::from_millis(50));
    }

    #[test]
    fn parse_select_rejects_hostile_envelopes() {
        let base = SelectSpec::new(
            "r",
            SelectionRequest {
                strategy: "gradmatch".into(),
                budget: 4,
                lambda: 0.5,
                eps: 1e-10,
                is_valid: false,
                seed: 1,
                rng_tag: 1,
                ground: vec![0, 1, 2, 3],
                shards: None,
                sketch: None,
            },
        );
        // out-of-range ground index would panic deep in staging — must be
        // rejected at the door
        let mut bad = base.clone();
        bad.request.ground = vec![0, 500];
        assert!(parse_select(&bad.to_json(), 1000).is_err());
        let mut bad = base.clone();
        bad.request.budget = 0;
        assert!(parse_select(&bad.to_json(), 1000).is_err());
        let mut bad = base.clone();
        bad.request.ground.clear();
        assert!(parse_select(&bad.to_json(), 1000).is_err());
        let mut bad = base.clone();
        bad.n_train = 0;
        assert!(parse_select(&bad.to_json(), 1000).is_err());
        let mut bad = base.clone();
        bad.chunk = 0;
        assert!(parse_select(&bad.to_json(), 1000).is_err());
        let mut bad = base.clone();
        bad.run_id = String::new();
        assert!(parse_select(&bad.to_json(), 1000).is_err());
        // missing request object
        let no_req = obj(vec![("type", s("select")), ("run_id", s("r"))]);
        assert!(parse_select(&no_req, 1000).is_err());
    }

    #[test]
    fn run_cfg_fingerprint_equality_drives_rebuilds() {
        let a = RunCfg {
            dataset: "synmnist".into(),
            n_train: 256,
            chunk: 64,
            h: 8,
            data_seed: 0,
        };
        let mut b = a.clone();
        assert_eq!(a, b);
        b.n_train = 512;
        assert_ne!(a, b, "config change must not silently reuse the old engine");
    }

    #[test]
    fn run_scope_separates_tenant_configs() {
        let base = RunCfg {
            dataset: "synmnist".into(),
            n_train: 256,
            chunk: 64,
            h: 8,
            data_seed: 0,
        };
        let scope = run_scope(&base);
        assert_eq!(scope, run_scope(&base.clone()), "deterministic");
        let mutations: [fn(&mut RunCfg); 5] = [
            |c| c.dataset = "syncifar10".into(),
            |c| c.n_train = 512,
            |c| c.chunk = 32,
            |c| c.h = 4,
            |c| c.data_seed = 7,
        ];
        for mutate in mutations {
            let mut other = base.clone();
            mutate(&mut other);
            assert_ne!(scope, run_scope(&other), "{other:?} must not share memoized rounds");
        }
    }

    #[test]
    fn daemon_stats_serialize_the_cache_counters() {
        let mut st = DaemonStats::default();
        st.cache_depth = 3;
        st.cache_hits = 5;
        st.cache_stores = 4;
        let j = st.to_json();
        assert_eq!(j.get("cache_depth").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("cache_hits").and_then(Json::as_usize), Some(5));
        assert_eq!(j.get("cache_stores").and_then(Json::as_usize), Some(4));
    }
}
