//! Dataset substrate: synthetic classification suites standing in for the
//! paper's MNIST / CIFAR / SVHN / ImageNet (no downloads offline — see
//! DESIGN.md §4 for the substitution argument), plus splits, the
//! class-imbalance transform, per-class index views, and the fixed-shape
//! padded batch iterator the AOT'd executables require.
//!
//! Generation model: per class, `clusters` anchor points in a latent space;
//! samples are anchors + isotropic spread, pushed through a fixed random
//! `tanh` feature map to the model's input dimension, plus observation
//! noise.  Low `clusters`/`spread` ⇒ high intra-class redundancy ⇒ subset
//! selection has signal (like near-duplicate images); `sep` controls class
//! overlap ⇒ task difficulty.

use crate::rng::Rng;
use crate::tensor::Matrix;

/// In-memory dataset (row-major features + integer labels).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Matrix,
    pub y: Vec<i32>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.x.rows
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Indices of each class: `out[c]` lists rows with label c.
    pub fn class_indices(&self) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); self.classes];
        for (i, &c) in self.y.iter().enumerate() {
            out[c as usize].push(i);
        }
        out
    }

    /// Subset view (copies rows).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.gather_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            classes: self.classes,
        }
    }
}

/// Train/val/test triple.
#[derive(Clone, Debug)]
pub struct Splits {
    pub train: Dataset,
    pub val: Dataset,
    pub test: Dataset,
}

/// Named dataset card — the knobs for one synthetic suite.
#[derive(Clone, Debug)]
pub struct DatasetCard {
    pub name: &'static str,
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
    pub d: usize,
    pub classes: usize,
    /// latent dimension of the generative model
    pub latent: usize,
    /// anchor clusters per class (lower ⇒ more redundancy)
    pub clusters: usize,
    /// distance between class anchors (higher ⇒ easier)
    pub sep: f32,
    /// within-cluster spread
    pub spread: f32,
    /// observation noise added in feature space
    pub noise: f32,
    /// default model variant for this card
    pub model: &'static str,
}

impl DatasetCard {
    /// The five suites used by the experiment harness (paper's five datasets).
    pub fn all() -> Vec<DatasetCard> {
        vec![
            // MNIST-like: easy, highly redundant → big subset-selection wins
            DatasetCard { name: "synmnist", n_train: 10_000, n_val: 1_000, n_test: 2_000,
                d: 784, classes: 10, latent: 24, clusters: 6, sep: 2.4, spread: 1.1,
                noise: 0.15, model: "lenet_s" },
            // CIFAR-10-like: moderate difficulty
            DatasetCard { name: "syncifar10", n_train: 10_000, n_val: 1_000, n_test: 2_000,
                d: 1024, classes: 10, latent: 32, clusters: 8, sep: 1.9, spread: 1.2,
                noise: 0.25, model: "resnet_s" },
            // CIFAR-100-like: many classes, hardest
            DatasetCard { name: "syncifar100", n_train: 10_000, n_val: 1_000, n_test: 2_000,
                d: 1024, classes: 20, latent: 32, clusters: 6, sep: 1.6, spread: 1.2,
                noise: 0.25, model: "resnet_s" },
            // SVHN-like: noisy observations
            DatasetCard { name: "synsvhn", n_train: 12_000, n_val: 1_200, n_test: 2_400,
                d: 1024, classes: 10, latent: 32, clusters: 8, sep: 2.0, spread: 1.2,
                noise: 0.45, model: "resnet_s" },
            // ImageNet-like: exercises the scaling path (3x samples)
            DatasetCard { name: "synimagenet", n_train: 30_000, n_val: 2_000, n_test: 4_000,
                d: 1024, classes: 20, latent: 40, clusters: 8, sep: 1.7, spread: 1.2,
                noise: 0.3, model: "resnet_s" },
        ]
    }

    /// Lookup by name.
    pub fn by_name(name: &str) -> Option<DatasetCard> {
        Self::all().into_iter().find(|c| c.name == name)
    }

    /// Generate the full train/val/test splits for a seed.
    ///
    /// `n_train_override` (when non-zero) shrinks the training split —
    /// benches use miniature configs.  Teacher map and anchors depend only
    /// on (card, seed), so different strategies see identical data.
    pub fn generate(&self, seed: u64, n_train_override: usize) -> Splits {
        let root = Rng::new(seed ^ fnv(self.name));
        let mut teacher_rng = root.split(1);

        // fixed random feature map: latent -> d
        let a = Matrix::from_vec(
            self.latent,
            self.d,
            (0..self.latent * self.d)
                .map(|_| teacher_rng.gaussian_f32() / (self.latent as f32).sqrt())
                .collect(),
        );
        let bias: Vec<f32> = (0..self.d).map(|_| 0.3 * teacher_rng.gaussian_f32()).collect();

        // class anchors
        let mut anchors = Vec::with_capacity(self.classes * self.clusters);
        for _ in 0..self.classes * self.clusters {
            let v: Vec<f32> = (0..self.latent)
                .map(|_| self.sep * teacher_rng.gaussian_f32() / 2.0f32.sqrt())
                .collect();
            anchors.push(v);
        }

        let n_train = if n_train_override > 0 { n_train_override } else { self.n_train };
        let gen = |n: usize, stream: u64| -> Dataset {
            let mut rng = root.split(stream);
            let mut x = Matrix::zeros(n, self.d);
            let mut y = Vec::with_capacity(n);
            let mut z = vec![0.0f32; self.latent];
            for i in 0..n {
                let cls = i % self.classes; // balanced by construction
                let cluster = rng.usize(self.clusters);
                let anchor = &anchors[cls * self.clusters + cluster];
                for (j, zj) in z.iter_mut().enumerate() {
                    *zj = anchor[j] + self.spread * rng.gaussian_f32();
                }
                let row = x.row_mut(i);
                // row = tanh(z @ A + bias) + noise
                for (jd, r) in row.iter_mut().enumerate() {
                    let mut acc = bias[jd];
                    for (jl, &zj) in z.iter().enumerate() {
                        acc += zj * a.at(jl, jd);
                    }
                    *r = acc.tanh() + self.noise * rng.gaussian_f32();
                }
                y.push(cls as i32);
            }
            // shuffle rows so classes are interleaved randomly
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            let ds = Dataset { x, y, classes: self.classes };
            ds.subset(&perm)
        };

        Splits {
            train: gen(n_train, 2),
            val: gen(self.n_val, 3),
            test: gen(self.n_test, 4),
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Class-imbalance transform (paper §5 "Data selection with class
/// imbalance"): reduce `frac_classes` of the classes to `keep_frac` of
/// their samples.  Returns the surviving indices (sorted).
pub fn imbalance_indices(
    ds: &Dataset,
    frac_classes: f64,
    keep_frac: f64,
    rng: &mut Rng,
) -> Vec<usize> {
    let n_classes = ds.classes;
    let n_reduce = ((n_classes as f64) * frac_classes).round() as usize;
    let mut classes: Vec<usize> = (0..n_classes).collect();
    rng.shuffle(&mut classes);
    let reduced: Vec<usize> = classes.into_iter().take(n_reduce).collect();
    let per_class = ds.class_indices();
    let mut keep = Vec::new();
    for (c, idxs) in per_class.iter().enumerate() {
        if reduced.contains(&c) {
            let k = ((idxs.len() as f64) * keep_frac).round().max(1.0) as usize;
            let chosen = rng.sample_indices(idxs.len(), k.min(idxs.len()));
            keep.extend(chosen.into_iter().map(|j| idxs[j]));
        } else {
            keep.extend_from_slice(idxs);
        }
    }
    keep.sort_unstable();
    keep
}

/// Label-noise transform (robust-learning extension; the paper's related
/// work — Mirzasoleiman et al. 2020b — studies CRAIG under noisy labels,
/// and GLISTER/GRAD-MATCH handle it via validation-gradient matching):
/// flip `noise_frac` of the labels to a uniformly random *different* class.
/// Returns the indices whose labels were flipped.
pub fn apply_label_noise(ds: &mut Dataset, noise_frac: f64, rng: &mut Rng) -> Vec<usize> {
    let n_flip = ((ds.len() as f64) * noise_frac).round() as usize;
    let flips = rng.sample_indices(ds.len(), n_flip.min(ds.len()));
    for &i in &flips {
        let old = ds.y[i];
        let mut new = rng.usize(ds.classes) as i32;
        while new == old && ds.classes > 1 {
            new = rng.usize(ds.classes) as i32;
        }
        ds.y[i] = new;
    }
    flips
}

/// Fixed-shape padded chunk: the bridge between variable-size index lists
/// and the static shapes of the AOT'd executables.
#[derive(Clone, Debug)]
pub struct PaddedChunk {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    /// 1.0 on live rows, 0.0 on padding
    pub mask: Vec<f32>,
    /// dataset row index per live slot
    pub indices: Vec<usize>,
    /// number of live rows
    pub live: usize,
}

/// Iterate `indices` in fixed-size chunks, zero-padding the last one.
pub fn padded_chunks<'a>(
    ds: &'a Dataset,
    indices: &'a [usize],
    chunk: usize,
) -> impl Iterator<Item = PaddedChunk> + 'a {
    let d = ds.x.cols;
    indices.chunks(chunk).map(move |slice| {
        let mut x = vec![0.0f32; chunk * d];
        let mut y = vec![0i32; chunk];
        let mut mask = vec![0.0f32; chunk];
        for (slot, &row) in slice.iter().enumerate() {
            x[slot * d..(slot + 1) * d].copy_from_slice(ds.x.row(row));
            y[slot] = ds.y[row];
            mask[slot] = 1.0;
        }
        PaddedChunk { x, y, mask, indices: slice.to_vec(), live: slice.len() }
    })
}

/// A weighted training batch (fixed shape, padded) for the train_step entry.
#[derive(Clone, Debug)]
pub struct WeightedBatch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    /// selection weight × padding mask
    pub w: Vec<f32>,
    pub live: usize,
}

/// Build shuffled weighted batches over `(indices, weights)` — Algorithm 1
/// line 9: shuffle the subset, chop into mini-batches of `batch`, carry each
/// example's selection weight into the loss.
pub fn weighted_batches(
    ds: &Dataset,
    indices: &[usize],
    weights: &[f32],
    batch: usize,
    rng: &mut Rng,
) -> Vec<WeightedBatch> {
    assert_eq!(indices.len(), weights.len());
    let d = ds.x.cols;
    let mut order: Vec<usize> = (0..indices.len()).collect();
    rng.shuffle(&mut order);
    order
        .chunks(batch)
        .map(|slice| {
            let mut x = vec![0.0f32; batch * d];
            let mut y = vec![0i32; batch];
            let mut w = vec![0.0f32; batch];
            for (slot, &oi) in slice.iter().enumerate() {
                let row = indices[oi];
                x[slot * d..(slot + 1) * d].copy_from_slice(ds.x.row(row));
                y[slot] = ds.y[row];
                w[slot] = weights[oi];
            }
            WeightedBatch { x, y, w, live: slice.len() }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_card() -> DatasetCard {
        DatasetCard {
            name: "tiny",
            n_train: 200,
            n_val: 40,
            n_test: 40,
            d: 16,
            classes: 4,
            latent: 6,
            clusters: 2,
            sep: 5.0,
            spread: 0.6,
            noise: 0.05,
            model: "lenet_s",
        }
    }

    #[test]
    fn cards_exist_and_lookup_works() {
        assert_eq!(DatasetCard::all().len(), 5);
        assert!(DatasetCard::by_name("syncifar100").is_some());
        assert!(DatasetCard::by_name("nope").is_none());
    }

    #[test]
    fn generate_shapes_and_determinism() {
        let card = tiny_card();
        let s1 = card.generate(7, 0);
        let s2 = card.generate(7, 0);
        assert_eq!(s1.train.len(), 200);
        assert_eq!(s1.val.len(), 40);
        assert_eq!(s1.train.x.cols, 16);
        assert_eq!(s1.train.x.data, s2.train.x.data);
        assert_eq!(s1.train.y, s2.train.y);
        let s3 = card.generate(8, 0);
        assert_ne!(s1.train.x.data, s3.train.x.data);
    }

    #[test]
    fn n_train_override_shrinks() {
        let card = tiny_card();
        let s = card.generate(7, 60);
        assert_eq!(s.train.len(), 60);
        assert_eq!(s.test.len(), 40); // test split unchanged
    }

    #[test]
    fn classes_are_roughly_balanced() {
        let card = tiny_card();
        let s = card.generate(1, 0);
        let counts = s.train.class_indices();
        for c in &counts {
            assert_eq!(c.len(), 50);
        }
    }

    #[test]
    fn features_bounded_by_tanh_plus_noise() {
        let card = tiny_card();
        let s = card.generate(2, 0);
        for v in &s.train.x.data {
            assert!(v.abs() < 1.0 + 6.0 * card.noise, "{v}");
        }
    }

    #[test]
    fn classes_are_separable_by_centroid_distance() {
        // sanity that the task is learnable: class centroids differ clearly
        let card = tiny_card();
        let s = card.generate(3, 0);
        let per = s.train.class_indices();
        let mut cents = Vec::new();
        for idxs in &per {
            let mut c = vec![0.0f32; 16];
            for &i in idxs {
                crate::tensor::axpy(1.0 / idxs.len() as f32, s.train.x.row(i), &mut c);
            }
            cents.push(c);
        }
        let d01 = crate::tensor::sqdist(&cents[0], &cents[1]);
        assert!(d01 > 0.05, "centroids too close: {d01}");
    }

    #[test]
    fn imbalance_reduces_selected_classes() {
        let card = tiny_card();
        let s = card.generate(4, 0);
        let mut rng = Rng::new(9);
        let keep = imbalance_indices(&s.train, 0.5, 0.1, &mut rng);
        let sub = s.train.subset(&keep);
        let counts: Vec<usize> = sub.class_indices().iter().map(|v| v.len()).collect();
        let small = counts.iter().filter(|&&c| c <= 6).count();
        let full = counts.iter().filter(|&&c| c == 50).count();
        assert_eq!(small, 2, "{counts:?}");
        assert_eq!(full, 2, "{counts:?}");
    }

    #[test]
    fn imbalance_keeps_at_least_one_per_class() {
        let card = tiny_card();
        let s = card.generate(5, 0);
        let mut rng = Rng::new(10);
        let keep = imbalance_indices(&s.train, 1.0, 0.0, &mut rng);
        let sub = s.train.subset(&keep);
        for c in sub.class_indices() {
            assert!(!c.is_empty());
        }
    }

    #[test]
    fn label_noise_flips_exactly_the_requested_fraction() {
        let card = tiny_card();
        let s = card.generate(11, 0);
        let mut ds = s.train.clone();
        let orig = ds.y.clone();
        let mut rng = Rng::new(1);
        let flips = apply_label_noise(&mut ds, 0.25, &mut rng);
        assert_eq!(flips.len(), 50); // 25% of 200
        let changed = ds.y.iter().zip(&orig).filter(|(a, b)| a != b).count();
        assert_eq!(changed, 50);
        // every flipped label is different from the original and in range
        for &i in &flips {
            assert_ne!(ds.y[i], orig[i]);
            assert!((ds.y[i] as usize) < ds.classes);
        }
    }

    #[test]
    fn label_noise_zero_is_identity() {
        let card = tiny_card();
        let s = card.generate(12, 0);
        let mut ds = s.train.clone();
        let orig = ds.y.clone();
        let mut rng = Rng::new(2);
        let flips = apply_label_noise(&mut ds, 0.0, &mut rng);
        assert!(flips.is_empty());
        assert_eq!(ds.y, orig);
    }

    #[test]
    fn padded_chunks_cover_all_indices_once() {
        let card = tiny_card();
        let s = card.generate(6, 0);
        let idx: Vec<usize> = (0..s.train.len()).step_by(3).collect();
        let chunks: Vec<_> = padded_chunks(&s.train, &idx, 32).collect();
        let total_live: usize = chunks.iter().map(|c| c.live).sum();
        assert_eq!(total_live, idx.len());
        let mut seen: Vec<usize> = chunks.iter().flat_map(|c| c.indices.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, idx);
        // mask matches live count; padding rows are zeroed
        for ch in &chunks {
            assert_eq!(ch.mask.iter().filter(|&&m| m > 0.0).count(), ch.live);
            for slot in ch.live..32 {
                assert_eq!(ch.y[slot], 0);
                assert!(ch.x[slot * 16..(slot + 1) * 16].iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn weighted_batches_preserve_weights_and_rows() {
        let card = tiny_card();
        let s = card.generate(7, 0);
        let idx: Vec<usize> = (0..50).collect();
        let wts: Vec<f32> = (0..50).map(|i| i as f32 + 1.0).collect();
        let mut rng = Rng::new(11);
        let batches = weighted_batches(&s.train, &idx, &wts, 16, &mut rng);
        assert_eq!(batches.len(), 4); // ceil(50/16)
        let mut wsum = 0.0f32;
        let mut live = 0usize;
        for b in &batches {
            wsum += b.w.iter().sum::<f32>();
            live += b.live;
        }
        assert_eq!(live, 50);
        assert!((wsum - wts.iter().sum::<f32>()).abs() < 1e-3);
    }

    #[test]
    fn weighted_batches_shuffle_depends_on_rng() {
        let card = tiny_card();
        let s = card.generate(8, 0);
        let idx: Vec<usize> = (0..40).collect();
        let wts = vec![1.0f32; 40];
        let b1 = weighted_batches(&s.train, &idx, &wts, 8, &mut Rng::new(1));
        let b2 = weighted_batches(&s.train, &idx, &wts, 8, &mut Rng::new(2));
        assert_ne!(b1[0].y, b2[0].y);
    }
}
