//! JL sketching for the correlation hot path (GRAFT-style).
//!
//! GRAD-MATCH's per-round cost bottoms out in correlation work over the
//! staged `[n, P]` class matrix (`P = h·c + c` gradient dimensions).  GRAFT
//! (PAPERS.md) shows that greedy gradient matching on a *low-rank sketch*
//! of that matrix preserves selection quality at a fraction of the cost:
//! project the staged gradients to `[n, k]` (k ≪ P) once per round, run
//! Batch-OMP against the sketched Gram, and (optionally) re-fit the
//! weights at full width on the selected support.
//!
//! # Determinism across staging paths
//!
//! The projection row for a gradient dimension is derived from the
//! dimension's **global column index** (`Rng::new(seed ^ TAG).split(salt)
//! .split(col)`), not from its position inside whatever slice happens to be
//! staged.  A class-sliced stage, a full-width stage, and a per-shard stage
//! therefore all see the *same* projection for the same dimension — which
//! is what lets the sharded path sketch per-shard solves while the merge
//! re-fit runs full width, and what makes sketched selections reproducible
//! from `(seed, seed_salt)` alone.
//!
//! # Memory
//!
//! The projection is applied column-block-wise: nothing wider than a
//! `[BLOCK, k]` strip of projection rows plus the `[n, k]` output is ever
//! materialized, so sketching never exceeds the staged buffers it reads.
//!
//! # JL guarantee
//!
//! For `k ≳ 8·ln(n)/ε²` the (Rademacher or Gaussian) projection preserves
//! pairwise distances to `(1 ± ε)` with high probability (Johnson &
//! Lindenstrauss; Achlioptas 2003 for the ±1 case).  [`pairwise_distortion`]
//! measures the empirical distortion so `theory.rs` can pin the bound, and
//! [`jl_width_for`] inverts it to a suggested width.

use anyhow::{anyhow, Result};

use crate::linalg::{residual, ridge_weights_nonneg};
use crate::omp::{omp_select_rust, OmpOpts, OmpResult};
use crate::rng::Rng;
use crate::tensor::{axpy, norm2, Matrix};

/// Stream tag decorrelating sketch projections from every other consumer
/// of the run seed (data synthesis, shuffling, fault injection, ...).
const SKETCH_STREAM_TAG: u64 = 0x5EED_C0DE_u64;

/// Columns of projection rows generated per strip while sketching.
const COL_BLOCK: usize = 128;

/// Entry distribution of the random projection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SketchKind {
    /// ±1/√k entries (Achlioptas) — one random bit per entry, the default.
    Rademacher,
    /// N(0, 1/k) entries — the classic JL matrix.
    Gaussian,
}

/// A seeded, deterministic `P → k` random projection.
///
/// Cheap to construct (no state beyond the parameters); projection rows
/// are regenerated on demand from the (seed, salt, global column) triple.
#[derive(Clone, Copy, Debug)]
pub struct Sketcher {
    width: usize,
    seed: u64,
    salt: u64,
    kind: SketchKind,
}

impl Sketcher {
    /// Rademacher sketcher of the given width.  `width` must be > 0.
    pub fn new(width: usize, seed: u64, salt: u64) -> Sketcher {
        Sketcher::with_kind(width, seed, salt, SketchKind::Rademacher)
    }

    /// Sketcher with an explicit entry distribution.
    pub fn with_kind(width: usize, seed: u64, salt: u64, kind: SketchKind) -> Sketcher {
        assert!(width > 0, "sketch width must be positive");
        Sketcher {
            width,
            seed,
            salt,
            kind,
        }
    }

    /// Sketch dimension `k`.
    pub fn width(&self) -> usize {
        self.width
    }

    /// The `k` projection entries for one **global** gradient dimension.
    pub fn projection_row(&self, global_col: usize) -> Vec<f32> {
        let mut row = vec![0.0f32; self.width];
        self.fill_projection_row(global_col, &mut row);
        row
    }

    fn fill_projection_row(&self, global_col: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.width);
        let mut rng = Rng::new(self.seed ^ SKETCH_STREAM_TAG)
            .split(self.salt)
            .split(global_col as u64);
        let scale = 1.0 / (self.width as f32).sqrt();
        match self.kind {
            SketchKind::Rademacher => {
                // one u64 buys 64 sign bits
                let mut bits = 0u64;
                for (t, slot) in out.iter_mut().enumerate() {
                    if t % 64 == 0 {
                        bits = rng.next_u64();
                    }
                    *slot = if bits & 1 == 1 { scale } else { -scale };
                    bits >>= 1;
                }
            }
            SketchKind::Gaussian => {
                for slot in out.iter_mut() {
                    *slot = rng.gaussian_f32() * scale;
                }
            }
        }
    }

    /// Project a staged `[n, w]` matrix to `[n, k]`.
    ///
    /// `cols[j]` is the **global** gradient-dimension index of local column
    /// `j` (for a full-width stage just pass `0..w`; class-sliced and
    /// sharded stages pass their `class_columns` map) — so every staging
    /// path applies the identical projection.
    pub fn sketch_matrix(&self, g: &Matrix, cols: &[usize]) -> Matrix {
        assert_eq!(
            g.cols,
            cols.len(),
            "sketch_matrix: column map must cover the staged width"
        );
        let k = self.width;
        let mut out = Matrix::zeros(g.rows, k);
        let mut strip = Matrix::zeros(COL_BLOCK.min(cols.len().max(1)), k);
        let mut start = 0;
        while start < cols.len() {
            let end = (start + COL_BLOCK).min(cols.len());
            for (bj, &col) in cols[start..end].iter().enumerate() {
                self.fill_projection_row(col, strip.row_mut(bj));
            }
            for r in 0..g.rows {
                let grow = &g.row(r)[start..end];
                let orow = out.row_mut(r);
                for (bj, &gv) in grow.iter().enumerate() {
                    if gv != 0.0 {
                        axpy(gv, strip.row(bj), orow);
                    }
                }
            }
            start = end;
        }
        out
    }

    /// Project a full-width vector (e.g. the matching target) to `[k]`.
    pub fn sketch_vec(&self, v: &[f32], cols: &[usize]) -> Vec<f32> {
        assert_eq!(
            v.len(),
            cols.len(),
            "sketch_vec: column map must cover the vector"
        );
        let mut out = vec![0.0f32; self.width];
        let mut row = vec![0.0f32; self.width];
        for (&gv, &col) in v.iter().zip(cols) {
            if gv != 0.0 {
                self.fill_projection_row(col, &mut row);
                axpy(gv, &row, &mut out);
            }
        }
        out
    }
}

/// Outcome of a sketched OMP solve (plus the optional full-width re-fit).
#[derive(Clone, Debug)]
pub struct SketchSolve {
    /// Row indices into the staged matrix, in pick order.
    pub selected: Vec<usize>,
    /// Non-negative weights aligned with `selected` (full-width when
    /// `refit` ran, sketch-space otherwise).
    pub weights: Vec<f32>,
    /// Residual norm in whichever space the weights live in.
    pub residual_norm: f32,
    /// OMP iterations in sketch space.
    pub iters: usize,
    /// Seconds spent projecting the matrix + target.
    pub sketch_secs: f64,
    /// Seconds spent on the full-width re-fit (0 when `refit` is off).
    pub refit_secs: f64,
}

/// Run Batch-OMP on the sketched problem, optionally re-fitting weights at
/// full width on the selected support.
///
/// `g`/`target` are the full-width staged matrix and matching target;
/// `cols` maps local columns to global gradient dimensions (see
/// [`Sketcher::sketch_matrix`]).  The caller is responsible for only
/// invoking this when `sketcher.width() < g.cols` — at `k ≥ P` the flat
/// solver is both cheaper and exact.
pub fn solve_sketched_omp(
    sketcher: &Sketcher,
    g: &Matrix,
    cols: &[usize],
    target: &[f32],
    opts: OmpOpts,
    refit: bool,
) -> Result<SketchSolve> {
    let t0 = std::time::Instant::now();
    let sk_g = sketcher.sketch_matrix(g, cols);
    let sk_target = sketcher.sketch_vec(target, cols);
    let sketch_secs = t0.elapsed().as_secs_f64();

    let OmpResult {
        selected,
        mut weights,
        mut residual_norm,
        iters,
    } = omp_select_rust(&sk_g, &sk_target, opts)?;

    let mut refit_secs = 0.0;
    if refit && !selected.is_empty() {
        let t1 = std::time::Instant::now();
        let (w, rnorm) = refit_full_width(&g.gather_rows(&selected), target, opts.lambda)?;
        weights = w;
        residual_norm = rnorm;
        refit_secs = t1.elapsed().as_secs_f64();
    }
    Ok(SketchSolve {
        selected,
        weights,
        residual_norm,
        iters,
        sketch_secs,
        refit_secs,
    })
}

/// Non-negative ridge re-fit of a selected support at full width.
///
/// Returns the weights (length = rows of `g_sel`, zeros where the
/// non-negativity clamp dropped a row) and the full-width residual norm.
pub fn refit_full_width(g_sel: &Matrix, target: &[f32], lambda: f32) -> Result<(Vec<f32>, f32)> {
    let w = ridge_weights_nonneg(g_sel, target, lambda)
        .map_err(|e| anyhow!("full-width refit failed: {e:?}"))?;
    let rnorm = norm2(&residual(g_sel, &w, target));
    Ok((w, rnorm))
}

/// Smallest sketch width with the JL `(1 ± ε)` pairwise guarantee for `n`
/// points: `⌈8·ln(n)/ε²⌉` (the usual constant for the ±1/Gaussian case).
pub fn jl_width_for(n: usize, eps: f64) -> usize {
    assert!(eps > 0.0 && eps < 1.0, "jl_width_for: eps must be in (0,1)");
    let n = n.max(2) as f64;
    (8.0 * n.ln() / (eps * eps)).ceil() as usize
}

/// Empirical max pairwise-distance distortion `|‖S(x−y)‖²/‖x−y‖² − 1|`
/// between rows of `g` (full width) and rows of `sk` (its sketch).
///
/// Pairs are enumerated deterministically with a stride that covers at
/// most `max_pairs` of them; degenerate pairs (‖x−y‖ ≈ 0) are skipped.
pub fn pairwise_distortion(g: &Matrix, sk: &Matrix, max_pairs: usize) -> f64 {
    assert_eq!(g.rows, sk.rows, "pairwise_distortion: row count mismatch");
    let n = g.rows;
    if n < 2 || max_pairs == 0 {
        return 0.0;
    }
    let total = n * (n - 1) / 2;
    let stride = total.div_ceil(max_pairs).max(1);
    let mut worst = 0.0f64;
    let mut idx = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            if idx % stride == 0 {
                let d_full = sq_dist(g.row(i), g.row(j));
                if d_full > 1e-12 {
                    let d_sk = sq_dist(sk.row(i), sk.row(j));
                    worst = worst.max((d_sk / d_full - 1.0).abs());
                }
            }
            idx += 1;
        }
    }
    worst
}

fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.set(r, c, rng.gaussian_f32());
            }
        }
        m
    }

    fn all_cols(w: usize) -> Vec<usize> {
        (0..w).collect()
    }

    #[test]
    fn deterministic_and_salted() {
        let mut rng = Rng::new(11);
        let g = random_matrix(&mut rng, 6, 40);
        let cols = all_cols(40);
        let a = Sketcher::new(8, 7, 3).sketch_matrix(&g, &cols);
        let b = Sketcher::new(8, 7, 3).sketch_matrix(&g, &cols);
        assert_eq!(a.data, b.data, "same (seed, salt) must reproduce exactly");
        let c = Sketcher::new(8, 7, 4).sketch_matrix(&g, &cols);
        assert_ne!(a.data, c.data, "different salt must decorrelate");
        let d = Sketcher::new(8, 9, 3).sketch_matrix(&g, &cols);
        assert_ne!(a.data, d.data, "different seed must decorrelate");
    }

    #[test]
    fn projection_is_linear() {
        let sk = Sketcher::new(16, 42, 0);
        let mut rng = Rng::new(5);
        let cols = all_cols(64);
        let x: Vec<f32> = (0..64).map(|_| rng.gaussian_f32()).collect();
        let y: Vec<f32> = (0..64).map(|_| rng.gaussian_f32()).collect();
        let combo: Vec<f32> = x.iter().zip(&y).map(|(a, b)| 2.0 * a - 0.5 * b).collect();
        let sx = sk.sketch_vec(&x, &cols);
        let sy = sk.sketch_vec(&y, &cols);
        let s_combo = sk.sketch_vec(&combo, &cols);
        for t in 0..16 {
            let expect = 2.0 * sx[t] - 0.5 * sy[t];
            assert!(
                (s_combo[t] - expect).abs() < 1e-4,
                "projection must be linear: {} vs {expect}",
                s_combo[t]
            );
        }
    }

    #[test]
    fn column_partition_sums_to_full_sketch() {
        // The sharded path sketches column slices against their GLOBAL ids;
        // linearity over a column partition is exactly what makes that
        // consistent with sketching the full-width stage in one go.
        let mut rng = Rng::new(23);
        let g = random_matrix(&mut rng, 5, 30);
        let sk = Sketcher::new(10, 99, 1);
        let full = sk.sketch_matrix(&g, &all_cols(30));
        let left_cols: Vec<usize> = (0..13).collect();
        let right_cols: Vec<usize> = (13..30).collect();
        let left = sk.sketch_matrix(&g.gather_cols(&left_cols), &left_cols);
        let right = sk.sketch_matrix(&g.gather_cols(&right_cols), &right_cols);
        for r in 0..5 {
            for t in 0..10 {
                let sum = left.at(r, t) + right.at(r, t);
                assert!(
                    (full.at(r, t) - sum).abs() < 1e-4,
                    "slice sketches must sum to the full sketch"
                );
            }
        }
    }

    #[test]
    fn distortion_shrinks_with_width() {
        let mut rng = Rng::new(31);
        let g = random_matrix(&mut rng, 24, 256);
        let cols = all_cols(256);
        let narrow = Sketcher::new(8, 17, 0);
        let wide = Sketcher::new(128, 17, 0);
        let d_narrow = pairwise_distortion(&g, &narrow.sketch_matrix(&g, &cols), 500);
        let d_wide = pairwise_distortion(&g, &wide.sketch_matrix(&g, &cols), 500);
        assert!(
            d_wide < d_narrow,
            "wider sketch must distort less: k=128 gives {d_wide}, k=8 gives {d_narrow}"
        );
        assert!(d_wide < 0.5, "k=128 over 256 dims should be accurate: {d_wide}");
    }

    #[test]
    fn gaussian_kind_also_concentrates() {
        let mut rng = Rng::new(37);
        let g = random_matrix(&mut rng, 16, 200);
        let cols = all_cols(200);
        let sk = Sketcher::with_kind(96, 41, 0, SketchKind::Gaussian);
        let d = pairwise_distortion(&g, &sk.sketch_matrix(&g, &cols), 200);
        assert!(d < 0.6, "gaussian sketch at k=96 should concentrate: {d}");
    }

    #[test]
    fn refit_recovers_planted_combination() {
        let mut rng = Rng::new(43);
        let g_sel = random_matrix(&mut rng, 2, 50);
        let mut target = vec![0.0f32; 50];
        axpy(2.0, g_sel.row(0), &mut target);
        axpy(3.0, g_sel.row(1), &mut target);
        let (w, rnorm) = refit_full_width(&g_sel, &target, 1e-6).unwrap();
        assert!((w[0] - 2.0).abs() < 1e-2, "w0={}", w[0]);
        assert!((w[1] - 3.0).abs() < 1e-2, "w1={}", w[1]);
        assert!(rnorm < 1e-2, "planted combination must refit exactly: {rnorm}");
    }

    #[test]
    fn sketched_omp_finds_planted_atom() {
        let mut rng = Rng::new(53);
        let p = 64;
        let g = random_matrix(&mut rng, 32, p);
        let planted = 17usize;
        let target: Vec<f32> = g.row(planted).iter().map(|v| 5.0 * v).collect();
        let sk = Sketcher::new(p / 4, 71, 0);
        let opts = OmpOpts {
            k: 4,
            lambda: 1e-4,
            eps: 1e-6,
        };
        let solve = solve_sketched_omp(&sk, &g, &all_cols(p), &target, opts, true).unwrap();
        assert_eq!(
            solve.selected[0], planted,
            "a 5x planted atom must dominate the sketched correlations"
        );
        let wi = solve.selected.iter().position(|&s| s == planted).unwrap();
        assert!(
            (solve.weights[wi] - 5.0).abs() < 0.5,
            "full-width refit should recover the planted weight: {}",
            solve.weights[wi]
        );
        assert!(solve.sketch_secs >= 0.0 && solve.refit_secs >= 0.0);
    }

    #[test]
    fn jl_width_formula_sane() {
        // n=1024, eps=0.5 → 8·ln(1024)/0.25 ≈ 222
        let k = jl_width_for(1024, 0.5);
        assert!((200..250).contains(&k), "k={k}");
        assert!(jl_width_for(1024, 0.25) > k, "tighter eps needs more width");
    }
}
