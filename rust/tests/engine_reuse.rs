//! Round-reuse contracts of the selection engine, pinned device-free on
//! the counting oracle: one `SelectionEngine` serves N trainer-style
//! rounds through `reset_round` —
//!
//! - every round re-stages (the reset truly invalidates the per-snapshot
//!   cache: N × ⌈n/chunk⌉ dispatches over N rounds, never a stale hit);
//! - the staging buffers are recycled, not reallocated: from round 2 on
//!   the scatter reuses the pooled matrices (`stage_reused_buffers`),
//!   and the engine-round index counts up (`engine_round == i`);
//! - per-round selections are identical to N fresh engines — reuse is a
//!   pure optimization;
//! - within a round the shared-staging cache still works after a reset
//!   (request 2 of each round reports `stage_shared`).

use gradmatch::data::Dataset;
use gradmatch::engine::{SelectionEngine, SelectionReport, SelectionRequest};
use gradmatch::grads::SynthGrads;
use gradmatch::rng::Rng;
use gradmatch::tensor::Matrix;

const CHUNK: usize = 8;
const ROUNDS: usize = 4;

fn dataset(seed: u64, classes: usize, d: usize) -> Dataset {
    let mut y: Vec<i32> = Vec::new();
    for cls in 0..classes {
        let n_c = if cls == 0 { 30 } else { 9 };
        y.extend(std::iter::repeat(cls as i32).take(n_c));
    }
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut y);
    let n = y.len();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

fn request(strategy: &str, ground: Vec<usize>, budget: usize, tag: u64) -> SelectionRequest {
    SelectionRequest {
        strategy: strategy.into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: tag,
        ground,
        shards: None,
        sketch: None,
    }
}

#[test]
fn one_engine_over_n_rounds_matches_n_fresh_engines() {
    let (classes, h, d) = (4usize, 3usize, 5usize);
    let p = h * classes + classes;
    let train = dataset(51, classes, d);
    let val = dataset(52, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let budget = n / 4;
    let passes = n.div_ceil(CHUNK);

    // trainer-style: ONE engine, reset_round between rounds; two
    // requests per round exercise the within-round shared cache too
    let mut reused_reports: Vec<(SelectionReport, SelectionReport)> = Vec::new();
    let mut reused_oracle = SynthGrads::new(CHUNK, p);
    {
        let mut engine = SelectionEngine::with_oracle(&mut reused_oracle, &train, &val, h, classes);
        for round in 0..ROUNDS {
            if round > 0 {
                engine.reset_round(None);
            }
            let tag = 1000 + round as u64;
            let a = engine.select(&request("gradmatch", ground.clone(), budget, tag)).unwrap();
            let b = engine.select(&request("craig", ground.clone(), budget, tag)).unwrap();
            reused_reports.push((a, b));
        }
    }

    // reference: a fresh engine (and fresh counting oracle) per round
    let mut fresh_calls = 0usize;
    for (round, (a, b)) in reused_reports.iter().enumerate() {
        let tag = 1000 + round as u64;
        let mut oracle = SynthGrads::new(CHUNK, p);
        let (want_a, want_b) = {
            let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
            (
                engine.select(&request("gradmatch", ground.clone(), budget, tag)).unwrap(),
                engine.select(&request("craig", ground.clone(), budget, tag)).unwrap(),
            )
        };
        fresh_calls += oracle.grad_calls;
        assert_eq!(a.selection, want_a.selection, "round {round}: gradmatch drifted");
        assert_eq!(b.selection, want_b.selection, "round {round}: craig drifted");
    }

    // the reset invalidates the cache — every round re-stages exactly once
    assert_eq!(reused_oracle.grad_calls, ROUNDS * passes, "one staged pass per round");
    assert_eq!(reused_oracle.grad_calls, fresh_calls, "reuse must not add or skip passes");
    assert_eq!(reused_oracle.mean_calls, 0);

    for (round, (a, b)) in reused_reports.iter().enumerate() {
        // the engine-round index counts resets; both requests of a round
        // share it
        assert_eq!(a.stats.engine_round, round, "gradmatch round index");
        assert_eq!(b.stats.engine_round, round, "craig round index");
        // request 1 stages, request 2 rides the round's cache — also
        // after resets
        assert!(!a.stats.stage_shared, "round {round}: first request must stage");
        assert_eq!(a.stats.stage_dispatches, passes, "round {round}");
        assert!(b.stats.stage_shared, "round {round}: second request must share");
        assert_eq!(b.stats.stage_dispatches, 0, "round {round}");
        // from round 2 on the staging scatter recycles the pooled
        // buffers — the no-per-round-reallocation path
        if round == 0 {
            assert!(!a.stats.stage_reused_buffers, "round 0 has nothing to recycle");
        } else {
            assert!(
                a.stats.stage_reused_buffers,
                "round {round}: staging must recycle the previous round's buffers"
            );
        }
    }
}

#[test]
fn reset_round_pools_per_key_and_rejects_shape_changes() {
    // two stage widths live in the round; after a reset each re-stage
    // finds its own pooled buffers — and a changed ground set (different
    // per-class sizes) must NOT reuse them
    let (classes, h, d) = (3usize, 2usize, 4usize);
    let p = h * classes + classes;
    let train = dataset(61, classes, d);
    let val = dataset(62, classes, d);
    let n = train.len();
    let full: Vec<usize> = (0..n).collect();
    let half: Vec<usize> = (0..n / 2).collect();

    let mut oracle = SynthGrads::new(CHUNK, p);
    {
        let mut engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
        // round 0: both widths staged
        engine.select(&request("gradmatch", full.clone(), n / 4, 1)).unwrap();
        engine.select(&request("gradmatch-perclass", full.clone(), n / 4, 1)).unwrap();
        engine.reset_round(None);
        // round 1: same keys — both recycle
        let a = engine.select(&request("gradmatch", full.clone(), n / 4, 2)).unwrap();
        let b = engine.select(&request("gradmatch-perclass", full.clone(), n / 4, 2)).unwrap();
        assert!(a.stats.stage_reused_buffers, "class-slice stage must recycle");
        assert!(b.stats.stage_reused_buffers, "full-P stage must recycle");
        engine.reset_round(None);
        // round 2: a different ground set misses the pool (different key)
        let c = engine.select(&request("gradmatch", half.clone(), n / 8, 3)).unwrap();
        assert!(
            !c.stats.stage_reused_buffers,
            "a different ground set must stage into fresh buffers"
        );
        assert_eq!(c.stats.engine_round, 2);
    }
    // dispatch ledger: rounds 0 and 1 stage both widths over the full
    // set, round 2 stages the half set once
    let want = 4 * n.div_ceil(CHUNK) + (n / 2).div_ceil(CHUNK);
    assert_eq!(oracle.grad_calls, want);
}
