//! Sharded-selection scale bench (`BENCH_shard.json`): the two-level
//! hierarchical OMP path over a ground set an order of magnitude larger
//! than any single staged gradient matrix.
//!
//! Hard checks (exit code 1 on failure — CI runs this under `--bench`):
//! - the large round's ground set is ≥ 10× its `peak_staged_rows`, and
//!   the peak stays under the `max_staged_rows` budget;
//! - on a medium size where the flat path also runs, the sharded
//!   subset's gradient-matching error `‖Σ wᵢgᵢ − Σ g‖ / ‖Σ g‖` stays
//!   within tolerance of the flat subset's.
//!
//! Device-free: rounds run on the synthetic gradient oracle, so this
//! bench exercises exactly the staging/solve machinery the conformance
//! suites pin, at sizes they don't reach.

use gradmatch::bench_harness as bh;
use gradmatch::data::Dataset;
use gradmatch::engine::{SelectionEngine, SelectionRequest, ShardPlan};
use gradmatch::grads::{self, SynthGrads};
use gradmatch::rng::Rng;
use gradmatch::selection::Selection;
use gradmatch::tensor::Matrix;

const CHUNK: usize = 256;
const CLASSES: usize = 10;
const H: usize = 8;
const D: usize = 8;

fn synth(seed: u64, n: usize) -> Dataset {
    let mut rng = Rng::new(seed);
    let y: Vec<i32> = (0..n).map(|i| (i % CLASSES) as i32).collect();
    let x = Matrix::from_vec(n, D, (0..n * D).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes: CLASSES }
}

fn request(n: usize, budget: usize, shards: Option<ShardPlan>) -> SelectionRequest {
    SelectionRequest {
        strategy: "gradmatch-rust".into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: 7,
        ground: (0..n).collect(),
        shards,
        sketch: None,
    }
}

fn run_round(
    train: &Dataset,
    val: &Dataset,
    p: usize,
    req: &SelectionRequest,
) -> gradmatch::engine::SelectionReport {
    let mut oracle = SynthGrads::new(CHUNK, p);
    let engine = SelectionEngine::with_oracle(&mut oracle, train, val, H, CLASSES);
    engine.select(req).expect("round must solve")
}

/// Paper-style matching error of a weighted subset against the full
/// ground gradient sum: `‖Σ wᵢgᵢ − Σ g‖ / ‖Σ g‖` (weights are
/// class-sum calibrated on both paths, so the metric is comparable).
fn subset_error(store: &grads::GradientStore, sel: &Selection) -> f64 {
    let p = store.g.cols;
    let mut full = vec![0.0f64; p];
    for r in 0..store.g.rows {
        for (j, &v) in store.g.row(r).iter().enumerate() {
            full[j] += v as f64;
        }
    }
    let mut sub = vec![0.0f64; p];
    for (slot, &row) in sel.indices.iter().enumerate() {
        let w = sel.weights[slot] as f64;
        for (j, &v) in store.g.row(row).iter().enumerate() {
            sub[j] += w * v as f64;
        }
    }
    let num: f64 = full.iter().zip(&sub).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    let den: f64 = full.iter().map(|a| a * a).sum::<f64>().sqrt();
    num / den.max(1e-12)
}

fn main() {
    let p = H * CLASSES + CLASSES;
    let mut report = bh::BenchReport::new("shard_scale");
    let mut ok = true;

    // --- large round: ground set >= 10x any staged matrix -------------------
    let (n_large, budget_large, max_rows) = (36_000usize, 1_500usize, 3_000usize);
    bh::section(&format!(
        "shard_scale — large round (n={n_large}, budget={budget_large}, max_staged_rows={max_rows})"
    ));
    let train = synth(11, n_large);
    let val = synth(12, 500);
    let plan = ShardPlan { shards: 0, max_staged_rows: max_rows };
    let req = request(n_large, budget_large, Some(plan));
    let mut last = None;
    report.rec("large/sharded_round", 3, || {
        let rep = run_round(&train, &val, p, &req);
        last = Some(rep.stats.clone());
        rep.selection.indices.len()
    });
    let stats = last.expect("at least one iteration ran");
    println!(
        "  shards {}  peak staged rows {}  merge candidates {}  stage dispatches {}",
        stats.shards, stats.peak_staged_rows, stats.merge_candidates, stats.stage_dispatches
    );
    let ratio = n_large as f64 / stats.peak_staged_rows.max(1) as f64;
    ok &= bh::shape_check(
        &format!("peak staged rows {} <= budget {max_rows}", stats.peak_staged_rows),
        stats.peak_staged_rows <= max_rows,
    );
    ok &= bh::shape_check(
        &format!("ground set {ratio:.1}x larger than peak staged matrix (need >= 10x)"),
        ratio >= 10.0,
    );
    report.note_round("shard_large", &stats);
    report.note("shard/ground_rows", n_large as f64);
    report.note("shard/scale_ratio", ratio);

    // --- medium size: flat and sharded both run; quality within tolerance ---
    let (n_med, budget_med, max_rows_med) = (6_000usize, 600usize, 1_500usize);
    bh::section(&format!(
        "shard_scale — flat vs sharded quality (n={n_med}, budget={budget_med}, max_staged_rows={max_rows_med})"
    ));
    let train_med = synth(21, n_med);
    let flat_req = request(n_med, budget_med, None);
    let shard_req =
        request(n_med, budget_med, Some(ShardPlan { shards: 0, max_staged_rows: max_rows_med }));
    let mut flat_rep = None;
    report.rec("medium/flat_round", 3, || {
        flat_rep = Some(run_round(&train_med, &val, p, &flat_req));
    });
    let mut shard_rep = None;
    report.rec("medium/sharded_round", 3, || {
        shard_rep = Some(run_round(&train_med, &val, p, &shard_req));
    });
    let (flat_rep, shard_rep) = (flat_rep.unwrap(), shard_rep.unwrap());
    report.note_round("shard_medium", &shard_rep.stats);

    let ground: Vec<usize> = (0..n_med).collect();
    let mut oracle = SynthGrads::new(CHUNK, p);
    let store = grads::per_sample_grads_with(&mut oracle, &train_med, &ground)
        .expect("per-sample gradients for the error metric");
    let err_flat = subset_error(&store, &flat_rep.selection);
    let err_shard = subset_error(&store, &shard_rep.selection);
    println!(
        "  matching error: flat {err_flat:.4}  sharded {err_shard:.4}  (sharded peak {} rows vs flat {})",
        shard_rep.stats.peak_staged_rows, n_med
    );
    // tolerance: the merge round solves over a reduced pool against an
    // f32-accumulated global target, so exact parity is not expected —
    // but quality must stay in the same regime as the flat solve
    const TOL_RATIO: f64 = 2.0;
    const TOL_ABS: f64 = 0.05;
    ok &= bh::shape_check(
        &format!("sharded error {err_shard:.4} <= {TOL_RATIO}x flat {err_flat:.4} + {TOL_ABS}"),
        err_shard <= TOL_RATIO * err_flat + TOL_ABS,
    );
    ok &= bh::shape_check(
        "sharded round staged fewer rows at peak than the flat round",
        shard_rep.stats.peak_staged_rows < n_med,
    );
    report.note("shard/err_flat", err_flat);
    report.note("shard/err_sharded", err_shard);
    report.note("shard/err_ratio", err_shard / err_flat.max(1e-12));
    report.note("shard/checks_passed", if ok { 1.0 } else { 0.0 });

    report.write(&bh::bench_out_path("BENCH_shard.json")).expect("write bench report");
    if !ok {
        std::process::exit(1);
    }
}
