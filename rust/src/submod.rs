//! Submodular maximization substrate: facility location + lazy greedy.
//!
//! Powers the CRAIG baseline (Mirzasoleiman et al. 2020 — facility location
//! over gradient-space distances, medoid-count weights; §3.2 / Appendix B.7
//! of the paper) and the feature-space facility-location baseline of
//! Table 12.  The lazy greedy implementation exploits submodularity: stale
//! upper bounds sit in a max-heap and are only refreshed when popped
//! (Minoux's accelerated greedy), which in practice evaluates a small
//! fraction of the O(n·k) gains the naive greedy needs.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::tensor::Matrix;

/// Facility-location objective over a precomputed similarity matrix:
/// `F(S) = Σ_i max_{j∈S} sim[i][j]` (sims must be ≥ 0).
pub struct FacilityLocation<'a> {
    /// `[n, n]` pairwise similarities (ground set × ground set)
    pub sim: &'a Matrix,
    /// best coverage per element under the current selection
    cover: Vec<f32>,
}

impl<'a> FacilityLocation<'a> {
    pub fn new(sim: &'a Matrix) -> Self {
        assert_eq!(sim.rows, sim.cols, "facility location needs square sims");
        FacilityLocation { sim, cover: vec![0.0; sim.rows] }
    }

    /// Number of ground-set elements.
    pub fn n(&self) -> usize {
        self.sim.rows
    }

    /// Marginal gain of adding `j` to the current selection.
    pub fn gain(&self, j: usize) -> f64 {
        let mut g = 0.0f64;
        let col_stride = self.sim.cols;
        for i in 0..self.sim.rows {
            let s = self.sim.data[i * col_stride + j];
            let c = self.cover[i];
            if s > c {
                g += (s - c) as f64;
            }
        }
        g
    }

    /// Commit element `j` (update coverage).
    pub fn commit(&mut self, j: usize) {
        for i in 0..self.sim.rows {
            let s = self.sim.at(i, j);
            if s > self.cover[i] {
                self.cover[i] = s;
            }
        }
    }

    /// Current objective value.
    pub fn value(&self) -> f64 {
        self.cover.iter().map(|&v| v as f64).sum()
    }

    /// Medoid-count weights for a selection: `w_j = |{i : j = argmax_{s∈S}
    /// sim[i][s]}|` — CRAIG's weights (Lemma 2).  Every element votes for
    /// its best-covering selected medoid.
    pub fn medoid_weights(&self, selected: &[usize]) -> Vec<f32> {
        let mut w = vec![0.0f32; selected.len()];
        if selected.is_empty() {
            return w;
        }
        for i in 0..self.sim.rows {
            let mut best = 0usize;
            let mut best_s = f32::NEG_INFINITY;
            for (slot, &j) in selected.iter().enumerate() {
                let s = self.sim.at(i, j);
                if s > best_s {
                    best_s = s;
                    best = slot;
                }
            }
            w[best] += 1.0;
        }
        w
    }
}

#[derive(PartialEq)]
struct HeapItem {
    gain: f64,
    item: usize,
    /// round when this gain was computed (staleness marker)
    round: usize,
}

impl Eq for HeapItem {}

impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        self.gain
            .partial_cmp(&other.gain)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.item.cmp(&self.item))
    }
}

impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Result of a greedy maximization.
#[derive(Clone, Debug)]
pub struct GreedyResult {
    pub selected: Vec<usize>,
    /// objective value after each pick (monotone nondecreasing)
    pub values: Vec<f64>,
    /// total gain evaluations performed (lazy-greedy efficiency metric)
    pub evals: usize,
}

/// Lazy (accelerated) greedy under a cardinality constraint `k`.
pub fn lazy_greedy(fl: &mut FacilityLocation<'_>, k: usize) -> GreedyResult {
    let n = fl.n();
    let k = k.min(n);
    let mut heap = BinaryHeap::with_capacity(n);
    let mut evals = 0usize;
    // Under the empty selection `cover` is all-zero, so every initial
    // gain is the clamped column sum Σ_i max(sim[i][j], 0) — computed for
    // all n columns at once on the parallel blocked layer (the O(n²)
    // heap-seeding pass that used to dominate small-k builds).
    for (j, g) in crate::par::colsum_pos(fl.sim).into_iter().enumerate() {
        evals += 1;
        heap.push(HeapItem { gain: g, item: j, round: 0 });
    }
    let mut selected = Vec::with_capacity(k);
    let mut values = Vec::with_capacity(k);
    let mut taken = vec![false; n];
    let mut round = 0usize;
    while selected.len() < k {
        let top = match heap.pop() {
            Some(t) => t,
            None => break,
        };
        if taken[top.item] {
            continue;
        }
        if top.round == round {
            // fresh bound — by submodularity it is the true max
            fl.commit(top.item);
            taken[top.item] = true;
            selected.push(top.item);
            values.push(fl.value());
            round += 1;
        } else {
            let g = fl.gain(top.item);
            evals += 1;
            heap.push(HeapItem { gain: g, item: top.item, round });
        }
    }
    GreedyResult { selected, values, evals }
}

/// Naive greedy (reference for tests; O(n·k) gain evaluations).
pub fn naive_greedy(fl: &mut FacilityLocation<'_>, k: usize) -> GreedyResult {
    let n = fl.n();
    let k = k.min(n);
    let mut selected = Vec::with_capacity(k);
    let mut values = Vec::with_capacity(k);
    let mut taken = vec![false; n];
    let mut evals = 0usize;
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_g = f64::NEG_INFINITY;
        for j in 0..n {
            if taken[j] {
                continue;
            }
            let g = fl.gain(j);
            evals += 1;
            if g > best_g {
                best_g = g;
                best = j;
            }
        }
        if best == usize::MAX {
            break;
        }
        fl.commit(best);
        taken[best] = true;
        selected.push(best);
        values.push(fl.value());
    }
    GreedyResult { selected, values, evals }
}

/// Greedy set cover (Theorem 3 regime): select until the objective reaches
/// `target_value` or the ground set is exhausted.
pub fn greedy_cover(fl: &mut FacilityLocation<'_>, target_value: f64) -> GreedyResult {
    let n = fl.n();
    let mut res = GreedyResult { selected: Vec::new(), values: Vec::new(), evals: 0 };
    let mut taken = vec![false; n];
    while fl.value() < target_value && res.selected.len() < n {
        let mut best = usize::MAX;
        let mut best_g = 0.0f64;
        for j in 0..n {
            if taken[j] {
                continue;
            }
            let g = fl.gain(j);
            res.evals += 1;
            if g > best_g {
                best_g = g;
                best = j;
            }
        }
        if best == usize::MAX || best_g <= 0.0 {
            break;
        }
        fl.commit(best);
        taken[best] = true;
        res.selected.push(best);
        res.values.push(fl.value());
    }
    res
}

/// Build a similarity matrix from squared distances:
/// `sim[i][j] = d_max − dist[i][j]` (the CRAIG kernelization — constant
/// shift makes similarities non-negative without changing the argmax
/// structure).
pub fn sim_from_sqdist(dist: &Matrix) -> Matrix {
    let d_max = dist.data.iter().cloned().fold(0.0f32, f32::max);
    let mut sim = Matrix::zeros(dist.rows, dist.cols);
    for i in 0..dist.data.len() {
        sim.data[i] = d_max - dist.data[i];
    }
    sim
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::testutil::forall;

    fn random_sim(n: usize, rng: &mut Rng) -> Matrix {
        // symmetric nonneg similarities with self-similarity maximal
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..i {
                let v = rng.f32();
                m.set(i, j, v);
                m.set(j, i, v);
            }
            m.set(i, i, 1.5);
        }
        m
    }

    #[test]
    fn lazy_equals_naive_greedy() {
        forall(15, |g| {
            let n = g.int(3, 25);
            let mut rng = Rng::new(g.case as u64 + 100);
            let sim = random_sim(n, &mut rng);
            let k = g.int(1, n);
            let lazy = lazy_greedy(&mut FacilityLocation::new(&sim), k);
            let naive = naive_greedy(&mut FacilityLocation::new(&sim), k);
            assert_eq!(lazy.selected, naive.selected, "n={n} k={k}");
            assert!(lazy.evals <= naive.evals);
        });
    }

    #[test]
    fn greedy_values_monotone_nondecreasing() {
        let mut rng = Rng::new(3);
        let sim = random_sim(30, &mut rng);
        let res = lazy_greedy(&mut FacilityLocation::new(&sim), 10);
        for w in res.values.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
    }

    #[test]
    fn diminishing_returns_of_gain() {
        // submodularity: gain(j | S) >= gain(j | S ∪ {e})
        let mut rng = Rng::new(4);
        let sim = random_sim(12, &mut rng);
        let mut fl = FacilityLocation::new(&sim);
        let j = 5;
        let before = fl.gain(j);
        fl.commit(2);
        let after = fl.gain(j);
        assert!(after <= before + 1e-9);
    }

    #[test]
    fn medoid_weights_sum_to_ground_set_size() {
        let mut rng = Rng::new(5);
        let sim = random_sim(20, &mut rng);
        let mut fl = FacilityLocation::new(&sim);
        let res = lazy_greedy(&mut fl, 4);
        let w = fl.medoid_weights(&res.selected);
        let total: f32 = w.iter().sum();
        assert!((total - 20.0).abs() < 1e-5);
        assert!(w.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn every_element_covers_itself_when_selected() {
        let mut rng = Rng::new(6);
        let sim = random_sim(8, &mut rng);
        let mut fl = FacilityLocation::new(&sim);
        let res = lazy_greedy(&mut fl, 8);
        // selecting everything covers every row at its self-similarity
        assert_eq!(res.selected.len(), 8);
        assert!((fl.value() - 8.0 * 1.5) < 1e-4);
    }

    #[test]
    fn greedy_cover_reaches_target_or_exhausts() {
        let mut rng = Rng::new(7);
        let sim = random_sim(15, &mut rng);
        let full_value = {
            let mut fl = FacilityLocation::new(&sim);
            lazy_greedy(&mut fl, 15);
            fl.value()
        };
        let mut fl = FacilityLocation::new(&sim);
        let res = greedy_cover(&mut fl, 0.8 * full_value);
        assert!(fl.value() >= 0.8 * full_value);
        assert!(res.selected.len() < 15, "cover should need fewer than all");
    }

    #[test]
    fn sim_from_sqdist_properties() {
        let d = Matrix::from_vec(2, 2, vec![0.0, 4.0, 4.0, 0.0]);
        let s = sim_from_sqdist(&d);
        // self-sim maximal, all entries nonneg
        assert_eq!(s.at(0, 0), 4.0);
        assert_eq!(s.at(0, 1), 0.0);
        assert!(s.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn first_pick_is_global_best() {
        let mut rng = Rng::new(8);
        let sim = random_sim(20, &mut rng);
        let res = lazy_greedy(&mut FacilityLocation::new(&sim), 1);
        let mut fl2 = FacilityLocation::new(&sim);
        let best = (0..20)
            .max_by(|&a, &b| fl2.gain(a).partial_cmp(&fl2.gain(b)).unwrap())
            .unwrap();
        let _ = &mut fl2;
        assert_eq!(res.selected[0], best);
    }
}
