//! Hot-path micro benches — the profiling substrate for the §Perf pass
//! (EXPERIMENTS.md).  Measures each layer's unit costs in isolation and
//! emits a machine-readable `BENCH_micro.json` so successive PRs can
//! track the perf trajectory:
//!
//! - scalar reference kernels vs the parallel blocked layer
//!   (`dot`/`gemv`/`gram`/pairwise-`sqdist`)
//! - end-to-end OMP: the seed per-round-GEMV solver vs the Batch-OMP
//!   correlation recurrence, with identity checks on the selected
//!   support (n=4096, P=256 — the acceptance ground set)
//! - the **selection round**: serial-classes baseline (one gradient pass
//!   + one target pass per class, serial solves) vs the staged fan-out
//!   engine (single ground pass, class-parallel solves) at C ∈ {10, 100}
//!   with imbalanced class sizes, on the synthetic gradient oracle —
//!   with the staging-vs-solve speedup decomposition in the JSON notes
//! - L3→PJRT `train_step` latency, gradient acquisition, Pallas
//!   `corr_chunk`/`sqdist_chunk` vs Rust (skipped with a note when the
//!   HLO artifacts / PJRT backend are unavailable)
//! - lazy vs naive submodular greedy

use gradmatch::bench_harness as bh;
use gradmatch::data::{Dataset, DatasetCard};
use gradmatch::engine::{Degradation, SelectionEngine, SelectionRequest};
use gradmatch::fault::{FaultPlan, FaultyOracle};
use gradmatch::grads::{
    class_columns, mean_gradient_with, per_sample_grads_with, stage_class_grads_with, StageWidth,
    SynthGrads,
};
use gradmatch::omp::{
    omp_select, omp_select_ref, omp_select_rust, CorrBackend, OmpOpts, RustCorr, XlaCorr,
};
use gradmatch::par;
use gradmatch::rng::Rng;
use gradmatch::runtime::Runtime;
use gradmatch::selection::{
    sketch_col_maps, solve_classes_omp, solve_classes_omp_sketched, split_budget, GradMatch,
    GradMatchVariant, GradSource, SelectCtx, Selection, Strategy,
};
use gradmatch::sketch::Sketcher;
use gradmatch::submod::{lazy_greedy, naive_greedy, sim_from_sqdist, FacilityLocation};
use gradmatch::tensor::{self, Matrix};

/// The seed correlation backend: single-thread `tensor::gemv` (what
/// `RustCorr` was before the parallel blocked layer).
struct ScalarCorr<'a> {
    g: &'a Matrix,
}

impl CorrBackend for ScalarCorr<'_> {
    fn corr(&mut self, v: &[f32]) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.g.rows];
        tensor::gemv(self.g, v, &mut out);
        Ok(out)
    }

    fn len(&self) -> usize {
        self.g.rows
    }
}

fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
    Matrix::from_vec(rows, cols, (0..rows * cols).map(|_| rng.gaussian_f32()).collect())
}

fn main() -> anyhow::Result<()> {
    let mut report = bh::BenchReport::new("micro_hotpath");
    let mut rng = Rng::new(42);
    report.note("threads", par::num_threads() as f64);

    // --- scalar reference vs parallel blocked kernels ------------------------
    bh::section(&format!(
        "micro — scalar vs parallel kernels ({} threads)",
        par::num_threads()
    ));
    let len = 1usize << 16;
    let va: Vec<f32> = (0..len).map(|_| rng.gaussian_f32()).collect();
    let vb: Vec<f32> = (0..len).map(|_| rng.gaussian_f32()).collect();
    let (dot_ref, _) = report.rec(&format!("dot {len} (scalar ref)"), 200, || tensor::dot(&va, &vb));
    let (dot_par, _) = report.rec(&format!("dot {len} (unrolled)"), 200, || par::dot(&va, &vb));
    report.note("dot_speedup", dot_ref / dot_par.max(1e-12));

    let (n, p) = (4096usize, 256usize);
    let g = random_matrix(&mut rng, n, p);
    let v: Vec<f32> = (0..p).map(|_| rng.gaussian_f32()).collect();
    let mut out = vec![0.0f32; n];
    let (gemv_ref, _) = report.rec(&format!("gemv {n}x{p} (scalar ref)"), 30, || {
        tensor::gemv(&g, &v, &mut out);
        out[0]
    });
    let mut out2 = vec![0.0f32; n];
    let (gemv_par, _) = report.rec(&format!("gemv {n}x{p} (parallel)"), 30, || {
        par::gemv(&g, &v, &mut out2);
        out2[0]
    });
    report.note("gemv_speedup", gemv_ref / gemv_par.max(1e-12));
    bh::shape_check(
        "parallel gemv matches scalar",
        out.iter().zip(&out2).all(|(a, b)| (a - b).abs() <= 1e-3 * (1.0 + a.abs())),
    );

    let gm = random_matrix(&mut rng, 768, 256);
    let (gram_ref, _) = report.rec("gram 768x256 (scalar ref)", 3, || tensor::gram(&gm));
    let (gram_par, _) = report.rec("gram 768x256 (parallel)", 3, || par::gram(&gm));
    report.note("gram_speedup", gram_ref / gram_par.max(1e-12));

    let (sq_ref, _) = report.rec("sqdist 768x768 pairwise (scalar ref)", 3, || {
        let mut d = Matrix::zeros(gm.rows, gm.rows);
        for i in 0..gm.rows {
            for j in i..gm.rows {
                let vv = tensor::sqdist(gm.row(i), gm.row(j));
                d.set(i, j, vv);
                d.set(j, i, vv);
            }
        }
        d
    });
    let (sq_par, _) =
        report.rec("sqdist 768x768 pairwise (parallel)", 3, || par::pairwise_sqdist(&gm));
    report.note("sqdist_speedup", sq_ref / sq_par.max(1e-12));

    // --- end-to-end OMP: seed solver vs Batch-OMP ----------------------------
    bh::section(&format!("micro — OMP n={n} P={p}: seed per-round GEMV vs Batch-OMP"));
    let target: Vec<f32> = (0..p).map(|_| rng.gaussian_f32()).collect();
    let opts = OmpOpts { k: 32, lambda: 0.5, eps: 1e-12 };
    let row = |j: usize| g.row(j).to_vec();
    let mut seed_backend = ScalarCorr { g: &g };
    let (omp_old, _) = report.rec(&format!("omp k={} n={n} (seed solver)", opts.k), 3, || {
        omp_select_ref(&mut seed_backend, &row, &target, opts).unwrap()
    });
    let mut par_backend = RustCorr { g: &g };
    // matched-backend row: seed algorithm over the parallel backend, so
    // the JSON separates the recurrence win from the threading win
    let (omp_old_par, _) =
        report.rec(&format!("omp k={} n={n} (seed solver, par gemv)", opts.k), 3, || {
            omp_select_ref(&mut par_backend, &row, &target, opts).unwrap()
        });
    let (omp_new, _) = report.rec(&format!("omp k={} n={n} (batch-omp)", opts.k), 3, || {
        omp_select(&mut par_backend, &row, &target, opts).unwrap()
    });
    let old_res = omp_select_ref(&mut seed_backend, &row, &target, opts)?;
    let new_res = omp_select(&mut par_backend, &row, &target, opts)?;
    let identical = old_res.selected == new_res.selected;
    let resid_close =
        (old_res.residual_norm - new_res.residual_norm).abs() <= 1e-4 * (1.0 + old_res.residual_norm);
    let speedup = omp_old / omp_new.max(1e-12);
    report.note("omp_identical_support", if identical { 1.0 } else { 0.0 });
    report.note("omp_residual_close", if resid_close { 1.0 } else { 0.0 });
    // end-to-end old-vs-new (recurrence + parallel layer — the PR's claim)
    report.note("omp_speedup", speedup);
    // decomposition: algorithm-only (matched backend) and backend-only
    report.note("omp_speedup_recurrence_only", omp_old_par / omp_new.max(1e-12));
    report.note("omp_speedup_backend_only", omp_old / omp_old_par.max(1e-12));
    bh::shape_check("batch-omp support identical to seed solver", identical);
    bh::shape_check("batch-omp residual within 1e-4 of seed solver", resid_close);
    bh::shape_check(&format!("batch-omp >= 2x over seed solver ({speedup:.2}x)"), speedup >= 2.0);

    // --- lazy vs naive greedy (backend-independent) --------------------------
    bh::section("micro — submodular greedy");
    let ns = 600;
    let gsub = random_matrix(&mut rng, ns, 64);
    let dist = par::pairwise_sqdist(&gsub);
    let sim = sim_from_sqdist(&dist);
    report.rec(&format!("lazy_greedy n={ns} k=60"), 5, || {
        lazy_greedy(&mut FacilityLocation::new(&sim), 60)
    });
    report.rec(&format!("naive_greedy n={ns} k=60"), 2, || {
        naive_greedy(&mut FacilityLocation::new(&sim), 60)
    });
    let lazy = lazy_greedy(&mut FacilityLocation::new(&sim), 60);
    let naive = naive_greedy(&mut FacilityLocation::new(&sim), 60);
    println!(
        "  lazy evals {} vs naive evals {} ({}x fewer)",
        lazy.evals,
        naive.evals,
        naive.evals / lazy.evals.max(1)
    );
    bh::shape_check("lazy greedy matches naive selection", lazy.selected == naive.selected);

    // --- selection round: serial classes vs staged fan-out -------------------
    // End-to-end per-class GRAD-MATCH rounds on the synthetic gradient
    // oracle (dispatch-shaped cost, no device needed): the serial-classes
    // baseline pays one padded gradient pass + one target pass per class
    // and solves serially; the engine pays one staged ground pass and
    // fans the solves out.  C=10 and C=100, imbalanced class sizes (the
    // imbalance is exactly what makes per-class padding waste hurt).
    bh::section(&format!(
        "micro — selection round: serial classes vs staged fan-out ({} threads)",
        par::num_threads()
    ));
    for &(c, heavy_n, small_n, tag) in
        &[(10usize, 512usize, 96usize, "c10"), (100, 256, 32, "c100")]
    {
        let (h, d, chunk) = (32usize, 64usize, 256usize);
        let p = h * c + c;
        let heavy_classes = (c / 5).max(1);
        let mut y: Vec<i32> = Vec::new();
        for cls in 0..c {
            let n_c = if cls < heavy_classes { heavy_n } else { small_n };
            y.extend(std::iter::repeat(cls as i32).take(n_c));
        }
        let mut shuffle_rng = Rng::new(4242);
        shuffle_rng.shuffle(&mut y);
        let n = y.len();
        let ds = Dataset {
            x: Matrix::from_vec(n, d, (0..n * d).map(|_| shuffle_rng.gaussian_f32()).collect()),
            y,
            classes: c,
        };
        let ground: Vec<usize> = (0..n).collect();
        let budget = (n / 10).max(c);
        let (lambda, eps) = (0.5f32, 1e-12f32);

        // class row lists + budgets are identical on both paths
        let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); c];
        for &i in &ground {
            per_class[ds.y[i] as usize].push(i);
        }
        let sizes: Vec<usize> = per_class.iter().map(Vec::len).collect();
        let budgets = split_budget(budget, &sizes);

        // the pre-engine serial round (per-class passes, serial solves)
        let serial_round = || -> Selection {
            let mut out = Selection::default();
            for (cls, rows) in per_class.iter().enumerate() {
                if rows.is_empty() || budgets[cls] == 0 {
                    continue;
                }
                let mut oracle = SynthGrads::new(chunk, p);
                let store = per_sample_grads_with(&mut oracle, &ds, rows).unwrap();
                let target_full = mean_gradient_with(&mut oracle, &ds, rows).unwrap();
                let cols = class_columns(h, c, cls);
                let g = store.g.gather_cols(&cols);
                let target: Vec<f32> = cols.iter().map(|&j| target_full[j]).collect();
                let res = omp_select_rust(
                    &g,
                    &target,
                    OmpOpts { k: budgets[cls], lambda, eps },
                )
                .unwrap();
                let scale = rows.len() as f32;
                for (slot, &j) in res.selected.iter().enumerate() {
                    out.indices.push(rows[j]);
                    out.weights.push(res.weights[slot] * scale);
                }
            }
            out
        };
        // the engine round (one staged pass, class fan-out)
        let fanout_round = || -> Selection {
            let mut oracle = SynthGrads::new(chunk, p);
            let stages = stage_class_grads_with(
                &mut oracle,
                &ds,
                &ground,
                h,
                c,
                StageWidth::ClassSlice,
                true,
            )
            .unwrap();
            let targets: Vec<Vec<f32>> = stages
                .iter()
                .enumerate()
                .map(|(cls, s)| {
                    class_columns(h, c, cls).iter().map(|&j| s.target_full[j]).collect()
                })
                .collect();
            solve_classes_omp(&stages, &budgets, &targets, lambda, eps, true).unwrap()
        };

        let (round_serial, _) =
            report.rec(&format!("round {tag} n={n} (serial classes)"), 3, serial_round);
        let (round_fanout, _) =
            report.rec(&format!("round {tag} n={n} (staged fan-out)"), 3, fanout_round);
        let round_speedup = round_serial / round_fanout.max(1e-12);
        report.note(&format!("round_speedup_{tag}"), round_speedup);

        // decomposition: staging alone (acquisition passes) …
        let (stage_serial, _) =
            report.rec(&format!("round {tag} staging (per-class passes)"), 3, || {
                let mut total = 0usize;
                for rows in per_class.iter().filter(|r| !r.is_empty()) {
                    let mut oracle = SynthGrads::new(chunk, p);
                    let store = per_sample_grads_with(&mut oracle, &ds, rows).unwrap();
                    let target = mean_gradient_with(&mut oracle, &ds, rows).unwrap();
                    total += store.g.rows + target.len();
                }
                total
            });
        let (stage_fanout, _) =
            report.rec(&format!("round {tag} staging (single pass)"), 3, || {
                let mut oracle = SynthGrads::new(chunk, p);
                stage_class_grads_with(&mut oracle, &ds, &ground, h, c, StageWidth::ClassSlice, true)
                    .unwrap()
                    .len()
            });
        report.note(
            &format!("round_staging_speedup_{tag}"),
            stage_serial / stage_fanout.max(1e-12),
        );
        // … and the solve fan-out alone (same staged inputs)
        let mut oracle = SynthGrads::new(chunk, p);
        let stages =
            stage_class_grads_with(&mut oracle, &ds, &ground, h, c, StageWidth::ClassSlice, true)
                .unwrap();
        let targets: Vec<Vec<f32>> = stages
            .iter()
            .enumerate()
            .map(|(cls, s)| class_columns(h, c, cls).iter().map(|&j| s.target_full[j]).collect())
            .collect();
        let (solve_serial, _) = report.rec(&format!("round {tag} solves (serial)"), 3, || {
            solve_classes_omp(&stages, &budgets, &targets, lambda, eps, false).unwrap()
        });
        let (solve_fanout, _) = report.rec(&format!("round {tag} solves (fan-out)"), 3, || {
            solve_classes_omp(&stages, &budgets, &targets, lambda, eps, true).unwrap()
        });
        report.note(
            &format!("round_solve_speedup_{tag}"),
            solve_serial / solve_fanout.max(1e-12),
        );

        // dispatch-count contract (also pinned by tests/round_engine.rs)
        let mut count_oracle = SynthGrads::new(chunk, p);
        stage_class_grads_with(&mut count_oracle, &ds, &ground, h, c, StageWidth::ClassSlice, true)
            .unwrap();
        let staged_dispatches = count_oracle.grad_calls + count_oracle.mean_calls;
        let serial_dispatches: usize =
            sizes.iter().filter(|&&s| s > 0).map(|&s| 2 * s.div_ceil(chunk)).sum();
        report.note(&format!("round_dispatches_staged_{tag}"), staged_dispatches as f64);
        report.note(&format!("round_dispatches_serial_{tag}"), serial_dispatches as f64);
        bh::shape_check(
            &format!(
                "round {tag}: staged pass = ⌈n/chunk⌉ = {} dispatches (serial {})",
                n.div_ceil(chunk),
                serial_dispatches
            ),
            staged_dispatches == n.div_ceil(chunk),
        );

        // the fan-out path is pinned to the serial reference
        let a = serial_round();
        let b = fanout_round();
        let supports_equal = a.indices == b.indices;
        let weights_close = a
            .weights
            .iter()
            .zip(&b.weights)
            .all(|(x, y)| (x - y).abs() <= 1e-4 * (1.0 + y.abs()));
        bh::shape_check(&format!("round {tag}: fan-out support == serial"), supports_equal);
        bh::shape_check(&format!("round {tag}: fan-out weights within 1e-4"), weights_close);
        report.note(
            &format!("round_identical_support_{tag}"),
            if supports_equal { 1.0 } else { 0.0 },
        );
        if tag == "c10" {
            bh::shape_check(
                &format!("round c10: staged fan-out >= 2x over serial classes ({round_speedup:.2}x)"),
                round_speedup >= 2.0,
            );
        }
    }

    // --- selection engine: shared staging across a multi-strategy round -----
    // The engine API contract in miniature: three requests (gradmatch,
    // gradmatch-warm, craig) against one model state share ONE staged
    // pass — ⌈n/chunk⌉ dispatches total — where three solo engines pay
    // one each.  (Pinned exactly by the counting-oracle test in
    // tests/engine_api.rs.)
    bh::section("micro — selection engine: 3-strategy round, shared staging");
    {
        let (c, h, d, chunk) = (10usize, 32usize, 64usize, 256usize);
        let p = h * c + c;
        let mut y: Vec<i32> = Vec::new();
        for cls in 0..c {
            let n_c = if cls < 2 { 512 } else { 96 };
            y.extend(std::iter::repeat(cls as i32).take(n_c));
        }
        let mut eng_rng = Rng::new(777);
        eng_rng.shuffle(&mut y);
        let n = y.len();
        let train = Dataset {
            x: Matrix::from_vec(n, d, (0..n * d).map(|_| eng_rng.gaussian_f32()).collect()),
            y,
            classes: c,
        };
        let val = Dataset { x: Matrix::zeros(4, d), y: vec![0, 1, 2, 3], classes: c };
        let base = SelectionRequest {
            strategy: "gradmatch".into(),
            budget: (n / 10).max(c),
            lambda: 0.5,
            eps: 1e-10,
            is_valid: false,
            seed: 42,
            rng_tag: 1,
            ground: (0..n).collect(),
            shards: None,
            sketch: None,
        };
        let specs = ["gradmatch", "gradmatch-warm", "craig"];
        let reqs: Vec<SelectionRequest> = specs
            .iter()
            .map(|spec| {
                let mut r = base.clone();
                r.strategy = spec.to_string();
                r
            })
            .collect();
        let mut shared_oracle = SynthGrads::new(chunk, p);
        let (reports, secs, round2, round2_secs) = {
            let mut engine = SelectionEngine::with_oracle(&mut shared_oracle, &train, &val, h, c);
            let (reports, secs) = bh::timed(|| engine.select_batch(&reqs).unwrap());
            // round 2 on the SAME engine: reset_round invalidates the
            // staged cache (new model state) but recycles the staging
            // buffers, so the re-staged pass skips the [n, w] allocations
            engine.reset_round(None);
            let (round2, round2_secs) = bh::timed(|| engine.select_batch(&reqs).unwrap());
            (reports, secs, round2, round2_secs)
        };
        println!(
            "  3-strategy round (shared staging): {:.3}ms; round 2 via reset_round: {:.3}ms",
            secs * 1e3,
            round2_secs * 1e3
        );
        report.note("engine_round_secs", secs);
        report.note("engine_round2_reused_secs", round2_secs);
        report.note("engine_shared_dispatches", reports[0].stats.stage_dispatches as f64);
        for (spec, rep) in specs.iter().zip(&reports) {
            report.note_round(&format!("engine/{spec}"), &rep.stats);
        }
        // solo baseline: each strategy staging privately
        let mut solo_calls = 0usize;
        for spec in specs {
            let mut oracle = SynthGrads::new(chunk, p);
            let mut r = base.clone();
            r.strategy = spec.to_string();
            {
                let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, c);
                engine.select(&r).unwrap();
            }
            solo_calls += oracle.grad_calls;
        }
        report.note("engine_solo_dispatches", solo_calls as f64);
        bh::shape_check(
            &format!(
                "engine: each 3-strategy round shares one staged pass — {} dispatches over 2 rounds (solo {})",
                shared_oracle.grad_calls, solo_calls
            ),
            shared_oracle.grad_calls == 2 * n.div_ceil(chunk)
                && reports[0].stats.stage_dispatches == n.div_ceil(chunk)
                && solo_calls == 3 * n.div_ceil(chunk),
        );
        bh::shape_check(
            "engine: later requests report stage_shared",
            !reports[0].stats.stage_shared
                && reports[1].stats.stage_shared
                && reports[2].stats.stage_shared,
        );
        bh::shape_check(
            "engine: round 2 recycles staging buffers and counts the reuse",
            round2[0].stats.stage_reused_buffers
                && round2[0].stats.engine_round == 1
                && round2[0].stats.stage_dispatches == n.div_ceil(chunk)
                && round2[1].stats.stage_shared,
        );
    }

    // --- fault tolerance: wrapper overhead + degradation ladder --------------
    // The zero-fault FaultyOracle must be free (no RNG draws, no sleeps)
    // so the fault-injection suites measure the *tolerance* layer, not
    // the wrapper; and a degraded round must cost no more than a normal
    // one (it reuses the last subset after the retry budget drains).
    bh::section("micro — fault tolerance: zero-fault wrapper overhead, degraded round");
    {
        let (c, h, d, chunk) = (10usize, 32usize, 64usize, 256usize);
        let p = h * c + c;
        let mut y: Vec<i32> = Vec::new();
        for cls in 0..c {
            y.extend(std::iter::repeat(cls as i32).take(128));
        }
        let mut f_rng = Rng::new(1313);
        f_rng.shuffle(&mut y);
        let n = y.len();
        let train = Dataset {
            x: Matrix::from_vec(n, d, (0..n * d).map(|_| f_rng.gaussian_f32()).collect()),
            y,
            classes: c,
        };
        let val = Dataset { x: Matrix::zeros(4, d), y: vec![0, 1, 2, 3], classes: c };
        let req = SelectionRequest {
            strategy: "gradmatch".into(),
            budget: (n / 10).max(c),
            lambda: 0.5,
            eps: 1e-10,
            is_valid: false,
            seed: 42,
            rng_tag: 7,
            ground: (0..n).collect(),
            shards: None,
            sketch: None,
        };
        let bare_round = || {
            let mut oracle = SynthGrads::new(chunk, p);
            let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, c);
            engine.select(&req).unwrap()
        };
        let wrapped_round = || {
            let mut inner = SynthGrads::new(chunk, p);
            let mut faulty = FaultyOracle::new(&mut inner, FaultPlan::none(42));
            let engine = SelectionEngine::with_oracle(&mut faulty, &train, &val, h, c);
            engine.select(&req).unwrap()
        };
        let (t_bare, _) = report.rec(&format!("round c10 n={n} (bare oracle)"), 3, bare_round);
        let (t_wrapped, _) =
            report.rec(&format!("round c10 n={n} (zero-fault FaultyOracle)"), 3, wrapped_round);
        report.note("fault_wrapper_overhead", t_wrapped / t_bare.max(1e-12));
        let a = bare_round();
        let b = wrapped_round();
        bh::shape_check(
            "zero-fault wrapper: selection bit-identical to bare oracle",
            a.selection == b.selection
                && b.stats.retries == 0
                && b.stats.quarantined == 0
                && b.stats.degradation == Degradation::None,
        );
        report.note_round("round_faultfree", &b.stats);

        // degraded round: clean round one, dead oracle from round two on —
        // the ladder serves round one's subset instead of erroring out
        let attempts_per_round = {
            let mut inner = SynthGrads::new(chunk, p);
            let mut probe = FaultyOracle::new(&mut inner, FaultPlan::none(42));
            {
                let engine = SelectionEngine::with_oracle(&mut probe, &train, &val, h, c);
                engine.select(&req).unwrap();
            }
            probe.attempts
        };
        let mut inner = SynthGrads::new(chunk, p);
        let mut plan = FaultPlan::none(42);
        plan.fail_from = attempts_per_round + 1;
        let mut faulty = FaultyOracle::new(&mut inner, plan);
        let mut engine = SelectionEngine::with_oracle(&mut faulty, &train, &val, h, c);
        let clean = engine.select(&req).unwrap();
        engine.reset_round(None);
        let degraded = engine.select(&req).unwrap();
        bh::shape_check(
            "degraded round reuses the last subset (never a panic)",
            degraded.stats.degradation == Degradation::ReusedLastRound
                && degraded.selection.indices == clean.selection.indices,
        );
        report.note_round("round_degraded", &degraded.stats);
    }

    // --- sketched correlation: width sweep (JL-projected Batch-OMP) ----------
    // One staged full-width per-class problem, solved flat and at
    // k ∈ {P/2, P/4, P/8, P/16} with the full-width re-fit on — the
    // speedup-vs-quality curve for picking `selection.sketch_width`.
    // The budget is deliberately larger than the narrow widths: sketching
    // pays when OMP iterations outnumber k, and the sweep shows the
    // crossover (wide sketches can LOSE — the projection itself costs
    // `n·P·k`).
    bh::section("micro — sketched solve: width sweep k ∈ {P/2, P/4, P/8, P/16}");
    {
        let (c, h, d, chunk) = (4usize, 64usize, 64usize, 256usize);
        let p = h * c + c; // 260
        let (n, budget) = (1024usize, 256usize);
        let (lambda, eps) = (0.5f32, 1e-12f32);
        let mut sk_rng = Rng::new(2718);
        let y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
        let ds = Dataset {
            x: Matrix::from_vec(n, d, (0..n * d).map(|_| sk_rng.gaussian_f32()).collect()),
            y,
            classes: c,
        };
        let ground: Vec<usize> = (0..n).collect();
        let mut oracle = SynthGrads::new(chunk, p);
        let stages =
            stage_class_grads_with(&mut oracle, &ds, &ground, h, c, StageWidth::Full, true)?;
        let sizes: Vec<usize> = stages.iter().map(|s| s.rows.len()).collect();
        let budgets = split_budget(budget, &sizes);
        let targets: Vec<Vec<f32>> = stages.iter().map(|s| s.target_full.clone()).collect();
        let col_maps = sketch_col_maps(h, c, false, p);

        let (t_flat, _) = report.rec(&format!("sketch sweep P={p} (flat solve)"), 3, || {
            solve_classes_omp(&stages, &budgets, &targets, lambda, eps, true).unwrap()
        });
        // solves are deterministic — one un-timed re-run yields the result
        let flat_sel = solve_classes_omp(&stages, &budgets, &targets, lambda, eps, true)?;

        // paper-style matched-gradient error of a weighted subset against
        // the full ground gradient sum (the shard-scale bench's metric)
        let mut err_oracle = SynthGrads::new(chunk, p);
        let store = per_sample_grads_with(&mut err_oracle, &ds, &ground)?;
        let err_of = |sel: &Selection| -> f64 {
            let mut full = vec![0.0f64; p];
            for r in 0..store.g.rows {
                for (j, &v) in store.g.row(r).iter().enumerate() {
                    full[j] += v as f64;
                }
            }
            let mut sub = vec![0.0f64; p];
            for (slot, &row) in sel.indices.iter().enumerate() {
                let w = sel.weights[slot] as f64;
                for (j, &v) in store.g.row(row).iter().enumerate() {
                    sub[j] += w * v as f64;
                }
            }
            let num: f64 =
                full.iter().zip(&sub).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
            let den: f64 = full.iter().map(|a| a * a).sum::<f64>().sqrt();
            num / den.max(1e-12)
        };
        let err_flat = err_of(&flat_sel);
        report.note("sketch_err_flat", err_flat);

        for (div, tag) in [(2usize, "p2"), (4usize, "p4"), (8usize, "p8"), (16usize, "p16")] {
            let k = (p / div).max(1);
            let sk = Sketcher::new(k, 0x5EED, 0);
            let (t_k, _) = report.rec(&format!("sketch sweep P={p} (k={k}, refit)"), 3, || {
                solve_classes_omp_sketched(
                    &stages, &budgets, &targets, lambda, eps, true, None, &sk, &col_maps, true,
                )
                .unwrap()
            });
            let (sel, sk_secs, rf_secs) = solve_classes_omp_sketched(
                &stages, &budgets, &targets, lambda, eps, true, None, &sk, &col_maps, true,
            )?;
            let speedup = t_flat / t_k.max(1e-12);
            let err_k = err_of(&sel);
            report.note(&format!("sketch_speedup_{tag}"), speedup);
            report.note(&format!("sketch_err_{tag}"), err_k);
            println!(
                "  k={k}: {speedup:.2}x vs flat, err {err_k:.4} (flat {err_flat:.4}; project {:.3}ms refit {:.3}ms)",
                sk_secs * 1e3,
                rf_secs * 1e3
            );
            bh::shape_check(
                &format!("sketch k={k}: selection within budget and finite"),
                !sel.indices.is_empty()
                    && sel.indices.len() <= budget
                    && sel.weights.iter().all(|w| w.is_finite()),
            );
            // quality gate: wide sketches must stay in the flat regime;
            // narrow ones only need the re-fit to beat the empty subset
            if div <= 4 {
                bh::shape_check(
                    &format!(
                        "sketch k={k}: error {err_k:.4} within the flat regime ({err_flat:.4})"
                    ),
                    err_k <= 3.0 * err_flat + 0.2,
                );
            } else {
                bh::shape_check(
                    &format!("sketch k={k}: re-fit beats the empty subset ({err_k:.4})"),
                    err_k < 1.0,
                );
            }
        }
    }

    // --- XLA/PJRT-backed sections (need HLO artifacts) -----------------------
    // A failure here must not discard the pure-Rust records above: note
    // it and still write the report.
    match Runtime::load(bh::artifacts_dir()) {
        Ok(rt) => match xla_sections(&rt, &mut report) {
            Ok(()) => report.note("xla_sections", 1.0),
            Err(e) => {
                println!("  XLA sections aborted: {e:#}");
                report.note("xla_sections", -1.0);
            }
        },
        Err(e) => {
            bh::section("micro — XLA/PJRT sections skipped");
            println!("  ({e:#})");
            report.note("xla_sections", 0.0);
        }
    }

    report.write(&bh::bench_out_path("BENCH_micro.json"))?;
    Ok(())
}

/// The artifact-backed benches: PJRT train step, gradient acquisition,
/// Pallas corr/sqdist kernels, and OMP over the XLA correlation backend.
fn xla_sections(rt: &Runtime, report: &mut bh::BenchReport) -> anyhow::Result<()> {
    let mut rng = Rng::new(43);
    for model in ["lenet_s", "resnet_s"] {
        let meta = rt.model(model)?.clone();
        bh::section(&format!(
            "micro — {model} (d={} h={} c={} P={})",
            meta.d, meta.h, meta.c, meta.p
        ));

        // --- train_step -----------------------------------------------------
        let card = DatasetCard::all()
            .into_iter()
            .find(|c| c.model == model)
            .unwrap();
        let splits = card.generate(1, 600);
        let mut st = rt.init(model, 1)?;
        let mut x = vec![0.0f32; meta.batch * meta.d];
        let mut y = vec![0i32; meta.batch];
        for s in 0..meta.batch {
            x[s * meta.d..(s + 1) * meta.d].copy_from_slice(splits.train.x.row(s));
            y[s] = splits.train.y[s];
        }
        let w = vec![1.0f32; meta.batch];
        report.rec(&format!("{model}/train_step (B={}, 16-literal)", meta.batch), 30, || {
            rt.train_step(&mut st, &x, &y, &w, 0.01).unwrap()
        });
        let mut fs = gradmatch::runtime::FusedState::from_state(&st)?;
        report.rec(&format!("{model}/train_step_fused (packed state)"), 30, || {
            rt.train_step_fused(&mut fs, &x, &y, &w, 0.01).unwrap()
        });

        // --- gradient acquisition -------------------------------------------
        let idx: Vec<usize> = (0..meta.chunk.min(600)).collect();
        let chunk = gradmatch::data::padded_chunks(&splits.train, &idx, meta.chunk)
            .next()
            .unwrap();
        report.rec(&format!("{model}/grads_chunk ({}xP)", meta.chunk), 10, || {
            rt.grads_chunk(&st, &chunk.x, &chunk.y, &chunk.mask).unwrap()
        });
        report.rec(&format!("{model}/mean_grad_chunk (fused)"), 10, || {
            rt.mean_grad_chunk(&st, &chunk.x, &chunk.y, &chunk.mask).unwrap()
        });

        // --- OMP inner loop: Pallas corr vs parallel Rust GEMV ----------------
        let n = meta.chunk * 4;
        let g = Matrix::from_vec(n, meta.p, (0..n * meta.p).map(|_| rng.gaussian_f32()).collect());
        let r: Vec<f32> = (0..meta.p).map(|_| rng.gaussian_f32()).collect();
        let mut xla = XlaCorr::new(rt, model, &g)?;
        report.rec(&format!("{model}/corr {}x{} (XLA+Pallas)", n, meta.p), 10, || {
            xla.corr(&r).unwrap()
        });
        let mut rust = RustCorr { g: &g };
        report.rec(&format!("{model}/corr {}x{} (Rust par gemv)", n, meta.p), 10, || {
            rust.corr(&r).unwrap()
        });

        // --- full OMP over the ground set: seed vs Batch-OMP per backend ------
        let target: Vec<f32> = (0..meta.p).map(|_| rng.gaussian_f32()).collect();
        let opts = OmpOpts { k: 16, lambda: 0.5, eps: 1e-12 };
        let row = |j: usize| g.row(j).to_vec();
        let (xla_old, _) = report.rec(&format!("{model}/omp k=16 n={n} (XLA, seed solver)"), 3, || {
            omp_select_ref(&mut xla, &row, &target, opts).unwrap()
        });
        let (xla_new, _) = report.rec(&format!("{model}/omp k=16 n={n} (XLA, batch-omp)"), 3, || {
            omp_select(&mut xla, &row, &target, opts).unwrap()
        });
        report.note(&format!("{model}/omp_xla_speedup"), xla_old / xla_new.max(1e-12));
        report.rec(&format!("{model}/omp k=16 n={n} (Rust, batch-omp)"), 3, || {
            omp_select(&mut rust, &row, &target, opts).unwrap()
        });

        // --- CRAIG distances --------------------------------------------------
        let a = Matrix::from_vec(
            meta.chunk,
            meta.p,
            (0..meta.chunk * meta.p).map(|_| rng.gaussian_f32()).collect(),
        );
        report.rec(&format!("{model}/sqdist {0}x{0} (XLA+Pallas)", meta.chunk), 5, || {
            rt.sqdist_chunk(model, &a, &a).unwrap()
        });
        report.rec(&format!("{model}/sqdist {0}x{0} (Rust parallel)", meta.chunk), 2, || {
            par::pairwise_sqdist(&a)
        });

        // --- live selection round: serial classes vs staged fan-out -----------
        let ground: Vec<usize> = (0..splits.train.len()).collect();
        let live_round = |parallel: bool| {
            let mut s =
                GradMatch::new(GradMatchVariant::PerClassPerGradient, meta.batch, false);
            s.parallel = parallel;
            let mut sel_rng = Rng::new(99);
            s.select(&mut SelectCtx {
                src: GradSource::Live { rt, state: &st },
                train: &splits.train,
                ground: &ground,
                val: &splits.val,
                budget: (ground.len() / 10).max(1),
                lambda: 0.5,
                eps: 1e-10,
                is_valid: false,
                rng: &mut sel_rng,
                round: None,
            })
            .unwrap()
        };
        let (live_serial, _) = report
            .rec(&format!("{model}/round gradmatch (serial classes)"), 3, || live_round(false));
        let (live_fanout, _) = report
            .rec(&format!("{model}/round gradmatch (staged fan-out)"), 3, || live_round(true));
        report.note(
            &format!("{model}/round_live_speedup"),
            live_serial / live_fanout.max(1e-12),
        );

        // the same live round through the engine API — the report's
        // staging/solve split and dispatch count land in the JSON notes
        let req = SelectionRequest {
            strategy: "gradmatch-rust".into(),
            budget: (ground.len() / 10).max(1),
            lambda: 0.5,
            eps: 1e-10,
            is_valid: false,
            seed: 42,
            rng_tag: 99,
            ground: ground.clone(),
            shards: None,
            sketch: None,
        };
        let engine = SelectionEngine::new(rt, st.clone(), &splits.train, &splits.val);
        let rep = engine.select(&req)?;
        println!(
            "  {model}/round via engine: stage {:.3}ms solve {:.3}ms ({} dispatches, fanout={})",
            rep.stats.stage_secs * 1e3,
            rep.stats.solve_secs * 1e3,
            rep.stats.stage_dispatches,
            rep.stats.fanout
        );
        report.note_round(&format!("{model}/round_engine"), &rep.stats);
    }
    Ok(())
}
