//! Table 9: average gradient-error norm ‖ Σ wᵢ gᵢ − Σ ∇Lᵢ ‖ per strategy
//! and budget — the paper's accounting: the target is the **sum** of
//! training gradients and weights are used as the strategies produce them
//! (GRAD-MATCH ridge weights sum-calibrated, CRAIG medoid counts,
//! RANDOM/GLISTER w=1, which under-scales and blows the error up exactly
//! as in the paper's Table 9).  Shape: GRAD-MATCH-PB ≤ CRAIG-PB; weighted
//! strategies ≪ unweighted; errors shrink as budgets grow.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;
use gradmatch::grads;
use gradmatch::rng::Rng;
use gradmatch::selection::{parse_strategy, GradSource, SelectCtx};

fn main() -> anyhow::Result<()> {
    let coord = Coordinator::new(&bh::artifacts_dir())?;
    let rt = &coord.rt;
    let card = gradmatch::data::DatasetCard::by_name("synmnist").unwrap();
    let splits = card.generate(42, 1500);
    let ground: Vec<usize> = (0..splits.train.len()).collect();
    // a lightly-trained model (selection happens at live checkpoints)
    let mut st = rt.init("lenet_s", 42)?;
    {
        let mut rng = Rng::new(1);
        let batches =
            gradmatch::data::weighted_batches(&splits.train, &ground, &vec![1.0; ground.len()], st.meta.batch, &mut rng);
        for b in batches.iter().take(20) {
            rt.train_step(&mut st, &b.x, &b.y, &b.w, 0.05)?;
        }
    }
    let mut target = grads::mean_gradient(rt, &st, &splits.train, &ground)?;
    // paper semantics: match the SUM of gradients
    for v in target.iter_mut() {
        *v *= ground.len() as f32;
    }

    let strategies = ["random", "craig", "craig-pb", "glister", "gradmatch", "gradmatch-pb"];
    let budgets = [0.01, 0.05, 0.10, 0.30];

    bh::section("Table 9 — normalized gradient-matching error (synmnist)");
    let mut header = vec!["strategy".to_string()];
    header.extend(budgets.iter().map(|b| format!("{:.0}%", b * 100.0)));
    bh::table_header(&header.iter().map(String::as_str).collect::<Vec<_>>());

    let mut errs = std::collections::HashMap::new();
    for strat in strategies {
        let mut row = vec![strat.to_string()];
        for &b in &budgets {
            let (mut strategy, _) = parse_strategy(strat, st.meta.batch)?;
            let mut rng = Rng::new(7);
            let sel = strategy.select(&mut SelectCtx {
                src: GradSource::Live { rt, state: &st },
                train: &splits.train,
                ground: &ground,
                val: &splits.val,
                budget: ((b * ground.len() as f64) as usize).max(1),
                lambda: 0.5,
                eps: 1e-10,
                is_valid: false,
                rng: &mut rng,
                round: None,
            })?;
            let store = grads::per_sample_grads(rt, &st, &splits.train, &sel.indices)?;
            let err = grads::gradient_error(&store.g, &sel.weights, &target);
            errs.insert((strat, (b * 100.0) as usize), err as f64);
            row.push(format!("{err:.5}"));
        }
        bh::table_row(&row);
    }

    let mut ok = true;
    ok &= bh::shape_check(
        "table9: weighted gradmatch error << unweighted random at 10%",
        errs[&("gradmatch", 10)] < errs[&("random", 10)],
    );
    ok &= bh::shape_check(
        "table9: gradmatch-pb error <= craig-pb error at 10%",
        errs[&("gradmatch-pb", 10)] <= errs[&("craig-pb", 10)] * 1.05,
    );
    ok &= bh::shape_check(
        "table9: gradmatch improves most from 1% to 30% (adaptive fit)",
        errs[&("gradmatch", 30)] / errs[&("gradmatch", 1)]
            < errs[&("glister", 30)] / errs[&("glister", 1)],
    );
    ok &= bh::shape_check(
        "table9: gradmatch has the lowest error at 30%",
        ["random", "craig", "craig-pb", "glister", "gradmatch-pb"]
            .iter()
            .all(|s| errs[&("gradmatch", 30)] <= errs[&(*s, 30)]),
    );
    ok &= bh::shape_check(
        "table9: errors shrink with budget (gradmatch-pb 1% -> 30%)",
        errs[&("gradmatch-pb", 30)] <= errs[&("gradmatch-pb", 1)] * 1.05,
    );
    println!("\ntable9_gradient_error: {}", if ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
