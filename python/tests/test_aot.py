"""AOT pipeline checks: lowering produces loadable HLO text + sane manifest."""

from __future__ import annotations

import json
import os

import jax
import pytest

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_contains_entry():
    spec = M.MODELS["lenet_narrow"]
    fn, args = aot.entry_points(spec)["corr_chunk"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "ENTRY" in text and "HloModule" in text


def test_entry_points_cover_contract():
    spec = M.MODELS["lenet_narrow"]
    eps = aot.entry_points(spec)
    assert set(eps) == {
        "init", "train_step", "eval_chunk", "grads_chunk",
        "mean_grad_chunk", "batch_gradsum_chunk", "corr_chunk", "sqdist_chunk",
        "train_step_fused",
    }
    # train_step: params(4) + momenta(4) + x,y,w,lr
    assert len(eps["train_step"][1]) == 12
    # corr_chunk shapes follow the manifest contract
    g, r = eps["corr_chunk"][1]
    assert g.shape == (spec.chunk, spec.p) and r.shape == (spec.p,)


def test_eval_shapes_match_declared_outputs():
    spec = M.MODELS["lenet_narrow"]
    for name, (fn, args) in aot.entry_points(spec).items():
        outs = jax.eval_shape(fn, *args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        for o in outs:
            assert all(dim > 0 for dim in o.shape) or o.shape == ()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_consistent_with_registry():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["interchange"] == "hlo-text"
    for name, spec in M.MODELS.items():
        mm = man["models"][name]
        assert mm["p"] == spec.p and mm["d"] == spec.d
        for entry, meta in mm["entries"].items():
            path = os.path.join(ART, meta["path"])
            assert os.path.exists(path), path
            head = open(path).read(4096)
            assert "HloModule" in head
