//! Minimal JSON parser/serializer (serde is not in the offline vendor set).
//!
//! Covers the full JSON grammar the project touches: the artifact
//! `manifest.json` written by `python/compile/aot.py`, the result files the
//! experiment harness emits, and — since the selection daemon speaks
//! line-delimited JSON to untrusted clients — hostile wire input.  Numbers
//! are f64; no streaming; inputs are small (KBs).
//!
//! Hardening contract (the daemon relies on all three):
//! - trailing garbage after the top-level value is rejected;
//! - nesting beyond [`MAX_DEPTH`] is rejected (bounds parser recursion, so
//!   a `[[[[...` bomb errors instead of overflowing the stack);
//! - non-finite numbers (`1e999`) and raw control bytes inside strings are
//!   rejected (both are invalid JSON that `f64::parse`/raw copy would
//!   otherwise accept).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Maximum array/object nesting depth accepted by [`Json::parse`].
pub const MAX_DEPTH: usize = 128;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]...` path access.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -- serialization ------------------------------------------------------

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    it.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builders used by the result writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(v: f64) -> Json {
    Json::Num(v)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    /// Enter one nesting level of array/object; errors past [`MAX_DEPTH`].
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => {
                    self.pos -= 1;
                    return Err(self.err("raw control character in string"));
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.b.len()
                        && self.b[end] != b'"'
                        && self.b[end] != b'\\'
                        && self.b[end] >= 0x20
                    {
                        end += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        let v = txt.parse::<f64>().map_err(|_| self.err("bad number"))?;
        if !v.is_finite() {
            return Err(self.err("number out of range"));
        }
        Ok(Json::Num(v))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// A corpus of malformed payloads every layer that parses untrusted JSON must
/// reject without panicking.  Shared between the unit corpus test below and
/// the daemon's per-connection isolation test (`tests/daemon.rs`), so the
/// wire protocol and the parser are hardened against the same inputs.
///
/// Entries are single-line (no `\n`) so they can be shipped verbatim over the
/// line-delimited protocol.
pub fn hostile_corpus() -> Vec<String> {
    let mut v: Vec<String> = [
        "",
        "   ",
        "{",
        "}",
        "[",
        "]",
        "{]",
        "[}",
        "[1,]",
        "{\"a\":}",
        "{\"a\"}",
        "{\"a\":1,}",
        "{a:1}",
        "{'a':1}",
        "nul",
        "truefalse",
        "+1",
        "01x",
        "1 2",
        "{}garbage",
        "\"unterminated",
        "\"bad escape \\q\"",
        "\"bad unicode \\u12\"",
        "1e999",
        "-1e999",
        "\u{0}",
        "{\"a\": \"\u{1}\"}",
        "\u{feff}{}",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    // Nesting bombs: just past the limit, and deep enough that unbounded
    // recursion would overflow the stack before erroring.
    v.push("[".repeat(MAX_DEPTH + 1));
    v.push(format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1)));
    v.push("[".repeat(100_000));
    v.push(format!("{}{}", "{\"k\":".repeat(MAX_DEPTH + 1), "}".repeat(MAX_DEPTH + 1)));
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":true,"n":null,"nested":{"k":-7}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn dump_escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn hostile_corpus_all_rejected() {
        for (i, payload) in hostile_corpus().iter().enumerate() {
            assert!(!payload.contains('\n'), "corpus entry {i} is not single-line");
            let r = Json::parse(payload);
            assert!(
                r.is_err(),
                "corpus entry {i} ({:?}...) parsed as {:?}",
                &payload[..payload.len().min(40)],
                r
            );
        }
    }

    #[test]
    fn depth_limit_boundary() {
        // exactly MAX_DEPTH levels parses...
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&ok).is_ok());
        // ...one more is a descriptive error, not a stack overflow
        let over = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let e = Json::parse(&over).unwrap_err();
        assert!(e.msg.contains("nesting"), "unexpected error: {e}");
        // siblings do not accumulate depth
        let wide = format!("[{}[1]]", "[1],".repeat(MAX_DEPTH * 2));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn rejects_nonfinite_numbers() {
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        assert!(Json::parse("1e-999").is_ok()); // underflows to 0, finite
    }

    #[test]
    fn rejects_raw_control_chars_but_accepts_escaped() {
        assert!(Json::parse("\"a\u{1}b\"").is_err());
        assert!(Json::parse("\"a\nb\"").is_err());
        assert_eq!(Json::parse("\"a\\nb\"").unwrap().as_str(), Some("a\nb"));
        // dump() escapes control chars, so every dumped value reparses
        let j = Json::Str("ctl \u{1} nl \n".into());
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"format":1,"models":{"m":{"p":1290,"entries":{"init":{"path":"m/init.hlo.txt","inputs":[{"shape":[],"dtype":"int32"}],"outputs":[{"shape":[784,128],"dtype":"float32"}]}}}}}"#;
        let j = Json::parse(src).unwrap();
        let p = j.path(&["models", "m", "p"]).unwrap().as_usize().unwrap();
        assert_eq!(p, 1290);
        let shape = j
            .path(&["models", "m", "entries", "init", "outputs"]).unwrap()
            .as_arr().unwrap()[0]
            .get("shape").unwrap()
            .as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(784));
    }
}
