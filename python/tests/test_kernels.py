"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps the shape space (rows not necessarily tile-aligned are
exercised through the model-level chunk padding; the raw kernels require
tile-divisible rows only when n > TILE, which the sweeps respect).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gradmatch_kernels as K
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _arr(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# rows: multiples of the tile, plus small (< tile) sizes where a single
# block covers everything.
ROWS = st.sampled_from([1, 3, 16, 128, 256, 384])
HDIM = st.sampled_from([1, 4, 32, 128])
CDIM = st.sampled_from([2, 5, 10, 20])
PDIM = st.sampled_from([8, 130, 1290])


@given(n=ROWS, h=HDIM, c=CDIM, seed=st.integers(0, 2**31 - 1))
def test_per_sample_grads_matches_ref(n, h, c, seed):
    rng = np.random.default_rng(seed)
    hm, em = _arr(rng, (n, h)), _arr(rng, (n, c))
    got = K.per_sample_grads(hm, em)
    want = ref.per_sample_grads_ref(hm, em)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(n=ROWS, p=PDIM, seed=st.integers(0, 2**31 - 1))
def test_corr_matches_ref(n, p, seed):
    rng = np.random.default_rng(seed)
    g, r = _arr(rng, (n, p)), _arr(rng, (p,))
    np.testing.assert_allclose(K.corr(g, r), ref.corr_ref(g, r), rtol=2e-4, atol=2e-3)


@given(na=ROWS, nb=ROWS, p=st.sampled_from([8, 130]), seed=st.integers(0, 2**31 - 1))
def test_sqdist_matches_ref(na, nb, p, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, (na, p)), _arr(rng, (nb, p))
    np.testing.assert_allclose(K.sqdist(a, b), ref.sqdist_ref(a, b), rtol=1e-3, atol=1e-3)


@given(n=ROWS, p=st.sampled_from([8, 330]), seed=st.integers(0, 2**31 - 1))
def test_weighted_gradsum_matches_ref(n, p, seed):
    rng = np.random.default_rng(seed)
    g, w = _arr(rng, (n, p)), _arr(rng, (n,))
    np.testing.assert_allclose(
        K.weighted_gradsum(g, w), ref.weighted_gradsum_ref(g, w), rtol=2e-4, atol=2e-3
    )


# --- analytic invariants -----------------------------------------------------


def test_sqdist_diagonal_zero_and_symmetric():
    rng = np.random.default_rng(0)
    a = _arr(rng, (64, 33))
    d = np.asarray(K.sqdist(a, a))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-4)
    np.testing.assert_allclose(d, d.T, rtol=1e-4, atol=1e-4)


def test_sqdist_nonnegative():
    rng = np.random.default_rng(1)
    a, b = _arr(rng, (128, 16)), _arr(rng, (128, 16))
    assert float(np.min(np.asarray(K.sqdist(a, b)))) >= 0.0


def test_corr_zero_residual():
    rng = np.random.default_rng(2)
    g = _arr(rng, (128, 40))
    assert np.allclose(K.corr(g, jnp.zeros((40,), jnp.float32)), 0.0)


def test_per_sample_grads_bias_block_is_err():
    rng = np.random.default_rng(3)
    h, e = _arr(rng, (16, 8)), _arr(rng, (16, 5))
    g = np.asarray(K.per_sample_grads(h, e))
    np.testing.assert_allclose(g[:, 8 * 5 :], np.asarray(e), rtol=1e-6)


def test_per_sample_grads_layout_row_major():
    """G[:, j*C + l] must equal h[:, j] * err[:, l] — the layout contract the
    Rust per-class slicing relies on (manifest: w2_row_major_hc_then_bias)."""
    rng = np.random.default_rng(4)
    h, e = _arr(rng, (8, 6)), _arr(rng, (8, 3))
    g = np.asarray(K.per_sample_grads(h, e))
    for j in (0, 5):
        for l in (0, 2):
            np.testing.assert_allclose(
                g[:, j * 3 + l], np.asarray(h[:, j] * e[:, l]), rtol=1e-6
            )


def test_weighted_gradsum_recovers_single_row():
    rng = np.random.default_rng(5)
    g = _arr(rng, (128, 12))
    w = np.zeros(128, np.float32)
    w[7] = 2.5
    out = np.asarray(K.weighted_gradsum(g, jnp.asarray(w)))
    np.testing.assert_allclose(out, 2.5 * np.asarray(g)[7], rtol=1e-5, atol=1e-5)
