//! Full-catalog conformance for the selection engine, pinned device-free
//! on the synthetic gradient oracle (no PJRT / HLO artifacts needed):
//!
//! - **coverage + equivalence** — EVERY spec in `strategy_specs()` runs
//!   under the engine's oracle backend, and its selection is
//!   index/weight-identical to the legacy `parse_strategy` +
//!   `Strategy::select` path over an identical oracle;
//! - **dispatch bounds** — the counting oracle pins each family's
//!   acquisition cost: one staged gradient pass for the per-class
//!   strategies, one group-sum pass for the PB ground sets, one
//!   eval-entry pass for ENTROPY/FORGETTING, zero dispatches for the
//!   model-free baselines;
//! - **stateful baselines** — FORGETTING keeps its cross-round memory
//!   through `SelectionEngine::select_with` on the oracle backend;
//! - **property tests** — `split_budget` invariants (sum, per-class
//!   caps) and `top_k_desc` edge cases (k=0, k=n, all-NaN, tie order).

use gradmatch::data::Dataset;
use gradmatch::engine::{Degradation, SelectionEngine, SelectionRequest};
use gradmatch::fault::{FaultPlan, FaultyOracle};
use gradmatch::grads::SynthGrads;
use gradmatch::rng::Rng;
use gradmatch::selection::{
    parse_strategy, split_budget, strategy_specs, top_k_desc, GradSource, SelectCtx, Selection,
};
use gradmatch::tensor::Matrix;
use gradmatch::testutil::forall;

const CHUNK: usize = 16;
const BATCH: usize = 4;

/// Imbalanced synthetic dataset: heavy head, long tail, every class
/// populated (so per-class and scoring strategies all have work).
fn imbalanced(seed: u64, classes: usize, d: usize) -> Dataset {
    let mut y: Vec<i32> = Vec::new();
    for cls in 0..classes {
        let n_c = match cls % 3 {
            0 => 37,
            1 => 11,
            _ => 4,
        };
        y.extend(std::iter::repeat(cls as i32).take(n_c));
    }
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut y);
    let n = y.len();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

fn request(strategy: &str, ground: Vec<usize>, budget: usize) -> SelectionRequest {
    SelectionRequest {
        strategy: strategy.into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: 7,
        ground,
        shards: None,
        sketch: None,
    }
}

/// Run `spec` through the legacy path (`parse_strategy` +
/// `Strategy::select`, private staging) over an explicit oracle with the
/// engine's RNG derivation.
fn legacy_select(
    spec: &str,
    oracle: &mut SynthGrads,
    train: &Dataset,
    val: &Dataset,
    h: usize,
    c: usize,
    req: &SelectionRequest,
) -> Selection {
    let (mut strategy, _warm) = parse_strategy(spec, BATCH).unwrap();
    let mut rng = req.round_rng();
    strategy
        .select(&mut SelectCtx {
            src: GradSource::Oracle { oracle, h, c },
            train,
            ground: &req.ground,
            val,
            budget: req.budget,
            lambda: req.lambda,
            eps: req.eps,
            is_valid: req.is_valid,
            rng: &mut rng,
            round: None,
        })
        .unwrap()
}

#[test]
fn every_spec_runs_on_the_oracle_engine_and_matches_the_legacy_path() {
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(11, classes, d);
    let val = imbalanced(12, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let budget = n / 4;

    for spec in strategy_specs() {
        let req = request(spec, ground.clone(), budget);

        let mut engine_oracle = SynthGrads::with_batch(CHUNK, p, BATCH);
        let report = {
            let engine =
                SelectionEngine::with_oracle(&mut engine_oracle, &train, &val, h, classes);
            engine
                .select(&req)
                .unwrap_or_else(|e| panic!("{spec}: oracle engine must serve it: {e:#}"))
        };

        let mut legacy_oracle = SynthGrads::with_batch(CHUNK, p, BATCH);
        let want = legacy_select(spec, &mut legacy_oracle, &train, &val, h, classes, &req);

        assert_eq!(
            report.selection.indices, want.indices,
            "{spec}: engine selection must equal the legacy path"
        );
        assert_eq!(report.selection.indices.len(), report.selection.weights.len(), "{spec}");
        for (a, b) in report.selection.weights.iter().zip(&want.weights) {
            assert!((a - b).abs() <= 1e-6 * (1.0 + b.abs()), "{spec}: weight {a} vs {b}");
        }
        match (report.selection.grad_error, want.grad_error) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert!((a - b).abs() <= 1e-6 * (1.0 + b.abs()), "{spec}: err {a} vs {b}")
            }
            (a, b) => panic!("{spec}: grad_error {a:?} vs {b:?}"),
        }
        assert!(!report.selection.indices.is_empty(), "{spec}: empty selection");
        assert!(report.selection.indices.iter().all(|&i| i < n), "{spec}: oob row");

        // identical acquisition on both paths: same dispatch counts per
        // entry point (the engine adds caching, not extra passes)
        assert_eq!(engine_oracle.grad_calls, legacy_oracle.grad_calls, "{spec}: grads");
        assert_eq!(engine_oracle.mean_calls, legacy_oracle.mean_calls, "{spec}: means");
        assert_eq!(engine_oracle.gradsum_calls, legacy_oracle.gradsum_calls, "{spec}: gradsums");
        assert_eq!(engine_oracle.eval_calls, legacy_oracle.eval_calls, "{spec}: evals");
    }
}

#[test]
fn dispatch_bounds_hold_per_strategy_family() {
    let (classes, h, d) = (4usize, 3usize, 5usize);
    let p = h * classes + classes;
    let train = imbalanced(21, classes, d);
    let val = imbalanced(22, classes, d);
    let n = train.len();
    let n_val = val.len();
    let ground: Vec<usize> = (0..n).collect();
    let budget = n / 4;
    let passes = n.div_ceil(CHUNK);

    for spec in strategy_specs() {
        let mut oracle = SynthGrads::with_batch(CHUNK, p, BATCH);
        {
            let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
            engine.select(&request(spec, ground.clone(), budget)).unwrap();
        }
        // (grads, means, gradsums, evals) the spec is allowed to cost
        let want = match spec {
            // per-class strategies: ONE staged gradient pass (train
            // targets fall out of it for free)
            "gradmatch" | "gradmatch-rust" | "gradmatch-perclass" | "craig" => (passes, 0, 0, 0),
            // PB ground sets: ONE fused group-sum pass; GRAD-MATCH also
            // pays the train-target mean pass, CRAIG matches no target
            "gradmatch-pb" | "gradmatch-pb-rust" => (0, passes, passes, 0),
            "craig-pb" => (0, 0, passes, 0),
            // GLISTER: one streamed score pass + the val-target means
            "glister" => (passes, n_val.div_ceil(CHUNK), 0, 0),
            // scoring baselines: ONE eval-entry pass, nothing else
            "entropy" | "forgetting" => (0, 0, 0, passes),
            // model-free baselines: zero runtime dispatches
            "random" | "full" | "full-earlystop" | "featurefl" => (0, 0, 0, 0),
            other => panic!("new spec '{other}' needs a dispatch bound here"),
        };
        assert_eq!(
            (oracle.grad_calls, oracle.mean_calls, oracle.gradsum_calls, oracle.eval_calls),
            want,
            "{spec}: dispatch counts"
        );
    }
}

#[test]
fn zero_fault_wrapper_is_transparent_for_every_spec() {
    // the fault-injection substrate must cost nothing when armed with
    // FaultPlan::none: byte-identical selections, identical inner
    // dispatch counts, fault-free round stats — for EVERY catalog spec
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(51, classes, d);
    let val = imbalanced(52, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let budget = n / 4;

    for spec in strategy_specs() {
        let req = request(spec, ground.clone(), budget);

        let mut bare = SynthGrads::with_batch(CHUNK, p, BATCH);
        let want = {
            let engine = SelectionEngine::with_oracle(&mut bare, &train, &val, h, classes);
            engine.select(&req).unwrap()
        };

        let mut inner = SynthGrads::with_batch(CHUNK, p, BATCH);
        let mut faulty = FaultyOracle::new(&mut inner, FaultPlan::none(42));
        let got = {
            let engine = SelectionEngine::with_oracle(&mut faulty, &train, &val, h, classes);
            engine.select(&req).unwrap()
        };
        let injected =
            faulty.injected_failures + faulty.injected_nan_rows + faulty.injected_spikes;

        assert_eq!(got.selection, want.selection, "{spec}: zero-fault wrapper must be transparent");
        assert_eq!(injected, 0, "{spec}: nothing may be injected");
        assert_eq!(got.stats.retries, 0, "{spec}");
        assert_eq!(got.stats.quarantined, 0, "{spec}");
        assert_eq!(got.stats.degradation, Degradation::None, "{spec}");
        assert_eq!(
            (inner.grad_calls, inner.mean_calls, inner.gradsum_calls, inner.eval_calls),
            (bare.grad_calls, bare.mean_calls, bare.gradsum_calls, bare.eval_calls),
            "{spec}: wrapped dispatch counts must match the bare oracle"
        );
    }
}

#[test]
fn forgetting_keeps_state_across_engine_rounds() {
    // a caller-held FORGETTING instance driven through select_with on the
    // oracle backend accumulates flips across rounds exactly like the
    // legacy twin (salt bumps emulate the model update between rounds)
    let (classes, h, d) = (3usize, 2usize, 4usize);
    let p = h * classes + classes;
    let train = imbalanced(31, classes, d);
    let val = imbalanced(32, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let req = request("forgetting", ground.clone(), n / 5);

    let mut engine_sel: Vec<Selection> = Vec::new();
    {
        let mut oracle = SynthGrads::new(CHUNK, p);
        let (mut strategy, _) = parse_strategy("forgetting", BATCH).unwrap();
        for round in 0..3u64 {
            oracle.salt = round;
            let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
            engine_sel.push(engine.select_with(strategy.as_mut(), &req).unwrap().selection);
        }
    }

    let mut legacy_sel: Vec<Selection> = Vec::new();
    {
        let mut oracle = SynthGrads::new(CHUNK, p);
        let (mut strategy, _) = parse_strategy("forgetting", BATCH).unwrap();
        for round in 0..3u64 {
            oracle.salt = round;
            let mut rng = req.round_rng();
            legacy_sel.push(
                strategy
                    .select(&mut SelectCtx {
                        src: GradSource::Oracle { oracle: &mut oracle, h, c: classes },
                        train: &train,
                        ground: &ground,
                        val: &val,
                        budget: req.budget,
                        lambda: req.lambda,
                        eps: req.eps,
                        is_valid: req.is_valid,
                        rng: &mut rng,
                        round: None,
                    })
                    .unwrap(),
            );
        }
    }
    assert_eq!(engine_sel, legacy_sel, "stateful rounds must track the legacy path");
    // the changing eval stream must actually move the ranking at least
    // once across rounds — otherwise this test pins nothing
    assert!(
        engine_sel[0].indices != engine_sel[2].indices
            || engine_sel[1].indices != engine_sel[2].indices,
        "flips never changed the selection — weak fixture"
    );
}

#[test]
fn unknown_spec_error_from_the_engine_lists_the_catalog() {
    let (classes, h, d) = (3usize, 2usize, 4usize);
    let p = h * classes + classes;
    let train = imbalanced(41, classes, d);
    let val = imbalanced(42, classes, d);
    let ground: Vec<usize> = (0..train.len()).collect();
    let mut oracle = SynthGrads::new(CHUNK, p);
    let err = {
        let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
        engine.select(&request("bogus-spec", ground, 5)).unwrap_err().to_string()
    };
    for spec in strategy_specs() {
        assert!(err.contains(spec), "engine error should name '{spec}': {err}");
    }
    assert!(err.contains("-warm"), "engine error should mention the warm suffix: {err}");
}

// ---------------------------------------------------------------------------
// property tests: split_budget / top_k_desc
// ---------------------------------------------------------------------------

#[test]
fn split_budget_invariants_hold_across_shapes() {
    // k=0, k>n, single-class, heavily imbalanced: Σ budgets ==
    // min(k, Σ sizes) and no class ever exceeds its population
    forall(60, |g| {
        let classes = g.int(1, 12);
        let sizes: Vec<usize> = (0..classes)
            .map(|cls| match cls % 4 {
                0 => g.int(0, 3),       // sometimes empty
                1 => g.int(1, 8),       // tail
                2 => g.int(20, 120),    // heavy head
                _ => g.int(0, 40),
            })
            .collect();
        let total: usize = sizes.iter().sum();
        // sweep k through the degenerate shapes: 0, 1, around total, and beyond
        for k in [0, 1, total / 2, total, total + 1, total + 17] {
            let out = split_budget(k, &sizes);
            assert_eq!(out.len(), sizes.len(), "sizes={sizes:?}");
            assert_eq!(
                out.iter().sum::<usize>(),
                k.min(total),
                "k={k} sizes={sizes:?} out={out:?}"
            );
            for (o, s) in out.iter().zip(&sizes) {
                assert!(o <= s, "k={k}: budget {o} over population {s} (sizes={sizes:?})");
            }
        }
    });
    // single class takes everything it can
    assert_eq!(split_budget(7, &[50]), vec![7]);
    assert_eq!(split_budget(70, &[50]), vec![50]);
    // extreme imbalance: the head class absorbs what the tail cannot hold
    let b = split_budget(30, &[1, 1, 1000]);
    assert_eq!(b.iter().sum::<usize>(), 30);
    assert!(b[2] >= 28, "{b:?}");
}

#[test]
fn top_k_desc_edges_and_tie_order() {
    forall(40, |g| {
        let n = g.int(1, 80);
        // duplicate-heavy scores force ties
        let scores: Vec<f32> = (0..n).map(|_| g.int(0, 5) as f32).collect();
        // k=0 and k=n edges
        assert!(top_k_desc(&scores, 0).is_empty());
        let full = top_k_desc(&scores, n);
        assert_eq!(full.len(), n);
        // ties keep deterministic (ascending-index) order within a score
        for w in full.windows(2) {
            let (a, b) = (w[0], w[1]);
            assert!(
                scores[a] > scores[b] || (scores[a] == scores[b] && a < b),
                "rank order broke at {a},{b}: {scores:?}"
            );
        }
        // any k is a prefix of the full ranking — partial selection must
        // not reorder
        let k = g.int(0, n);
        assert_eq!(top_k_desc(&scores, k), full[..k].to_vec(), "k={k}");
    });
    // all-NaN: fills k slots without panicking, deterministically
    let nans = vec![f32::NAN; 6];
    let picked = top_k_desc(&nans, 4);
    assert_eq!(picked.len(), 4);
    assert_eq!(picked, top_k_desc(&nans, 4));
    assert_eq!(top_k_desc(&nans, 0), Vec::<usize>::new());
    assert_eq!(top_k_desc(&nans, 6).len(), 6);
}
