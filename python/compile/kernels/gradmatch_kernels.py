"""Layer-1 Pallas kernels for GRAD-MATCH's compute hot spots.

Three kernels cover the selection-side arithmetic the paper runs on GPU
(V100 batched GEMMs); here they are restated for the TPU execution model
Pallas exposes, then lowered with ``interpret=True`` so the resulting HLO
runs on the CPU PJRT client the Rust coordinator embeds:

- ``per_sample_grads``  — fused per-sample last-layer gradient extraction:
  the rank-1 outer product ``h_i ⊗ err_i`` concatenated with the bias
  gradient ``err_i``, written tile-by-tile so ``G`` is produced in one pass.
- ``corr``              — the OMP inner loop ``G @ r`` (residual
  correlations), tiled over rows so each grid step holds a ``TILE_N × P``
  gradient tile in VMEM and performs an MXU-friendly mat-vec contraction.
- ``sqdist``            — pairwise squared distances between gradient rows
  for CRAIG's facility-location objective, using the
  ``‖a‖² + ‖b‖² − 2·a·b`` decomposition so the inner term is a
  ``TILE × TILE`` MXU matmul.

Hardware adaptation notes (GPU paper → TPU kernel shapes): the paper's
threadblock-per-row-block schedule becomes the BlockSpec index map; tiles
are sized so a tile of f32 gradients stays well under VMEM (~16 MB) —
TILE_N=128 rows × P≈5k cols ≈ 2.6 MB.  See DESIGN.md §5.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile used by all kernels. 128 is the MXU lane width on real TPUs and
# keeps VMEM tiles ≈1–3 MB for the P ranges this project lowers (1.3k–5.2k).
TILE_N = 128


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# per-sample last-layer gradients
# ---------------------------------------------------------------------------


def _psg_kernel(h_ref, err_ref, out_ref, *, hdim: int, c: int):
    """One row-tile: out[:, :H*C] = flatten(h ⊗ err); out[:, H*C:] = err."""
    h = h_ref[...]                       # [T, H]
    err = err_ref[...]                   # [T, C]
    t = h.shape[0]
    outer = h[:, :, None] * err[:, None, :]           # [T, H, C]
    out_ref[:, : hdim * c] = outer.reshape(t, hdim * c)
    out_ref[:, hdim * c :] = err


def per_sample_grads(h: jax.Array, err: jax.Array) -> jax.Array:
    """Pallas version of :func:`ref.per_sample_grads_ref`.

    ``h : [N, H]`` hidden activations, ``err : [N, C]`` masked softmax
    errors; returns ``G : [N, H*C + C]``.  N must be a multiple of the row
    tile (the AOT path always pads chunks to a fixed multiple-of-128 size).
    """
    n, hdim = h.shape
    c = err.shape[1]
    p = hdim * c + c
    tile = min(TILE_N, n)
    grid = (_ceil_div(n, tile),)
    return pl.pallas_call(
        functools.partial(_psg_kernel, hdim=hdim, c=c),
        out_shape=jax.ShapeDtypeStruct((n, p), h.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, hdim), lambda i: (i, 0)),
            pl.BlockSpec((tile, c), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, p), lambda i: (i, 0)),
        interpret=True,
    )(h, err)


# ---------------------------------------------------------------------------
# OMP residual correlations:  corr = G @ r
# ---------------------------------------------------------------------------


def _corr_kernel(g_ref, r_ref, out_ref):
    """One row-tile of the mat-vec: out = G_tile @ r.

    Expressed as a dot contraction (not elementwise-multiply + reduce) so
    it maps onto the MXU on real TPUs and onto XLA's optimized GEMV on the
    CPU interpret path (§Perf: ~2× over the broadcast-reduce form).
    """
    g = g_ref[...]                       # [T, P]  (VMEM tile)
    r = r_ref[...]                       # [1, P]  (broadcast to every tile)
    out_ref[...] = jax.lax.dot_general(
        g, r, (((1,), (1,)), ((), ())), preferred_element_type=g.dtype
    )[:, 0]


def corr(g: jax.Array, r: jax.Array) -> jax.Array:
    """Pallas version of :func:`ref.corr_ref`: ``G[N,P] @ r[P] -> [N]``."""
    n, p = g.shape
    tile = min(TILE_N, n)
    grid = (_ceil_div(n, tile),)
    r2 = r.reshape(1, p)
    return pl.pallas_call(
        _corr_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), g.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, p), lambda i: (i, 0)),
            pl.BlockSpec((1, p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        interpret=True,
    )(g, r2)


# ---------------------------------------------------------------------------
# pairwise squared distances (CRAIG facility location)
# ---------------------------------------------------------------------------


def _sqdist_kernel(a_ref, b_ref, out_ref):
    """One (row-tile, col-tile) block of ‖a_i − b_j‖²."""
    a = a_ref[...]                       # [TA, P]
    b = b_ref[...]                       # [TB, P]
    a2 = jnp.sum(a * a, axis=1)[:, None]
    b2 = jnp.sum(b * b, axis=1)[None, :]
    # The cross term is the MXU-shaped contraction: [TA,P] x [P,TB].
    cross = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=a.dtype
    )
    out_ref[...] = jnp.maximum(a2 + b2 - 2.0 * cross, 0.0)


def sqdist(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pallas version of :func:`ref.sqdist_ref`: ``[NA,P],[NB,P] -> [NA,NB]``."""
    na, p = a.shape
    nb = b.shape[0]
    ta = min(TILE_N, na)
    tb = min(TILE_N, nb)
    grid = (_ceil_div(na, ta), _ceil_div(nb, tb))
    return pl.pallas_call(
        _sqdist_kernel,
        out_shape=jax.ShapeDtypeStruct((na, nb), a.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ta, p), lambda i, j: (i, 0)),
            pl.BlockSpec((tb, p), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ta, tb), lambda i, j: (i, j)),
        interpret=True,
    )(a, b)


# ---------------------------------------------------------------------------
# weighted gradient sum:  Gᵀ w  (used by gradient-error diagnostics)
# ---------------------------------------------------------------------------


def _wsum_kernel(g_ref, w_ref, acc_ref):
    """Accumulate one row-tile's weighted contribution into the output."""
    i = pl.program_id(0)
    g = g_ref[...]                       # [T, P]
    w = w_ref[...]                       # [T, 1]

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(g * w, axis=0)


def weighted_gradsum(g: jax.Array, w: jax.Array) -> jax.Array:
    """Pallas version of :func:`ref.weighted_gradsum_ref`: ``Gᵀ w -> [P]``."""
    n, p = g.shape
    tile = min(TILE_N, n)
    grid = (_ceil_div(n, tile),)
    w2 = w.reshape(n, 1)
    return pl.pallas_call(
        _wsum_kernel,
        out_shape=jax.ShapeDtypeStruct((p,), g.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, p), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((p,), lambda i: (0,)),
        interpret=True,
    )(g, w2)
