//! Statistics for the experiment tables: summary stats, relative error /
//! speedup derivations, and the Wilcoxon signed-rank test the paper uses to
//! claim significance (Table 8).

/// Mean of a sample (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased (n−1) standard deviation; 0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (averages the middle pair for even n).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Relative error in % against a skyline accuracy:
/// `100 · (acc_full − acc) / acc_full` (the y-axis of Fig. 3 scatter plots).
pub fn relative_error_pct(acc: f64, acc_full: f64) -> f64 {
    100.0 * (acc_full - acc) / acc_full
}

/// Speedup w.r.t. full training (the x-axis of Fig. 3 scatter plots).
pub fn speedup(time: f64, time_full: f64) -> f64 {
    time_full / time.max(1e-12)
}

/// Standard normal CDF via the erf-free Abramowitz–Stegun 7.1.26 polynomial.
pub fn normal_cdf(z: f64) -> f64 {
    // Φ(z) = 0.5 * erfc(-z/√2); approximate erf with A&S 7.1.26 (|ε|<1.5e-7)
    let x = z / std::f64::consts::SQRT_2;
    let t = 1.0 / (1.0 + 0.3275911 * x.abs());
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let erf = 1.0 - poly * (-x * x).exp();
    let erf = if x < 0.0 { -erf } else { erf };
    0.5 * (1.0 + erf)
}

/// Result of a Wilcoxon signed-rank test.
#[derive(Clone, Copy, Debug)]
pub struct Wilcoxon {
    /// signed-rank statistic W (sum of ranks of positive differences)
    pub w_plus: f64,
    /// number of non-zero paired differences used
    pub n: usize,
    /// one-tailed p-value for H1: sample `a` > sample `b`
    pub p_one_tailed: f64,
}

/// One-tailed Wilcoxon signed-rank test on paired samples (H1: a > b).
///
/// Uses the normal approximation with tie-corrected variance — the same
/// regime the paper operates in (dozens of paired cells across datasets ×
/// budgets).  Zero differences are dropped (Wilcoxon's original treatment).
pub fn wilcoxon_signed_rank(a: &[f64], b: &[f64]) -> Wilcoxon {
    assert_eq!(a.len(), b.len(), "wilcoxon: paired samples");
    let mut diffs: Vec<f64> = a
        .iter()
        .zip(b)
        .map(|(x, y)| x - y)
        .filter(|d| *d != 0.0)
        .collect();
    let n = diffs.len();
    if n == 0 {
        return Wilcoxon { w_plus: 0.0, n: 0, p_one_tailed: 0.5 };
    }
    // rank |d| with midranks for ties
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| diffs[i].abs().partial_cmp(&diffs[j].abs()).unwrap());
    let mut ranks = vec![0.0f64; n];
    let mut tie_correction = 0.0f64;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && diffs[order[j + 1]].abs() == diffs[order[i]].abs() {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        let t = (j - i + 1) as f64;
        if t > 1.0 {
            tie_correction += t * t * t - t;
        }
        for k in i..=j {
            ranks[order[k]] = avg_rank;
        }
        i = j + 1;
    }
    let w_plus: f64 = diffs
        .iter()
        .zip(&ranks)
        .filter(|(d, _)| **d > 0.0)
        .map(|(_, r)| r)
        .sum();
    let nf = n as f64;
    let mu = nf * (nf + 1.0) / 4.0;
    let sigma2 = nf * (nf + 1.0) * (2.0 * nf + 1.0) / 24.0 - tie_correction / 48.0;
    let sigma = sigma2.max(1e-12).sqrt();
    // continuity correction toward the mean
    let cc = if w_plus == mu { 0.0 } else { 0.5 * (w_plus - mu).signum() };
    let z = (w_plus - mu - cc) / sigma;
    let p = 1.0 - normal_cdf(z);
    diffs.clear();
    Wilcoxon { w_plus, n, p_one_tailed: p.clamp(0.0, 1.0) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_median() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
        assert!((median(&xs) - 4.5).abs() < 1e-12);
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stddev_degenerate() {
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn relerr_speedup() {
        assert!((relative_error_pct(93.0, 95.0) - 2.1052631).abs() < 1e-4);
        assert!((speedup(1.0, 4.0) - 4.0).abs() < 1e-12);
        assert!(relative_error_pct(95.0, 95.0).abs() < 1e-12);
    }

    #[test]
    fn normal_cdf_sanity() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((normal_cdf(-1.96) - 0.025).abs() < 1e-3);
        assert!(normal_cdf(6.0) > 0.999999);
    }

    #[test]
    fn wilcoxon_clear_dominance() {
        // a beats b in all 12 pairs -> tiny one-tailed p
        let a: Vec<f64> = (0..12).map(|i| 90.0 + i as f64 * 0.1 + 1.0).collect();
        let b: Vec<f64> = (0..12).map(|i| 90.0 + i as f64 * 0.1).collect();
        let w = wilcoxon_signed_rank(&a, &b);
        assert_eq!(w.n, 12);
        assert!(w.p_one_tailed < 0.01, "p={}", w.p_one_tailed);
    }

    #[test]
    fn wilcoxon_no_difference() {
        let a: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let w = wilcoxon_signed_rank(&a, &a);
        assert_eq!(w.n, 0);
        assert!((w.p_one_tailed - 0.5).abs() < 1e-12);
    }

    #[test]
    fn wilcoxon_symmetric_alternating() {
        // symmetric wins/losses of equal magnitude -> p near 0.5
        let a: Vec<f64> = (0..20).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let b: Vec<f64> = (0..20).map(|i| if i % 2 == 0 { 0.0 } else { 1.0 }).collect();
        let w = wilcoxon_signed_rank(&a, &b);
        assert!((w.p_one_tailed - 0.5).abs() < 0.15, "p={}", w.p_one_tailed);
    }

    #[test]
    fn wilcoxon_direction_matters() {
        let a: Vec<f64> = (0..15).map(|i| i as f64 + 2.0).collect();
        let b: Vec<f64> = (0..15).map(|i| i as f64).collect();
        let fwd = wilcoxon_signed_rank(&a, &b);
        let rev = wilcoxon_signed_rank(&b, &a);
        assert!(fwd.p_one_tailed < 0.05);
        assert!(rev.p_one_tailed > 0.9);
    }
}
