//! Selection strategies: GRAD-MATCH and every baseline the paper compares
//! against (§5), behind one [`Strategy`] trait the trainer drives every `R`
//! epochs (Algorithm 1).
//!
//! | spec string            | algorithm                                            |
//! |------------------------|------------------------------------------------------|
//! | `gradmatch`            | OMP, per-class + per-gradient approx (paper default) |
//! | `gradmatch-perclass`   | OMP per class on full-P gradients (Table 11)         |
//! | `gradmatch-pb`         | OMP over per-mini-batch gradients                    |
//! | `craig` / `craig-pb`   | facility location over gradient distances            |
//! | `glister`              | Taylor-approximation greedy on val-gradient dots     |
//! | `random`               | uniform subset                                       |
//! | `full`                 | entire ground set (skyline / early-stop baseline)    |
//! | `entropy`              | max predictive entropy (Table 12)                    |
//! | `forgetting`           | forgetting-events counter (Table 12)                 |
//! | `featurefl`            | facility location on raw features (Table 12)         |
//!
//! A trailing `-warm` on any spec enables the κ warm-start schedule, which
//! the trainer owns (`T_f = κ·T·k/n` full epochs first — §4 of the paper).

use anyhow::{anyhow, Result};

use crate::data::Dataset;
use crate::grads;
use crate::omp::{omp_select, OmpOpts, XlaCorr};
use crate::rng::Rng;
use crate::runtime::{ModelState, Runtime};
use crate::submod::{lazy_greedy, sim_from_sqdist, FacilityLocation};
use crate::tensor::Matrix;

/// Everything a strategy may look at when selecting.
pub struct SelectCtx<'a> {
    pub rt: &'a Runtime,
    pub state: &'a ModelState,
    pub train: &'a Dataset,
    /// ground set: dataset rows eligible for selection (handles imbalance)
    pub ground: &'a [usize],
    pub val: &'a Dataset,
    /// subset size k (samples)
    pub budget: usize,
    /// OMP ridge λ
    pub lambda: f32,
    /// OMP tolerance ε
    pub eps: f32,
    /// match validation gradients instead of training gradients (L = L_V)
    pub is_valid: bool,
    pub rng: &'a mut Rng,
}

/// A selected weighted subset.  `indices` are dataset rows; `weights`
/// align 1:1 (non-negative; the weighted loss normalizes, so scale is
/// irrelevant).
#[derive(Clone, Debug, Default)]
pub struct Selection {
    pub indices: Vec<usize>,
    pub weights: Vec<f32>,
    /// gradient-matching residual where the strategy computes one
    pub grad_error: Option<f32>,
}

impl Selection {
    fn push(&mut self, idx: usize, w: f32) {
        self.indices.push(idx);
        self.weights.push(w);
    }
}

/// A data-selection strategy (Algorithm 1's OMP slot, or a baseline).
pub trait Strategy {
    fn name(&self) -> String;
    /// Whether re-selection every R epochs is useful (adaptive strategies).
    fn is_adaptive(&self) -> bool {
        true
    }
    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection>;
}

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

/// Ground-set rows per class.
fn ground_per_class(ds: &Dataset, ground: &[usize]) -> Vec<Vec<usize>> {
    let mut per = vec![Vec::new(); ds.classes];
    for &i in ground {
        per[ds.y[i] as usize].push(i);
    }
    per
}

/// Split budget k across classes proportionally to class sizes (largest
/// remainder; every non-empty class gets ≥ 1 when k ≥ #classes).
pub fn split_budget(k: usize, sizes: &[usize]) -> Vec<usize> {
    let total: usize = sizes.iter().sum();
    if total == 0 {
        return vec![0; sizes.len()];
    }
    let mut out = vec![0usize; sizes.len()];
    let mut rems: Vec<(f64, usize)> = Vec::new();
    let mut assigned = 0usize;
    for (c, &s) in sizes.iter().enumerate() {
        let exact = k as f64 * s as f64 / total as f64;
        let base = (exact.floor() as usize).min(s);
        out[c] = base;
        assigned += base;
        rems.push((exact - base as f64, c));
    }
    rems.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    // Hand out the remainder in largest-remainder order until it is gone
    // or every class is saturated.  (A bounded `cycle().take(2·len)` pass
    // could strand budget when only a few classes still had spare
    // capacity; the progress guard makes exhaustion explicit.)
    let mut left = k.saturating_sub(assigned);
    while left > 0 {
        let mut progressed = false;
        for &(_, c) in &rems {
            if left == 0 {
                break;
            }
            if out[c] < sizes[c] {
                out[c] += 1;
                left -= 1;
                progressed = true;
            }
        }
        if !progressed {
            break; // every class saturated — k exceeds the ground set
        }
    }
    out
}

/// Target (mean) gradient for a scope of training rows, or — when
/// `is_valid` — for the matching validation rows of the same classes.
fn target_gradient(ctx: &SelectCtx<'_>, train_rows: &[usize], class: Option<usize>) -> Result<Vec<f32>> {
    if ctx.is_valid {
        let rows: Vec<usize> = match class {
            Some(c) => (0..ctx.val.len()).filter(|&i| ctx.val.y[i] as usize == c).collect(),
            None => (0..ctx.val.len()).collect(),
        };
        if rows.is_empty() {
            // no validation rows for this class — fall back to train target
            return grads::mean_gradient(ctx.rt, ctx.state, ctx.train, train_rows);
        }
        grads::mean_gradient(ctx.rt, ctx.state, ctx.val, &rows)
    } else {
        grads::mean_gradient(ctx.rt, ctx.state, ctx.train, train_rows)
    }
}

// ---------------------------------------------------------------------------
// GRAD-MATCH
// ---------------------------------------------------------------------------

/// Which GRAD-MATCH variant to run (Table 11 compares them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GradMatchVariant {
    /// per-class + per-gradient (last-layer class slice) — paper default
    PerClassPerGradient,
    /// per-class on full last-layer gradients
    PerClass,
    /// per-mini-batch ground set (GRAD-MATCH-PB)
    PerBatch,
}

/// GRAD-MATCH: OMP-based gradient matching (Algorithm 1 + 2).
pub struct GradMatch {
    pub variant: GradMatchVariant,
    /// mini-batch size for the PB ground set
    pub batch: usize,
    /// route full-P correlations through the XLA/Pallas kernel
    pub use_xla: bool,
}

impl GradMatch {
    pub fn new(variant: GradMatchVariant, batch: usize, use_xla: bool) -> Self {
        GradMatch { variant, batch, use_xla }
    }

    fn select_per_class(&self, ctx: &mut SelectCtx<'_>, per_gradient: bool) -> Result<Selection> {
        let meta = &ctx.state.meta;
        let per_class = ground_per_class(ctx.train, ctx.ground);
        let sizes: Vec<usize> = per_class.iter().map(Vec::len).collect();
        let budgets = split_budget(ctx.budget, &sizes);
        let mut out = Selection::default();
        let mut err_acc = 0.0f64;
        let mut err_n = 0usize;
        for (cls, rows) in per_class.iter().enumerate() {
            let k_c = budgets[cls];
            if rows.is_empty() || k_c == 0 {
                continue;
            }
            let store = grads::per_sample_grads(ctx.rt, ctx.state, ctx.train, rows)?;
            let target_full = target_gradient(ctx, rows, Some(cls))?;
            let (g, target): (Matrix, Vec<f32>) = if per_gradient {
                let cols = grads::class_columns(meta.h, meta.c, cls);
                (store.g.gather_cols(&cols), cols.iter().map(|&j| target_full[j]).collect())
            } else {
                (store.g.clone(), target_full)
            };
            let omp_opts = OmpOpts { k: k_c, lambda: ctx.lambda, eps: ctx.eps };
            let res = if !per_gradient && self.use_xla {
                let mut backend = XlaCorr::new(ctx.rt, &meta.name, &g)?;
                omp_select(&mut backend, &|j| g.row(j).to_vec(), &target, omp_opts)?
            } else {
                crate::omp::omp_select_rust(&g, &target, omp_opts)?
            };
            // OMP fits the class *mean* gradient; calibrate to the class
            // *sum* (×n_c) so weights are comparable with CRAIG's medoid
            // counts and the paper's Err(w, X) accounting (Table 9).  The
            // weighted loss normalizes, so training is scale-invariant.
            let scale = rows.len() as f32;
            for (slot, &j) in res.selected.iter().enumerate() {
                out.push(rows[j], res.weights[slot] * scale);
            }
            err_acc += res.residual_norm as f64;
            err_n += 1;
        }
        if err_n > 0 {
            out.grad_error = Some((err_acc / err_n as f64) as f32);
        }
        Ok(out)
    }

    fn select_per_batch(&self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let meta = &ctx.state.meta;
        // deterministic-per-round shuffle defines the mini-batch ground set
        let mut order = ctx.ground.to_vec();
        ctx.rng.shuffle(&mut order);
        // device-side group reduction — never materializes per-sample grads
        let (bg, members) =
            grads::per_batch_grads_fused(ctx.rt, ctx.state, ctx.train, &order)?;
        let target = target_gradient(ctx, &order, None)?;
        let b_k = (ctx.budget / self.batch).max(1).min(bg.rows);
        let omp_opts = OmpOpts { k: b_k, lambda: ctx.lambda, eps: ctx.eps };
        let res = if self.use_xla {
            let mut backend = XlaCorr::new(ctx.rt, &meta.name, &bg)?;
            omp_select(&mut backend, &|j| bg.row(j).to_vec(), &target, omp_opts)?
        } else {
            crate::omp::omp_select_rust(&bg, &target, omp_opts)?
        };
        let mut out = Selection::default();
        // same sum-calibration as the per-class path (×n over the mean fit)
        let scale = order.len() as f32;
        for (slot, &b) in res.selected.iter().enumerate() {
            let w = res.weights[slot] * scale / members[b].len().max(1) as f32;
            for &row in &members[b] {
                out.push(row, w);
            }
        }
        out.grad_error = Some(res.residual_norm);
        Ok(out)
    }
}

impl Strategy for GradMatch {
    fn name(&self) -> String {
        match self.variant {
            GradMatchVariant::PerClassPerGradient => "gradmatch".into(),
            GradMatchVariant::PerClass => "gradmatch-perclass".into(),
            GradMatchVariant::PerBatch => "gradmatch-pb".into(),
        }
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        match self.variant {
            GradMatchVariant::PerClassPerGradient => self.select_per_class(ctx, true),
            GradMatchVariant::PerClass => self.select_per_class(ctx, false),
            GradMatchVariant::PerBatch => self.select_per_batch(ctx),
        }
    }
}

// ---------------------------------------------------------------------------
// CRAIG (facility location over gradient distances)
// ---------------------------------------------------------------------------

/// CRAIG baseline: maximize the facility-location lower bound F̂ (§3.2 /
/// Appendix B.7), weights = medoid counts.
pub struct Craig {
    pub per_batch: bool,
    pub batch: usize,
    /// route full-P pairwise distances through the XLA/Pallas kernel
    pub use_xla: bool,
}

impl Craig {
    fn sqdist_matrix(&self, ctx: &SelectCtx<'_>, g: &Matrix) -> Result<Matrix> {
        if self.use_xla && g.cols == ctx.state.meta.p {
            let meta = &ctx.state.meta;
            let rows = meta.chunk;
            let nblocks = g.rows.div_ceil(rows);
            // pad row blocks once
            let mut blocks = Vec::with_capacity(nblocks);
            for bi in 0..nblocks {
                let lo = bi * rows;
                let hi = ((bi + 1) * rows).min(g.rows);
                let mut m = Matrix::zeros(rows, g.cols);
                for (slot, r) in (lo..hi).enumerate() {
                    m.row_mut(slot).copy_from_slice(g.row(r));
                }
                blocks.push((m, lo, hi));
            }
            let mut dist = Matrix::zeros(g.rows, g.rows);
            for (ba, lo_a, hi_a) in &blocks {
                for (bb, lo_b, hi_b) in &blocks {
                    let d = ctx.rt.sqdist_chunk(&ctx.state.meta.name, ba, bb)?;
                    for (ia, ra) in (*lo_a..*hi_a).enumerate() {
                        for (ib, rb) in (*lo_b..*hi_b).enumerate() {
                            dist.set(ra, rb, d.at(ia, ib));
                        }
                    }
                }
            }
            Ok(dist)
        } else {
            // Rust fallback (per-gradient slices / tests) — parallel
            // blocked pairwise distances
            Ok(crate::par::pairwise_sqdist(g))
        }
    }

    fn select_ground(
        &self,
        ctx: &SelectCtx<'_>,
        g: &Matrix,
        k: usize,
    ) -> Result<(Vec<usize>, Vec<f32>)> {
        let dist = self.sqdist_matrix(ctx, g)?;
        let sim = sim_from_sqdist(&dist);
        let mut fl = FacilityLocation::new(&sim);
        let res = lazy_greedy(&mut fl, k);
        let w = fl.medoid_weights(&res.selected);
        Ok((res.selected, w))
    }
}

impl Strategy for Craig {
    fn name(&self) -> String {
        if self.per_batch { "craig-pb".into() } else { "craig".into() }
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let meta = ctx.state.meta.clone();
        let mut out = Selection::default();
        if self.per_batch {
            let mut order = ctx.ground.to_vec();
            ctx.rng.shuffle(&mut order);
            let (bg, members) =
                grads::per_batch_grads_fused(ctx.rt, ctx.state, ctx.train, &order)?;
            let b_k = (ctx.budget / self.batch).max(1).min(bg.rows);
            let (sel, w) = self.select_ground(ctx, &bg, b_k)?;
            for (slot, &b) in sel.iter().enumerate() {
                for &row in &members[b] {
                    out.push(row, w[slot]);
                }
            }
        } else {
            // per-class + per-gradient slices (keeps the n_c² distance
            // matrices cheap — same approximation CRAIG itself adopts)
            let per_class = ground_per_class(ctx.train, ctx.ground);
            let sizes: Vec<usize> = per_class.iter().map(Vec::len).collect();
            let budgets = split_budget(ctx.budget, &sizes);
            for (cls, rows) in per_class.iter().enumerate() {
                if rows.is_empty() || budgets[cls] == 0 {
                    continue;
                }
                let store = grads::per_sample_grads(ctx.rt, ctx.state, ctx.train, rows)?;
                let cols = grads::class_columns(meta.h, meta.c, cls);
                let g = store.g.gather_cols(&cols);
                let (sel, w) = self.select_ground(ctx, &g, budgets[cls])?;
                for (slot, &j) in sel.iter().enumerate() {
                    out.push(rows[j], w[slot]);
                }
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// GLISTER (Taylor-approximation greedy)
// ---------------------------------------------------------------------------

/// GLISTER baseline: the Taylor approximation of the bi-level objective
/// reduces to scoring each candidate by `∇L_V(θ) · g_j` (§3.2); selection
/// is top-k, unweighted.
pub struct Glister;

impl Strategy for Glister {
    fn name(&self) -> String {
        "glister".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        // validation mean gradient (GLISTER always uses the val set)
        let val_rows: Vec<usize> = (0..ctx.val.len()).collect();
        let v = grads::mean_gradient(ctx.rt, ctx.state, ctx.val, &val_rows)?;
        // per-class proportional budgets (CORDS-style) — plain global top-k
        // of the Taylor gains collapses onto whichever class currently has
        // the largest aligned gradients
        let per_class = ground_per_class(ctx.train, ctx.ground);
        let sizes: Vec<usize> = per_class.iter().map(Vec::len).collect();
        let budgets = split_budget(ctx.budget, &sizes);
        let mut out = Selection::default();
        for (cls, rows) in per_class.iter().enumerate() {
            if rows.is_empty() || budgets[cls] == 0 {
                continue;
            }
            let store = grads::per_sample_grads(ctx.rt, ctx.state, ctx.train, rows)?;
            let mut scores = vec![0.0f32; store.g.rows];
            crate::par::gemv(&store.g, &v, &mut scores);
            let mut order: Vec<usize> = (0..scores.len()).collect();
            order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
            for &j in order.iter().take(budgets[cls]) {
                out.push(store.rows[j], 1.0);
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// RANDOM / FULL
// ---------------------------------------------------------------------------

/// Uniform random subset (re-sampled every selection round).
pub struct Random;

impl Strategy for Random {
    fn name(&self) -> String {
        "random".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let k = ctx.budget.min(ctx.ground.len());
        let picks = ctx.rng.sample_indices(ctx.ground.len(), k);
        let mut out = Selection::default();
        for j in picks {
            out.push(ctx.ground[j], 1.0);
        }
        Ok(out)
    }
}

/// Entire ground set — full training and the FULL-EARLYSTOP baseline (the
/// trainer handles the early-stop budget).
pub struct Full;

impl Strategy for Full {
    fn name(&self) -> String {
        "full".into()
    }

    fn is_adaptive(&self) -> bool {
        false
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let mut out = Selection::default();
        for &i in ctx.ground {
            out.push(i, 1.0);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Table-12 extra baselines
// ---------------------------------------------------------------------------

/// Max-entropy uncertainty sampling.
pub struct Entropy;

impl Strategy for Entropy {
    fn name(&self) -> String {
        "entropy".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let mut ent = Vec::with_capacity(ctx.ground.len());
        for chunk in crate::data::padded_chunks(ctx.train, ctx.ground, ctx.state.meta.chunk) {
            let (_, _, _, e) = ctx.rt.eval_chunk(ctx.state, &chunk.x, &chunk.y, &chunk.mask)?;
            for slot in 0..chunk.live {
                ent.push((e[slot], chunk.indices[slot]));
            }
        }
        ent.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut out = Selection::default();
        for &(_, idx) in ent.iter().take(ctx.budget) {
            out.push(idx, 1.0);
        }
        Ok(out)
    }
}

/// Forgetting events (Toneva et al. 2019): count correct→incorrect flips
/// across selection rounds; select the most-forgotten samples.
pub struct Forgetting {
    prev_correct: Vec<f32>,
    counts: Vec<f32>,
    n: usize,
}

impl Forgetting {
    pub fn new() -> Self {
        Forgetting { prev_correct: Vec::new(), counts: Vec::new(), n: 0 }
    }
}

impl Default for Forgetting {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for Forgetting {
    fn name(&self) -> String {
        "forgetting".into()
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let n_total = ctx.train.len();
        if self.n != n_total {
            self.prev_correct = vec![0.0; n_total];
            self.counts = vec![0.0; n_total];
            self.n = n_total;
        }
        for chunk in crate::data::padded_chunks(ctx.train, ctx.ground, ctx.state.meta.chunk) {
            let (_, _, correct, _) =
                ctx.rt.eval_chunk(ctx.state, &chunk.x, &chunk.y, &chunk.mask)?;
            for slot in 0..chunk.live {
                let idx = chunk.indices[slot];
                if self.prev_correct[idx] > 0.5 && correct[slot] < 0.5 {
                    self.counts[idx] += 1.0;
                }
                self.prev_correct[idx] = correct[slot];
            }
        }
        // rank by forgetting count; break ties by a stable jitter so early
        // rounds (all-zero counts) still pick a spread-out subset
        let mut scored: Vec<(f32, usize)> = ctx
            .ground
            .iter()
            .map(|&i| (self.counts[i] + 1e-6 * ((i * 2654435761) % 1000) as f32, i))
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let mut out = Selection::default();
        for &(_, idx) in scored.iter().take(ctx.budget) {
            out.push(idx, 1.0);
        }
        Ok(out)
    }
}

/// Facility location on raw features (model-independent; Table 12).
pub struct FeatureFL;

impl Strategy for FeatureFL {
    fn name(&self) -> String {
        "featurefl".into()
    }

    fn is_adaptive(&self) -> bool {
        false // features never change — select once
    }

    fn select(&mut self, ctx: &mut SelectCtx<'_>) -> Result<Selection> {
        let per_class = ground_per_class(ctx.train, ctx.ground);
        let sizes: Vec<usize> = per_class.iter().map(Vec::len).collect();
        let budgets = split_budget(ctx.budget, &sizes);
        let mut out = Selection::default();
        for (cls, rows) in per_class.iter().enumerate() {
            if rows.is_empty() || budgets[cls] == 0 {
                continue;
            }
            let x = ctx.train.x.gather_rows(rows);
            let dist = crate::par::pairwise_sqdist(&x);
            let sim = sim_from_sqdist(&dist);
            let mut fl = FacilityLocation::new(&sim);
            let res = lazy_greedy(&mut fl, budgets[cls]);
            let w = fl.medoid_weights(&res.selected);
            for (slot, &j) in res.selected.iter().enumerate() {
                out.push(rows[j], w[slot]);
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// spec parsing
// ---------------------------------------------------------------------------

/// Parse a strategy spec like `gradmatch-pb-warm`.
/// Returns the strategy and whether warm-start is requested.
pub fn parse_strategy(spec: &str, batch: usize) -> Result<(Box<dyn Strategy>, bool)> {
    let mut s = spec.trim().to_lowercase();
    let warm = s.ends_with("-warm");
    if warm {
        s.truncate(s.len() - "-warm".len());
    }
    let b: Box<dyn Strategy> = match s.as_str() {
        "gradmatch" => Box::new(GradMatch::new(GradMatchVariant::PerClassPerGradient, batch, true)),
        "gradmatch-perclass" => Box::new(GradMatch::new(GradMatchVariant::PerClass, batch, true)),
        "gradmatch-pb" => Box::new(GradMatch::new(GradMatchVariant::PerBatch, batch, true)),
        "gradmatch-rust" => Box::new(GradMatch::new(GradMatchVariant::PerClassPerGradient, batch, false)),
        "gradmatch-pb-rust" => Box::new(GradMatch::new(GradMatchVariant::PerBatch, batch, false)),
        "craig" => Box::new(Craig { per_batch: false, batch, use_xla: false }),
        "craig-pb" => Box::new(Craig { per_batch: true, batch, use_xla: true }),
        "glister" => Box::new(Glister),
        "random" => Box::new(Random),
        "full" | "full-earlystop" => Box::new(Full),
        "entropy" => Box::new(Entropy),
        "forgetting" => Box::new(Forgetting::new()),
        "featurefl" => Box::new(FeatureFL),
        other => return Err(anyhow!("unknown strategy '{other}' (from spec '{spec}')")),
    };
    Ok((b, warm))
}

/// All strategy specs the paper's Figure 3 sweeps compare.
pub fn paper_strategies() -> Vec<&'static str> {
    vec![
        "random", "random-warm",
        "glister", "glister-warm",
        "craig", "craig-warm", "craig-pb", "craig-pb-warm",
        "gradmatch", "gradmatch-warm", "gradmatch-pb", "gradmatch-pb-warm",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_budget_exact_and_proportional() {
        let b = split_budget(10, &[50, 30, 20]);
        assert_eq!(b.iter().sum::<usize>(), 10);
        assert_eq!(b, vec![5, 3, 2]);
    }

    #[test]
    fn split_budget_handles_remainders() {
        let b = split_budget(10, &[33, 33, 34]);
        assert_eq!(b.iter().sum::<usize>(), 10);
        assert!(b.iter().all(|&k| (3..=4).contains(&k)));
    }

    #[test]
    fn split_budget_caps_at_class_size() {
        let b = split_budget(10, &[2, 100]);
        assert_eq!(b.iter().sum::<usize>(), 10);
        assert!(b[0] <= 2);
    }

    #[test]
    fn split_budget_drains_leftovers_into_spare_capacity() {
        // only one class has spare capacity — every leftover must land
        // there, however many passes that takes
        let b = split_budget(12, &[1, 1, 1, 40]);
        assert_eq!(b.iter().sum::<usize>(), 12);
        assert!(b[..3].iter().all(|&x| x <= 1));
        // k ≥ total: saturate everything and terminate
        assert_eq!(split_budget(30, &[10, 3]), vec![10, 3]);
        // invariant sweep: Σout == min(k, Σsizes) and out[c] ≤ sizes[c]
        for k in 0..=20 {
            for sizes in [vec![0usize, 7, 2], vec![5, 5, 5], vec![1, 0, 13], vec![2, 2]] {
                let total: usize = sizes.iter().sum();
                let out = split_budget(k, &sizes);
                assert_eq!(out.iter().sum::<usize>(), k.min(total), "k={k} sizes={sizes:?}");
                assert!(out.iter().zip(&sizes).all(|(o, s)| o <= s), "k={k} sizes={sizes:?}");
            }
        }
    }

    #[test]
    fn split_budget_empty_classes() {
        let b = split_budget(6, &[0, 10, 0, 10]);
        assert_eq!(b.iter().sum::<usize>(), 6);
        assert_eq!(b[0], 0);
        assert_eq!(b[2], 0);
    }

    #[test]
    fn parse_strategy_specs() {
        for spec in paper_strategies() {
            let (s, warm) = parse_strategy(spec, 128).unwrap();
            assert_eq!(warm, spec.ends_with("-warm"));
            assert!(!s.name().is_empty());
        }
        assert!(parse_strategy("bogus", 128).is_err());
        let (s, warm) = parse_strategy("gradmatch-pb-warm", 32).unwrap();
        assert!(warm);
        assert_eq!(s.name(), "gradmatch-pb");
        let (s, _) = parse_strategy("FULL-EARLYSTOP", 32).unwrap();
        assert_eq!(s.name(), "full");
        assert!(!s.is_adaptive());
    }
}
