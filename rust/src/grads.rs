//! Gradient acquisition layer: runs the AOT'd gradient entry points over a
//! dataset and exposes the views the selection strategies need —
//! per-sample last-layer gradients, per-mini-batch (PB) aggregates,
//! per-class column slices (the paper's per-class-per-gradient
//! approximation), and mean/target gradients.

use anyhow::Result;

use crate::data::{padded_chunks, Dataset};
use crate::par::{dot, norm2};
use crate::runtime::{ModelState, Runtime};
use crate::tensor::{axpy, Matrix};

/// Per-sample gradients for a set of dataset rows.
#[derive(Clone, Debug)]
pub struct GradientStore {
    /// `[rows.len(), P]` — one last-layer gradient per row
    pub g: Matrix,
    /// dataset index of each gradient row
    pub rows: Vec<usize>,
}

/// Compute per-sample last-layer gradients for `indices` (chunked through
/// the `grads_chunk` executable; padding rows are dropped).
pub fn per_sample_grads(
    rt: &Runtime,
    st: &ModelState,
    ds: &Dataset,
    indices: &[usize],
) -> Result<GradientStore> {
    let meta = &st.meta;
    let mut g = Matrix::zeros(indices.len(), meta.p);
    let mut cursor = 0usize;
    for chunk in padded_chunks(ds, indices, meta.chunk) {
        let gm = rt.grads_chunk(st, &chunk.x, &chunk.y, &chunk.mask)?;
        for slot in 0..chunk.live {
            g.row_mut(cursor).copy_from_slice(gm.row(slot));
            cursor += 1;
        }
    }
    debug_assert_eq!(cursor, indices.len());
    Ok(GradientStore { g, rows: indices.to_vec() })
}

/// Mean last-layer gradient over `indices` — the matching target
/// ∇L(θ).  Uses the fused `mean_grad_chunk` fast path (never materializes
/// the per-sample matrix).
pub fn mean_gradient(
    rt: &Runtime,
    st: &ModelState,
    ds: &Dataset,
    indices: &[usize],
) -> Result<Vec<f32>> {
    let meta = &st.meta;
    let mut acc = vec![0.0f32; meta.p];
    for chunk in padded_chunks(ds, indices, meta.chunk) {
        let partial = rt.mean_grad_chunk(st, &chunk.x, &chunk.y, &chunk.mask)?;
        axpy(1.0, &partial, &mut acc);
    }
    let n = indices.len().max(1) as f32;
    for v in acc.iter_mut() {
        *v /= n;
    }
    Ok(acc)
}

/// Per-mini-batch mean gradients computed with the **device-side group
/// reduction** (`batch_gradsum_chunk`) — the PB fast path: readback is
/// `[n/B, P]` instead of `[n, P]` (§Perf: ~2× on PB selection rounds).
/// Groups are consecutive `meta.batch`-row blocks of `order`.
pub fn per_batch_grads_fused(
    rt: &Runtime,
    st: &ModelState,
    ds: &Dataset,
    order: &[usize],
) -> Result<(Matrix, Vec<Vec<usize>>)> {
    let meta = &st.meta;
    let b = meta.batch;
    let nb_total = order.len().div_ceil(b);
    let mut bg = Matrix::zeros(nb_total, meta.p);
    let mut members: Vec<Vec<usize>> = Vec::with_capacity(nb_total);
    let mut batch_cursor = 0usize;
    for chunk in padded_chunks(ds, order, meta.chunk) {
        let sums = rt.batch_gradsum_chunk(st, &chunk.x, &chunk.y, &chunk.mask)?;
        let groups_in_chunk = meta.chunk / b;
        for gi in 0..groups_in_chunk {
            let lo = gi * b;
            if lo >= chunk.live {
                break;
            }
            let hi = ((gi + 1) * b).min(chunk.live);
            let live = (hi - lo) as f32;
            let row = bg.row_mut(batch_cursor);
            row.copy_from_slice(sums.row(gi));
            for v in row.iter_mut() {
                *v /= live;
            }
            members.push(chunk.indices[lo..hi].to_vec());
            batch_cursor += 1;
        }
    }
    debug_assert_eq!(batch_cursor, nb_total);
    Ok((bg, members))
}

/// Per-mini-batch aggregation (the PB variants): group gradient rows into
/// consecutive batches of `batch` and average.  Returns the batch-gradient
/// matrix and the member rows of each batch.
pub fn per_batch_grads(store: &GradientStore, batch: usize) -> (Matrix, Vec<Vec<usize>>) {
    assert!(batch > 0);
    let n = store.g.rows;
    let p = store.g.cols;
    let nb = n.div_ceil(batch);
    let mut bg = Matrix::zeros(nb, p);
    let mut members = Vec::with_capacity(nb);
    for b in 0..nb {
        let lo = b * batch;
        let hi = ((b + 1) * batch).min(n);
        let row = bg.row_mut(b);
        for i in lo..hi {
            axpy(1.0, store.g.row(i), row);
        }
        let cnt = (hi - lo) as f32;
        for v in row.iter_mut() {
            *v /= cnt;
        }
        members.push(store.rows[lo..hi].to_vec());
    }
    (bg, members)
}

/// Column indices of class `cls` in the last-layer gradient layout
/// (`w2_row_major_hc_then_bias`): W2 entries `{j*C + cls : j < H}` plus the
/// bias entry `H*C + cls`.  This is the paper's *per-gradient*
/// approximation — class-c rows only have nonzero error in a few logits,
/// and their own logit dominates, so OMP runs on this (H+1)-dim slice.
pub fn class_columns(h: usize, c: usize, cls: usize) -> Vec<usize> {
    assert!(cls < c);
    let mut cols: Vec<usize> = (0..h).map(|j| j * c + cls).collect();
    cols.push(h * c + cls);
    cols
}

/// Gradient-matching error ‖ Σᵢ wᵢ gᵢ − target ‖ — the `Err` term of
/// Theorem 1, reported in Table 9 and logged at every selection round.
pub fn gradient_error(g_sel: &Matrix, weights: &[f32], target: &[f32]) -> f32 {
    assert_eq!(g_sel.rows, weights.len());
    assert_eq!(g_sel.cols, target.len());
    let mut fitted = vec![0.0f32; target.len()];
    for (i, &w) in weights.iter().enumerate() {
        if w != 0.0 {
            axpy(w, g_sel.row(i), &mut fitted);
        }
    }
    let diff = crate::tensor::sub(&fitted, target);
    norm2(&diff)
}

/// Cosine similarity between a matched gradient and the target — a cheap
/// health metric (Theorem 4's descent condition needs it positive).
pub fn match_cosine(g_sel: &Matrix, weights: &[f32], target: &[f32]) -> f32 {
    let mut fitted = vec![0.0f32; target.len()];
    for (i, &w) in weights.iter().enumerate() {
        axpy(w, g_sel.row(i), &mut fitted);
    }
    let denom = norm2(&fitted) * norm2(target);
    if denom <= 1e-20 {
        return 0.0;
    }
    dot(&fitted, target) / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_columns_layout() {
        // h=3, c=2: class 0 -> [0, 2, 4, 6]; class 1 -> [1, 3, 5, 7]
        assert_eq!(class_columns(3, 2, 0), vec![0, 2, 4, 6]);
        assert_eq!(class_columns(3, 2, 1), vec![1, 3, 5, 7]);
    }

    #[test]
    fn class_columns_cover_p_exactly_once() {
        let (h, c) = (5, 4);
        let mut all: Vec<usize> = (0..c).flat_map(|cls| class_columns(h, c, cls)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..h * c + c).collect::<Vec<_>>());
    }

    #[test]
    fn per_batch_grads_averages_rows() {
        let g = Matrix::from_vec(5, 2, vec![1., 1., 3., 3., 5., 5., 7., 7., 9., 9.]);
        let store = GradientStore { g, rows: vec![10, 11, 12, 13, 14] };
        let (bg, members) = per_batch_grads(&store, 2);
        assert_eq!(bg.rows, 3);
        assert_eq!(bg.row(0), &[2.0, 2.0]); // mean of rows 0,1
        assert_eq!(bg.row(2), &[9.0, 9.0]); // lone last row
        assert_eq!(members[0], vec![10, 11]);
        assert_eq!(members[2], vec![14]);
    }

    #[test]
    fn gradient_error_zero_for_exact_match() {
        let g = Matrix::from_vec(2, 3, vec![1., 0., 0., 0., 1., 0.]);
        let target = [2.0f32, 3.0, 0.0];
        let err = gradient_error(&g, &[2.0, 3.0], &target);
        assert!(err < 1e-6);
        let err2 = gradient_error(&g, &[0.0, 0.0], &target);
        assert!((err2 - (13.0f32).sqrt()).abs() < 1e-5);
    }

    #[test]
    fn match_cosine_signs() {
        let g = Matrix::from_vec(1, 2, vec![1.0, 0.0]);
        assert!((match_cosine(&g, &[1.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!((match_cosine(&g, &[-1.0], &[1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(match_cosine(&g, &[0.0], &[1.0, 0.0]), 0.0);
    }
}
