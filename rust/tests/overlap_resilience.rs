//! Overlap-worker resilience: a dead or failed background selector is an
//! `Err` the trainer survives, never a panic.
//!
//! Device-free half: `AsyncSelector` surfaces a failed worker (bogus
//! artifacts dir → `Runtime::load` error) through `recv`/`try_recv`, and
//! a subsequent `request` on the dead worker is an `Err` — the seam the
//! trainer's synchronous fallback hangs off.  Runtime half (skips
//! without HLO artifacts): `train_overlapped` with a doomed selector
//! finishes training and reports the synchronous-fallback rounds.

mod common;

use std::collections::HashMap;

use gradmatch::data::Dataset;
use gradmatch::engine::SelectionRequest;
use gradmatch::overlap::{AsyncSelector, SelectorConfig};
use gradmatch::rng::Rng;
use gradmatch::runtime::{ModelMeta, ModelState};
use gradmatch::selection::parse_strategy;
use gradmatch::tensor::Matrix;
use gradmatch::trainer::{train_overlapped, TrainOpts};

fn toy_meta() -> ModelMeta {
    let (d, h, c) = (4usize, 3usize, 2usize);
    ModelMeta {
        name: "toy".into(),
        d,
        h,
        c,
        batch: 4,
        chunk: 4,
        p: h * c + c,
        momentum: 0.9,
        weight_decay: 0.0,
        entries: HashMap::new(),
    }
}

fn toy_state() -> ModelState {
    let m = toy_meta();
    ModelState::new(
        &m,
        vec![0.0; m.d * m.h],
        vec![0.0; m.h],
        vec![0.0; m.h * m.c],
        vec![0.0; m.c],
    )
}

fn toy_dataset(seed: u64, n: usize, d: usize, classes: usize) -> Dataset {
    let mut rng = Rng::new(seed);
    let y: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

fn request(ground: Vec<usize>, budget: usize) -> SelectionRequest {
    SelectionRequest {
        strategy: "gradmatch".into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: 0,
        ground,
        shards: None,
        sketch: None,
    }
}

#[test]
fn failed_worker_surfaces_as_err_and_later_requests_do_not_panic() {
    let train = toy_dataset(1, 16, 4, 2);
    let val = toy_dataset(2, 8, 4, 2);
    let cfg = SelectorConfig {
        artifacts_dir: "definitely/not/an/artifacts/dir".into(),
        request: request((0..16).collect(), 4),
    };
    let mut sel = AsyncSelector::spawn(cfg, train, val).unwrap();

    // the worker's runtime-load failure arrives as a per-request Err
    let err = sel.recv().unwrap_err().to_string();
    assert!(err.contains("selector runtime"), "{err}");

    // once the worker thread has fully exited (its channel ends drop a
    // beat after the Err send lands), submitting and polling are Errs,
    // not the old `.expect("selector shut down")` panic
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let submit_dead = sel.request(toy_state(), 1001).is_err();
        let poll_dead = sel.try_recv().is_err();
        if submit_dead && poll_dead {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "request/try_recv on a dead worker must eventually be Errs \
             (submit_dead={submit_dead}, poll_dead={poll_dead})"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
}

#[test]
fn bad_strategy_spec_surfaces_through_the_worker_channel() {
    let train = toy_dataset(3, 16, 4, 2);
    let val = toy_dataset(4, 8, 4, 2);
    let mut req = request((0..16).collect(), 4);
    req.strategy = "bogus-spec".into();
    let cfg = SelectorConfig {
        // parse_strategy fails before the runtime matters on the stub
        // build; on a real-artifact build the runtime loads first and the
        // spec error still arrives on the channel
        artifacts_dir: common::artifacts_dir(),
        request: req,
    };
    let mut sel = AsyncSelector::spawn(cfg, train, val).unwrap();
    assert!(sel.recv().is_err(), "a worker that cannot serve rounds reports an Err");
}

// ---------------------------------------------------------------------------
// runtime-backed half (skips without HLO artifacts)
// ---------------------------------------------------------------------------

#[test]
fn training_survives_a_dead_selector_with_synchronous_fallback_rounds() {
    if !common::runtime_available() {
        return;
    }
    let rt = common::runtime();
    let splits = common::tiny_mnist(600);
    let ground: Vec<usize> = (0..splits.train.len()).collect();
    let st = rt.init("lenet_narrow", 5).unwrap();

    // a selector whose worker dies immediately (bogus artifacts dir)
    let cfg = SelectorConfig {
        artifacts_dir: "definitely/not/an/artifacts/dir".into(),
        request: request(ground.clone(), 60),
    };
    let mut sel = AsyncSelector::spawn(cfg, splits.train.clone(), splits.val.clone()).unwrap();

    let (mut strategy, _) = parse_strategy("gradmatch", st.meta.batch).unwrap();
    let opts = TrainOpts {
        epochs: 6,
        r_interval: 2,
        budget_frac: 0.1,
        overlap: true,
        ..Default::default()
    };
    let (_, out) = train_overlapped(
        &rt,
        st,
        &splits,
        &ground,
        strategy.as_mut(),
        &opts,
        Some(&mut sel),
    )
    .unwrap();

    assert_eq!(out.history.len(), 6, "training must run to completion");
    assert!(
        out.sync_fallback_rounds >= 1,
        "worker death must be absorbed by synchronous rounds (got {})",
        out.sync_fallback_rounds
    );
    assert!(out.selections >= 1, "synchronous fallback still selects");
    assert!(out.steps > 0);
}
