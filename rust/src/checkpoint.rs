//! Checkpointing: save/restore model state (params + momenta) to disk in a
//! small self-describing binary format, so long experiments can resume and
//! the examples can hand models between runs.
//!
//! Format (little-endian):
//! ```text
//! magic  "GMCK1\0"          6 bytes
//! model  name-len u32 + utf-8 bytes
//! epoch  u64
//! dims   d,h,c u32 ×3       (validated against the manifest on load)
//! state  2·(d·h + h + h·c + c) f32  (ModelState::pack layout)
//! crc    u32 (FNV-1a over the state bytes)
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{ModelMeta, ModelState};

const MAGIC: &[u8; 6] = b"GMCK1\0";

fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h = 0x811c9dc5u32;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

/// Save a model state (+ the epoch it was taken at).
///
/// Crash-safe: the bytes are written to a temp file in the *same directory*
/// and atomically renamed over `path`, so a crash mid-write can never leave a
/// truncated checkpoint under the final name — readers see either the old
/// complete file or the new complete file.
pub fn save(path: &Path, st: &ModelState, epoch: u64) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file_name = path
        .file_name()
        .ok_or_else(|| anyhow!("checkpoint path {} has no file name", path.display()))?
        .to_string_lossy()
        .into_owned();
    // Same directory as the target: rename(2) is only atomic within a
    // filesystem, and temp_dir() may be a different mount.
    let tmp = path.with_file_name(format!(".{}.tmp.{}", file_name, std::process::id()));
    let write = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(MAGIC)?;
        let name = st.meta.name.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&epoch.to_le_bytes())?;
        for v in [st.meta.d as u32, st.meta.h as u32, st.meta.c as u32] {
            f.write_all(&v.to_le_bytes())?;
        }
        let flat = st.pack();
        let bytes: Vec<u8> = flat.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        f.write_all(&fnv1a(&bytes).to_le_bytes())?;
        // flush to stable storage before the rename publishes the file
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
        Ok(())
    })();
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        anyhow!("renaming {} -> {}: {e}", tmp.display(), path.display())
    })?;
    Ok(())
}

/// `read_exact` with a descriptive error naming the field and the file, so a
/// truncated checkpoint reports *what* was missing instead of a bare
/// "failed to fill whole buffer".
fn read_field(f: &mut std::fs::File, buf: &mut [u8], what: &str, path: &Path) -> Result<()> {
    f.read_exact(buf).with_context(|| {
        format!("{}: checkpoint truncated or corrupt while reading {what}", path.display())
    })
}

/// Load a model state; validates magic, model identity, dims, and checksum.
pub fn load(path: &Path, meta: &ModelMeta) -> Result<(ModelState, u64)> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 6];
    read_field(&mut f, &mut magic, "magic", path)?;
    if &magic != MAGIC {
        bail!("{}: not a gradmatch checkpoint", path.display());
    }
    let mut u32buf = [0u8; 4];
    read_field(&mut f, &mut u32buf, "model name length", path)?;
    let name_len = u32::from_le_bytes(u32buf) as usize;
    if name_len > 256 {
        bail!("checkpoint name too long");
    }
    let mut name = vec![0u8; name_len];
    read_field(&mut f, &mut name, "model name", path)?;
    let name = String::from_utf8(name).map_err(|_| anyhow!("bad checkpoint name"))?;
    if name != meta.name {
        bail!("checkpoint is for model '{name}', expected '{}'", meta.name);
    }
    let mut u64buf = [0u8; 8];
    read_field(&mut f, &mut u64buf, "epoch", path)?;
    let epoch = u64::from_le_bytes(u64buf);
    let mut dims = [0u32; 3];
    for d in dims.iter_mut() {
        read_field(&mut f, &mut u32buf, "dims", path)?;
        *d = u32::from_le_bytes(u32buf);
    }
    if dims != [meta.d as u32, meta.h as u32, meta.c as u32] {
        bail!("checkpoint dims {dims:?} do not match manifest");
    }
    let n_state = 2 * (meta.d * meta.h + meta.h + meta.h * meta.c + meta.c);
    let mut bytes = vec![0u8; n_state * 4];
    read_field(&mut f, &mut bytes, "state tensor", path)?;
    read_field(&mut f, &mut u32buf, "checksum", path)?;
    let want_crc = u32::from_le_bytes(u32buf);
    if fnv1a(&bytes) != want_crc {
        bail!("{}: checksum mismatch (corrupt checkpoint)", path.display());
    }
    let flat: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((ModelState::unpack(meta, &flat), epoch))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn meta() -> ModelMeta {
        let m = Manifest::parse(
            r#"{"format":1,"interchange":"hlo-text","models":{"m1":{"d":4,"h":3,
            "c":2,"batch":8,"chunk":16,"p":8,"momentum":0.9,"weight_decay":0.0005,
            "entries":{}}}}"#,
        )
        .unwrap();
        m.models["m1"].clone()
    }

    fn sample_state(meta: &ModelMeta) -> ModelState {
        let mut st = ModelState::new(
            meta,
            (0..12).map(|v| v as f32 * 0.5).collect(),
            vec![1.0, 2.0, 3.0],
            (0..6).map(|v| -(v as f32)).collect(),
            vec![0.1, 0.2],
        );
        st.m_w1[3] = 7.5;
        st
    }

    #[test]
    fn roundtrip_preserves_state_and_epoch() {
        let meta = meta();
        let st = sample_state(&meta);
        let dir = std::env::temp_dir().join("gm_ckpt_test");
        let path = dir.join("a.ckpt");
        save(&path, &st, 42).unwrap();
        let (st2, epoch) = load(&path, &meta).unwrap();
        assert_eq!(epoch, 42);
        assert_eq!(st.w1, st2.w1);
        assert_eq!(st.b2, st2.b2);
        assert_eq!(st.m_w1, st2.m_w1);
    }

    #[test]
    fn rejects_wrong_model() {
        let meta = meta();
        let st = sample_state(&meta);
        let path = std::env::temp_dir().join("gm_ckpt_test/b.ckpt");
        save(&path, &st, 1).unwrap();
        let mut other = meta.clone();
        other.name = "different".into();
        assert!(load(&path, &other).is_err());
    }

    #[test]
    fn rejects_corruption() {
        let meta = meta();
        let st = sample_state(&meta);
        let path = std::env::temp_dir().join("gm_ckpt_test/c.ckpt");
        save(&path, &st, 1).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path, &meta).is_err());
    }

    #[test]
    fn truncated_checkpoint_is_descriptive_err_never_panic() {
        let meta = meta();
        let st = sample_state(&meta);
        let path = std::env::temp_dir().join("gm_ckpt_test/trunc.ckpt");
        save(&path, &st, 9).unwrap();
        let full = std::fs::read(&path).unwrap();
        // every possible truncation point must produce Err, never a panic
        for cut in [0, 3, 6, 8, full.len() / 4, full.len() / 2, full.len() - 5, full.len() - 1] {
            std::fs::write(&path, &full[..cut]).unwrap();
            let err = load(&path, &meta).expect_err(&format!("cut at {cut} must fail"));
            let msg = format!("{err:#}");
            assert!(
                msg.contains("trunc.ckpt"),
                "error should name the file (cut {cut}): {msg}"
            );
        }
        // a mid-file truncation should say what field was being read
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let msg = format!("{:#}", load(&path, &meta).unwrap_err());
        assert!(msg.contains("truncated"), "expected 'truncated' in: {msg}");
    }

    #[test]
    fn save_is_atomic_and_leaves_no_temp_file() {
        let meta = meta();
        let st = sample_state(&meta);
        let dir = std::env::temp_dir().join("gm_ckpt_atomic_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("e.ckpt");
        save(&path, &st, 1).unwrap();
        // overwrite in place: the final file is always a complete checkpoint
        save(&path, &st, 2).unwrap();
        let (_, epoch) = load(&path, &meta).unwrap();
        assert_eq!(epoch, 2);
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp files left behind: {leftovers:?}");
    }

    #[test]
    fn rejects_non_checkpoint_file() {
        let meta = meta();
        let path = std::env::temp_dir().join("gm_ckpt_test/d.ckpt");
        std::fs::write(&path, b"hello world, definitely not a checkpoint").unwrap();
        assert!(load(&path, &meta).is_err());
    }
}
