//! Mini property-testing harness (proptest is not in the offline vendor
//! set).  Seeded generators + a `forall` driver that reports the failing
//! seed/case so failures reproduce exactly.
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath in this sandbox):
//! ```no_run
//! use gradmatch::testutil::{forall, Gen};
//! forall(64, |g: &mut Gen| {
//!     let v = g.vec_f32(10, -1.0, 1.0);
//!     let s: f32 = v.iter().sum();
//!     assert!(s.abs() <= 10.0);
//! });
//! ```

use crate::rng::Rng;

/// Case-local generator handed to every property iteration.
pub struct Gen {
    pub rng: Rng,
    /// which iteration this is (0-based)
    pub case: usize,
}

impl Gen {
    /// Integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.usize(hi - lo + 1)
    }

    /// f32 in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    /// Uniform f32 vector.
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32(lo, hi)).collect()
    }

    /// Standard-normal f32 vector.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.gaussian_f32()).collect()
    }

    /// Random row-major matrix with gaussian entries.
    pub fn matrix(&mut self, rows: usize, cols: usize) -> crate::tensor::Matrix {
        crate::tensor::Matrix::from_vec(rows, cols, self.gauss_vec(rows * cols))
    }

    /// Pick one of the given items.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.usize(items.len())]
    }

    /// Random subset of indices `[0, n)` of size `k`.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        self.rng.sample_indices(n, k)
    }
}

/// Run `prop` on `cases` generated cases.  Panics (with the case number and
/// derived seed) on the first failure; rerun with `forall_seeded` to debug.
pub fn forall(cases: usize, prop: impl Fn(&mut Gen)) {
    forall_seeded(0xC0FFEE, cases, prop)
}

/// Like [`forall`] with an explicit master seed.
pub fn forall_seeded(seed: u64, cases: usize, prop: impl Fn(&mut Gen)) {
    let root = Rng::new(seed);
    for case in 0..cases {
        let mut g = Gen { rng: root.split(case as u64 + 1), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed on case {case} (seed {seed}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(32, |g| {
            let n = g.int(1, 20);
            let v = g.vec_f32(n, 0.0, 1.0);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|&x| (0.0..1.0).contains(&x)));
        });
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn forall_reports_failing_case() {
        forall(100, |g| {
            let v = g.int(0, 10);
            assert!(v < 10, "hit the boundary");
        });
    }

    #[test]
    fn gen_subset_is_valid() {
        forall(32, |g| {
            let n = g.int(2, 30);
            let k = g.int(1, n);
            let s = g.subset(n, k);
            assert_eq!(s.len(), k);
            assert!(s.iter().all(|&i| i < n));
        });
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first = Vec::new();
        forall_seeded(99, 5, |g| {
            if g.case == 3 {
                // capture values through interior mutability-free channel:
                // recompute in second pass below and compare
            }
            let _ = g.f32(0.0, 1.0);
        });
        // direct check: same split → same draw
        let root = Rng::new(99);
        for case in 0..5 {
            let mut g = Gen { rng: root.split(case + 1), case: case as usize };
            first.push(g.f32(0.0, 1.0));
        }
        let root2 = Rng::new(99);
        for case in 0..5 {
            let mut g = Gen { rng: root2.split(case + 1), case: case as usize };
            assert_eq!(first[case as usize], g.f32(0.0, 1.0));
        }
    }
}
