//! Figure 3 (h, i) + Table 6: (simulated) energy-gain vs relative error, and
//! the per-strategy energy-consumption table.  Energy is a phase-power
//! integral (DESIGN.md §4) — the shape the paper reports (energy tracks
//! time, selection overhead included) is what's asserted.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let strategies = [
        "random",
        "glister",
        "craig-pb",
        "gradmatch-pb",
        "gradmatch-pb-warm",
    ];
    let budgets = [0.05, 0.10, 0.30];
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;

    let mut all_ok = true;
    for (ds, model) in [("synmnist", "lenet_s"), ("syncifar10", "resnet_s")] {
        bh::section(&format!("Fig. 3h/i + Table 6 — simulated energy, {ds}"));
        let mut cfg = bh::bench_config(ds, model);
        cfg.epochs = 10;
        cfg.r_interval = 5;
        let rows = coord.sweep(&cfg, &strategies, &budgets)?;
        let full = coord.full_baseline(&cfg, cfg.seed)?;
        println!("FULL energy (sim): {:.6} kWh", full.energy_kwh);
        bh::table_header(&["strategy", "budget%", "kWh(sim)", "energy-x", "rel-err%"]);
        for r in &rows {
            bh::table_row(&[
                r.summary.strategy.clone(),
                format!("{:.0}", r.summary.budget_frac * 100.0),
                format!("{:.6}", r.summary.energy_kwh),
                format!("{:.2}", r.energy_ratio),
                format!("{:.2}", r.rel_err_pct),
            ]);
        }
        // shape checks at miniature scale: selection cost is a fixed
        // overhead that the short schedules don't amortize, so budget-
        // monotonicity of the energy gain is only asserted for RANDOM
        // (no selection cost); at full scale (examples/) the paper's
        // monotone shape holds for all strategies.
        let g30 = rows
            .iter()
            .find(|r| r.summary.strategy == "random" && r.summary.budget_frac == 0.30)
            .unwrap();
        all_ok &= bh::shape_check(
            &format!("{ds}/random: 30% subset energy below full"),
            g30.summary.energy_kwh < full.energy_kwh * 1.05,
        );
        let r05 = rows
            .iter()
            .find(|r| r.summary.strategy == "random" && r.summary.budget_frac == 0.05)
            .unwrap();
        let r30 = rows
            .iter()
            .find(|r| r.summary.strategy == "random" && r.summary.budget_frac == 0.30)
            .unwrap();
        all_ok &= bh::shape_check(
            &format!("{ds}/random: energy gain grows as budget shrinks"),
            r05.energy_ratio >= r30.energy_ratio * 0.95,
        );
    }
    println!("\nfig3_energy: {}", if all_ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
