//! Experiment configuration: typed config structs + a TOML-subset parser
//! (the `toml`/`serde` crates are not in the offline vendor set).
//!
//! The grammar covers what experiment files need: `[section]` headers,
//! `key = value` with string / number / bool / flat-array values, `#`
//! comments.  `--set section.key=value` CLI overrides reuse the same value
//! parser, so the launcher and files stay consistent.

use std::collections::BTreeMap;
use std::path::Path;

/// A parsed scalar/array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Parse(usize, String),
    Key(String),
    Invalid(String, String),
    Unknown(&'static str, String),
    Io(std::io::Error),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(line, msg) => write!(f, "config parse error on line {line}: {msg}"),
            ConfigError::Key(k) => write!(f, "missing or mistyped key '{k}'"),
            ConfigError::Invalid(k, why) => write!(f, "invalid value for '{k}': {why}"),
            ConfigError::Unknown(what, v) => write!(f, "unknown {what} '{v}'"),
            ConfigError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

/// Raw `[section] key=value` table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    map: BTreeMap<String, Value>,
}

impl Table {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Table, ConfigError> {
        let mut t = Table::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                let name = inner
                    .strip_suffix(']')
                    .ok_or_else(|| ConfigError::Parse(lineno + 1, "unclosed section".into()))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| ConfigError::Parse(lineno + 1, "expected key = value".into()))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = parse_value(v.trim())
                .ok_or_else(|| ConfigError::Parse(lineno + 1, format!("bad value: {v}")))?;
            t.map.insert(key, val);
        }
        Ok(t)
    }

    pub fn from_file(path: &Path) -> Result<Table, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            // name the file: a bare "No such file or directory" from a
            // CLI-supplied --config path is undiagnosable
            ConfigError::Io(std::io::Error::new(e.kind(), format!("{}: {e}", path.display())))
        })?;
        Table::parse(&text)
    }

    /// Apply a `section.key=value` override.
    pub fn set(&mut self, spec: &str) -> Result<(), ConfigError> {
        let (k, v) = spec
            .split_once('=')
            .ok_or_else(|| ConfigError::Parse(0, format!("override '{spec}' missing '='")))?;
        let val = parse_value(v.trim())
            .ok_or_else(|| ConfigError::Parse(0, format!("bad override value: {v}")))?;
        self.map.insert(k.trim().to_string(), val);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64, ConfigError> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.as_f64().ok_or_else(|| ConfigError::Key(key.into())),
        }
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize, ConfigError> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.as_usize().ok_or_else(|| ConfigError::Key(key.into())),
        }
    }

    /// Presence-aware opt-in count: an *absent* key means `default`
    /// (feature off), but an explicitly written 0, negative, or fractional
    /// value is rejected here by name — a degenerate plan must fail at the
    /// config boundary, not surface as a confusing no-op (or worse)
    /// downstream.
    fn opt_in_usize(&self, key: &str, default: usize) -> Result<usize, ConfigError> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => {
                let n = v.as_f64().ok_or_else(|| ConfigError::Key(key.into()))?;
                if n <= 0.0 || n.fract() != 0.0 {
                    return Err(ConfigError::Invalid(
                        key.into(),
                        format!("expected a positive integer, got {n} (omit the key to disable)"),
                    ));
                }
                Ok(n as usize)
            }
        }
    }

    fn str_or(&self, key: &str, default: &str) -> Result<String, ConfigError> {
        match self.map.get(key) {
            None => Ok(default.to_string()),
            Some(v) => v
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| ConfigError::Key(key.into())),
        }
    }

    fn bool_or(&self, key: &str, default: bool) -> Result<bool, ConfigError> {
        match self.map.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool().ok_or_else(|| ConfigError::Key(key.into())),
        }
    }
}

fn parse_value(s: &str) -> Option<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Some(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Some(Value::Bool(true));
    }
    if s == "false" {
        return Some(Value::Bool(false));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in inner.split(',') {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            items.push(parse_value(p)?);
        }
        return Some(Value::Arr(items));
    }
    s.parse::<f64>().ok().map(Value::Num)
}

// ---------------------------------------------------------------------------
// typed experiment config
// ---------------------------------------------------------------------------

/// Full configuration for one training run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// dataset card name (see `data::DatasetCard::by_name`)
    pub dataset: String,
    /// model variant (must exist in the artifact manifest)
    pub model: String,
    /// selection strategy spec, e.g. "gradmatch-pb-warm" (see selection::parse)
    pub strategy: String,
    /// subset fraction of the training set (paper: 0.01 – 0.30)
    pub budget_frac: f64,
    /// total training epochs T
    pub epochs: usize,
    /// re-select every R epochs (paper default 20)
    pub r_interval: usize,
    /// initial learning rate for cosine annealing (paper: 0.01)
    pub lr0: f64,
    /// OMP ridge regularizer λ (paper default 0.5)
    pub lambda: f64,
    /// OMP tolerance ε
    pub eps: f64,
    /// warm-start fraction κ (paper default 0.5)
    pub kappa: f64,
    /// master seed
    pub seed: u64,
    /// repeated runs for mean/std tables
    pub runs: usize,
    /// artifact directory (manifest.json lives here)
    pub artifacts_dir: String,
    /// where to write result json/csv
    pub out_dir: String,
    /// validate every N epochs (0 = only at end)
    pub eval_every: usize,
    /// use validation-set gradients as the matching target (class imbalance)
    pub is_valid: bool,
    /// dataset size override (0 = card default) — benches shrink this
    pub n_train: usize,
    /// fraction of classes made scarce when `is_valid` (paper: 0.3/0.6/0.9)
    pub imbalance_frac: f64,
    /// fraction of samples kept in the scarce classes (paper: 0.1)
    pub imbalance_keep: f64,
    /// fraction of training labels flipped to a random wrong class
    /// (robust-learning extension; 0 = clean)
    pub label_noise: f64,
    /// overlapped selection: serve selection from a background worker so
    /// training never stalls on a selection round (extension; see
    /// `rust/src/overlap.rs`)
    pub overlap: bool,
    /// selection memory budget: max ground rows staged at once.  `> 0`
    /// turns on the two-level sharded OMP path (shard count derived as
    /// `⌈n / max_staged_rows⌉`; see `engine::ShardPlan`); 0 = flat solve
    pub max_staged_rows: usize,
    /// sketched correlation: JL-project the staged `[n, P]` gradients to
    /// `[n, k]` before Batch-OMP, with a full-width re-fit on the selected
    /// support (see `engine::SketchPlan` / `sketch.rs`); 0 = full width
    pub sketch_width: usize,
    /// reuse selections across sweep arms: memoize each solved round in a
    /// coordinator-level `engine::SelectionCache` keyed by (dataset
    /// fingerprint, strategy spec, round signature), so later arms
    /// sharing a signature replay the subset with zero staging dispatches
    /// (MILO-style amortization; default off until the `sweep_transfer`
    /// bench justifies flipping it)
    pub reuse_across_arms: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "synmnist".into(),
            model: "lenet_s".into(),
            strategy: "gradmatch-pb".into(),
            budget_frac: 0.10,
            epochs: 60,
            r_interval: 20,
            lr0: 0.05,
            lambda: 0.5,
            eps: 1e-10,
            kappa: 0.5,
            seed: 42,
            runs: 1,
            artifacts_dir: "artifacts".into(),
            out_dir: "results".into(),
            eval_every: 5,
            is_valid: false,
            n_train: 0,
            imbalance_frac: 0.3,
            imbalance_keep: 0.1,
            label_noise: 0.0,
            overlap: false,
            max_staged_rows: 0,
            sketch_width: 0,
            reuse_across_arms: false,
        }
    }
}

impl ExperimentConfig {
    /// Build from a parsed table (missing keys take defaults).
    pub fn from_table(t: &Table) -> Result<Self, ConfigError> {
        let d = ExperimentConfig::default();
        Ok(ExperimentConfig {
            dataset: t.str_or("experiment.dataset", &d.dataset)?,
            model: t.str_or("experiment.model", &d.model)?,
            strategy: t.str_or("experiment.strategy", &d.strategy)?,
            budget_frac: t.f64_or("experiment.budget_frac", d.budget_frac)?,
            epochs: t.usize_or("experiment.epochs", d.epochs)?,
            r_interval: t.usize_or("experiment.r_interval", d.r_interval)?,
            lr0: t.f64_or("experiment.lr0", d.lr0)?,
            lambda: t.f64_or("selection.lambda", d.lambda)?,
            eps: t.f64_or("selection.eps", d.eps)?,
            kappa: t.f64_or("selection.kappa", d.kappa)?,
            seed: t.usize_or("experiment.seed", d.seed as usize)? as u64,
            runs: t.usize_or("experiment.runs", d.runs)?,
            artifacts_dir: t.str_or("paths.artifacts", &d.artifacts_dir)?,
            out_dir: t.str_or("paths.out", &d.out_dir)?,
            eval_every: t.usize_or("experiment.eval_every", d.eval_every)?,
            is_valid: t.bool_or("selection.is_valid", d.is_valid)?,
            n_train: t.usize_or("experiment.n_train", d.n_train)?,
            imbalance_frac: t.f64_or("selection.imbalance_frac", d.imbalance_frac)?,
            imbalance_keep: t.f64_or("selection.imbalance_keep", d.imbalance_keep)?,
            label_noise: t.f64_or("selection.label_noise", d.label_noise)?,
            overlap: t.bool_or("experiment.overlap", d.overlap)?,
            max_staged_rows: t.opt_in_usize("selection.max_staged_rows", d.max_staged_rows)?,
            sketch_width: t.opt_in_usize("selection.sketch_width", d.sketch_width)?,
            reuse_across_arms: t.bool_or("selection.reuse_across_arms", d.reuse_across_arms)?,
        })
    }

    /// Validate cross-field constraints.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if !(0.0 < self.budget_frac && self.budget_frac <= 1.0) {
            return Err(ConfigError::Key("experiment.budget_frac".into()));
        }
        if self.epochs == 0 || self.r_interval == 0 {
            return Err(ConfigError::Key("experiment.epochs/r_interval".into()));
        }
        if !(0.0..=1.0).contains(&self.kappa) {
            return Err(ConfigError::Key("selection.kappa".into()));
        }
        if self.lambda < 0.0 {
            return Err(ConfigError::Key("selection.lambda".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment file
[experiment]
dataset = "syncifar10"
model = "resnet_s"
strategy = "gradmatch-pb-warm"
budget_frac = 0.3
epochs = 300
r_interval = 20
seed = 7

[selection]
lambda = 0.5
is_valid = false

[paths]
artifacts = "artifacts"
"#;

    #[test]
    fn parse_sections_and_types() {
        let t = Table::parse(SAMPLE).unwrap();
        assert_eq!(t.get("experiment.dataset").unwrap().as_str(), Some("syncifar10"));
        assert_eq!(t.get("experiment.epochs").unwrap().as_usize(), Some(300));
        assert_eq!(t.get("selection.lambda").unwrap().as_f64(), Some(0.5));
        assert_eq!(t.get("selection.is_valid").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parse_arrays() {
        let t = Table::parse("budgets = [0.05, 0.1, 0.3]\n").unwrap();
        match t.get("budgets").unwrap() {
            Value::Arr(items) => {
                assert_eq!(items.len(), 3);
                assert_eq!(items[1].as_f64(), Some(0.1));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let t = Table::parse("# hi\n\na = 1 # trailing\n").unwrap();
        assert_eq!(t.get("a").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn bad_lines_error_with_lineno() {
        let e = Table::parse("a = 1\nbogus line\n").unwrap_err();
        match e {
            ConfigError::Parse(2, _) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn from_file_errors_name_the_path() {
        let e = Table::from_file(Path::new("definitely/not/here.toml")).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("definitely/not/here.toml"), "{msg}");
    }

    #[test]
    fn typed_config_roundtrip() {
        let t = Table::parse(SAMPLE).unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.dataset, "syncifar10");
        assert_eq!(c.model, "resnet_s");
        assert_eq!(c.epochs, 300);
        assert_eq!(c.seed, 7);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn overrides_win() {
        let mut t = Table::parse(SAMPLE).unwrap();
        t.set("experiment.epochs=5").unwrap();
        t.set("selection.lambda=0.1").unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.epochs, 5);
        assert_eq!(c.lambda, 0.1);
    }

    #[test]
    fn defaults_fill_missing() {
        let c = ExperimentConfig::from_table(&Table::default()).unwrap();
        assert_eq!(c.dataset, "synmnist");
        assert_eq!(c.r_interval, 20);
        assert!((c.lambda - 0.5).abs() < 1e-12);
        assert_eq!(c.max_staged_rows, 0, "sharding is opt-in");
    }

    #[test]
    fn max_staged_rows_parses() {
        let mut t = Table::default();
        t.set("selection.max_staged_rows=4096").unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.max_staged_rows, 4096);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn sketch_width_parses_and_defaults_off() {
        let c = ExperimentConfig::from_table(&Table::default()).unwrap();
        assert_eq!(c.sketch_width, 0, "sketching is opt-in");
        let mut t = Table::default();
        t.set("selection.sketch_width=256").unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert_eq!(c.sketch_width, 256);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn reuse_across_arms_parses_and_defaults_off() {
        let c = ExperimentConfig::from_table(&Table::default()).unwrap();
        assert!(!c.reuse_across_arms, "cross-arm subset reuse is opt-in");
        let mut t = Table::default();
        t.set("selection.reuse_across_arms=true").unwrap();
        let c = ExperimentConfig::from_table(&t).unwrap();
        assert!(c.reuse_across_arms);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn explicit_zero_opt_in_keys_are_rejected_by_name() {
        for key in ["selection.max_staged_rows", "selection.sketch_width"] {
            for bad in ["0", "-8", "3.5"] {
                let mut t = Table::default();
                t.set(&format!("{key}={bad}")).unwrap();
                let e = ExperimentConfig::from_table(&t).unwrap_err();
                match &e {
                    ConfigError::Invalid(k, why) => {
                        assert_eq!(k, key, "error must name the offending key");
                        assert!(why.contains("positive integer"), "{why}");
                    }
                    other => panic!("{key}={bad} should be Invalid, got {other:?}"),
                }
                let msg = e.to_string();
                assert!(msg.contains(key), "message must name the key: {msg}");
            }
        }
    }

    #[test]
    fn validate_catches_bad_values() {
        let mut c = ExperimentConfig::default();
        c.budget_frac = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.kappa = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn string_with_spaces_and_override_strings() {
        let mut t = Table::default();
        t.set(r#"paths.out="my results/dir""#).unwrap();
        assert_eq!(t.get("paths.out").unwrap().as_str(), Some("my results/dir"));
    }
}
