"""Layer-2 JAX model: the classifier + selection-support entry points.

This is the build-time half of the training path.  Every public function
here is jitted and lowered **once** by ``aot.py`` into HLO text under
``artifacts/<model>/``; the Rust coordinator loads and executes those —
Python never runs at training time.

Model: a two-layer MLP classifier ``x → relu(x W1 + b1) → W2 + b2`` with
softmax cross-entropy.  The paper trains ResNet-18 / LeNet on a V100; the
selection layer only ever consumes *last-layer* gradients, which have the
same structure for any network ending in a linear layer, so the substitution
preserves the behaviour the experiments measure (DESIGN.md §4).

Fixed-shape contract (HLO has static shapes; the Rust side pads + masks):

- ``B``  train mini-batch rows  (default 128)
- ``E``  eval chunk rows        (default 256)
- ``G``  gradient chunk rows    (default 256)
- ``P = H*C + C`` last-layer gradient dimension

SGD hyper-parameters follow the paper's setup (§5): momentum 0.9, weight
decay 5e-4 are baked as constants; the learning rate arrives as a runtime
scalar so the Rust side owns the cosine-annealing schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import gradmatch_kernels as kernels

MOMENTUM = 0.9
WEIGHT_DECAY = 5e-4


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static configuration for one AOT'd model variant."""

    name: str
    d: int            # input features
    h: int            # hidden width
    c: int            # classes
    batch: int = 128  # train mini-batch (B)
    chunk: int = 256  # eval/grad chunk (E = G)

    @property
    def p(self) -> int:
        """Last-layer gradient dimension H*C + C."""
        return self.h * self.c + self.c


# Variant registry. ``*_narrow`` are the Fig-3l "smaller model" proxies
# (MobileNet stand-ins): same depth, much narrower hidden layer.
MODELS: Dict[str, ModelSpec] = {
    spec.name: spec
    for spec in [
        ModelSpec("lenet_s", d=784, h=128, c=10),
        ModelSpec("resnet_s", d=1024, h=256, c=20),
        ModelSpec("lenet_narrow", d=784, h=32, c=10),
        ModelSpec("resnet_narrow", d=1024, h=64, c=20),
    ]
}

Params = Tuple[jax.Array, jax.Array, jax.Array, jax.Array]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(spec: ModelSpec, seed: jax.Array) -> Params:
    """He-initialized parameters from an int32 seed (traced, so one HLO)."""
    key = jax.random.key(seed.astype(jnp.uint32))
    k1, k2 = jax.random.split(key)
    s1 = jnp.sqrt(2.0 / spec.d).astype(jnp.float32)
    s2 = jnp.sqrt(2.0 / spec.h).astype(jnp.float32)
    w1 = jax.random.normal(k1, (spec.d, spec.h), jnp.float32) * s1
    b1 = jnp.zeros((spec.h,), jnp.float32)
    w2 = jax.random.normal(k2, (spec.h, spec.c), jnp.float32) * s2
    b2 = jnp.zeros((spec.c,), jnp.float32)
    return w1, b1, w2, b2


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def forward(params: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Hidden activations and logits."""
    w1, b1, w2, b2 = params
    h = jax.nn.relu(x @ w1 + b1)
    return h, h @ w2 + b2


def per_sample_ce(logits: jax.Array, y: jax.Array) -> jax.Array:
    """Per-sample softmax cross-entropy."""
    logz = jax.nn.logsumexp(logits, axis=1)
    true_logit = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
    return logz - true_logit


def weighted_loss(params: Params, x: jax.Array, y: jax.Array, w: jax.Array) -> jax.Array:
    """Weight-normalized subset loss  Σ w_i ℓ_i / Σ w_i  (Algorithm 1, line 9).

    ``w`` carries both the GRAD-MATCH instance/mini-batch weights and the
    padding mask (padded rows have w=0), so one scalar path serves every
    strategy including plain random subsets (w=1 on real rows).
    """
    _, logits = forward(params, x)
    ce = per_sample_ce(logits, y)
    return jnp.sum(w * ce) / jnp.maximum(jnp.sum(w), 1e-8)


# ---------------------------------------------------------------------------
# train step (weighted mini-batch SGD, momentum + weight decay)
# ---------------------------------------------------------------------------


def train_step(spec: ModelSpec, params: Params, momenta: Params,
               x: jax.Array, y: jax.Array, w: jax.Array, lr: jax.Array):
    """One weighted SGD step.  Returns (params', momenta', loss, correct).

    ``correct`` counts argmax hits on rows with w > 0 — the trainer uses it
    for cheap running train accuracy without a second forward pass.
    """
    loss, grads = jax.value_and_grad(weighted_loss)(params, x, y, w)
    new_params = []
    new_momenta = []
    for p, m, g in zip(params, momenta, grads):
        m2 = MOMENTUM * m + g + WEIGHT_DECAY * p
        new_params.append(p - lr * m2)
        new_momenta.append(m2)
    _, logits = forward(params, x)
    hit = (jnp.argmax(logits, axis=1) == y) & (w > 0)
    correct = jnp.sum(hit.astype(jnp.float32))
    return (*new_params, *new_momenta, loss, correct)


# ---------------------------------------------------------------------------
# fused train step (single packed state tensor)
# ---------------------------------------------------------------------------
#
# PJRT returns multi-output computations as ONE tuple buffer, so the Rust
# hot loop cannot keep 8 separate param/momentum buffers device-chained.
# Packing (params, momenta) into a single flat f32 state lets the trainer
# thread one literal through consecutive steps with no host re-marshalling
# of the model state (§Perf).  XLA fuses the pack/unpack slices away.


def state_shapes(spec: ModelSpec):
    return [(spec.d, spec.h), (spec.h,), (spec.h, spec.c), (spec.c,)]


def state_size(spec: ModelSpec) -> int:
    return 2 * sum(int(np_prod(s)) for s in state_shapes(spec))


def np_prod(shape) -> int:
    out = 1
    for v in shape:
        out *= int(v)
    return out


def pack_state(params: Params, momenta: Params) -> jax.Array:
    return jnp.concatenate([p.reshape(-1) for p in (*params, *momenta)])


def unpack_state(spec: ModelSpec, flat: jax.Array):
    shapes = state_shapes(spec) * 2
    out = []
    off = 0
    for shp in shapes:
        n = np_prod(shp)
        out.append(flat[off : off + n].reshape(shp))
        off += n
    return tuple(out[:4]), tuple(out[4:])


def train_step_fused(spec: ModelSpec, state: jax.Array,
                     x: jax.Array, y: jax.Array, w: jax.Array, lr: jax.Array):
    """One weighted SGD step over the packed state. Returns (state', loss,
    correct)."""
    params, momenta = unpack_state(spec, state)
    out = train_step(spec, params, momenta, x, y, w, lr)
    new_state = pack_state(out[:4], out[4:8])
    return new_state, out[8], out[9]


# ---------------------------------------------------------------------------
# eval chunk
# ---------------------------------------------------------------------------


def eval_chunk(spec: ModelSpec, params: Params,
               x: jax.Array, y: jax.Array, mask: jax.Array):
    """Masked eval over one fixed-size chunk.

    Returns (Σloss, Σcorrect, per-sample-correct[E], entropy[E]).  The
    per-sample outputs feed the forgetting-events counter and the entropy
    baseline (Table 12) with no extra forward passes.
    """
    _, logits = forward(params, x)
    ce = per_sample_ce(logits, y) * mask
    correct = (jnp.argmax(logits, axis=1) == y).astype(jnp.float32) * mask
    logp = jax.nn.log_softmax(logits, axis=1)
    entropy = -jnp.sum(jnp.exp(logp) * logp, axis=1) * mask
    return jnp.sum(ce), jnp.sum(correct), correct, entropy


# ---------------------------------------------------------------------------
# selection-side entry points (call the L1 Pallas kernels)
# ---------------------------------------------------------------------------


def _masked_err(params: Params, x: jax.Array, y: jax.Array, mask: jax.Array):
    h, logits = forward(params, x)
    probs = jax.nn.softmax(logits, axis=1)
    err = (probs - jax.nn.one_hot(y, logits.shape[1], dtype=jnp.float32))
    return h, err * mask[:, None]


def grads_chunk(spec: ModelSpec, params: Params,
                x: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Per-sample last-layer gradients ``G[G_chunk, P]`` (L1 fused kernel)."""
    h, err = _masked_err(params, x, y, mask)
    return kernels.per_sample_grads(h, err)


def mean_grad_chunk(spec: ModelSpec, params: Params,
                    x: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Σ_i grad_i without materializing G — the target-gradient fast path.

    Σ_i h_i ⊗ err_i = hᵀ err is a single [H,G]x[G,C] MXU matmul; XLA fuses
    the whole thing into one kernel.  The Rust side accumulates chunk sums
    and divides by the live count.
    """
    h, err = _masked_err(params, x, y, mask)
    w2g = (h.T @ err).reshape(spec.h * spec.c)
    b2g = jnp.sum(err, axis=0)
    return jnp.concatenate([w2g, b2g])


def corr_chunk(spec: ModelSpec, g: jax.Array, r: jax.Array) -> jax.Array:
    """OMP residual correlations for one gradient chunk (L1 kernel)."""
    return kernels.corr(g, r)


def sqdist_chunk(spec: ModelSpec, a: jax.Array, b: jax.Array) -> jax.Array:
    """Pairwise squared gradient distances for CRAIG (L1 kernel)."""
    return kernels.sqdist(a, b)


# ---------------------------------------------------------------------------
# fused per-mini-batch gradient sums (PB selection fast path)
# ---------------------------------------------------------------------------
#
# The PB variants only consume per-mini-batch mean gradients.  Materializing
# the per-sample matrix [chunk, P] and averaging host-side reads back
# chunk/B × too much data (5.2 MB vs 40 KB per chunk for resnet_s); this
# entry reduces the B-row groups on device — an MXU-shaped [nb,B,H]x[nb,B,C]
# batched contraction (§Perf).


def batch_gradsum_chunk(spec: ModelSpec, params: Params,
                        x: jax.Array, y: jax.Array, mask: jax.Array) -> jax.Array:
    """Per-mini-batch gradient *sums* over one chunk → [chunk/B, P].

    Groups are consecutive B-row blocks of the chunk; masked (padded) rows
    contribute zero, and the Rust side divides by live counts.
    """
    h, err = _masked_err(params, x, y, mask)
    nb = spec.chunk // spec.batch
    hg = h.reshape(nb, spec.batch, spec.h)
    eg = err.reshape(nb, spec.batch, spec.c)
    # [nb, H, C] batched contraction over the B dimension
    w2g = jax.lax.dot_general(hg, eg, (((1,), (1,)), ((0,), (0,))))
    b2g = jnp.sum(eg, axis=1)
    return jnp.concatenate([w2g.reshape(nb, spec.h * spec.c), b2g], axis=1)
