//! The selection-engine API: a typed [`SelectionRequest`] in, a
//! shared-staging [`SelectionEngine`] round, a structured
//! [`SelectionReport`] out.
//!
//! Algorithm 1 of the paper is one round of gradient staging followed by
//! an OMP solve.  Before this module, every caller (trainer, overlap
//! worker, benches, examples) hand-assembled a mutable
//! [`SelectCtx`](crate::selection::SelectCtx) and called
//! [`Strategy::select`](crate::selection::Strategy::select), so each
//! strategy re-staged its own gradients and the only output was a bare
//! index/weight list.  The engine makes the round a *service* boundary:
//!
//! - [`SelectionRequest`] — a plain, serializable description of one
//!   selection round (strategy spec, budget, λ/ε, ground set,
//!   train-vs-val matching, seed), constructible from
//!   [`ExperimentConfig`] and from CLI flags.
//! - [`SelectionEngine`] — owns the round: a live `Runtime` + owned
//!   model snapshot (or, for device-free tests and benches, an explicit
//!   [`GradOracle`]) plus a **round-scoped staging cache**
//!   ([`RoundShared`]), so N requests against the same model state — a
//!   strategy sweep, GRAD-MATCH + CRAIG in one round, warm + cold
//!   variants — share ONE [`grads::stage_class_grads`] pass instead of
//!   N.  Strategies are stateless solvers over [`GradSource`] oracle
//!   views, so **every** spec in
//!   [`crate::selection::strategy_specs`] — including the PB variants,
//!   ENTROPY, and FORGETTING — runs through either backend; the old
//!   `parse_strategy` + `select` path still works and rides the same
//!   solvers (with `round: None`, i.e. private staging).
//! - [`SelectionReport`] — the [`Selection`] plus per-round
//!   observability: staging/solve wall-clock split, staging dispatch
//!   count, per-class budgets from `split_budget`, residual
//!   `grad_error`, the fan-out-vs-serial decision, and the engine-reuse
//!   counters (`engine_round`, `stage_reused_buffers`).  Serialized via
//!   [`crate::jsonlite`] into `RunSummary` and `BENCH_micro.json`.
//!
//! The engine is **run-scoped, round-reusable**: build ONE engine per
//! run and call [`SelectionEngine::reset_round`] at every parameter
//! update — staged gradients are only valid for the snapshot they were
//! computed against, so the reset invalidates the cache, but the staging
//! buffers pool across rounds (the next pass scatters into last round's
//! matrices) and the probe keeps counting engine rounds.
//!
//! Dispatch contract (pinned by the counting-oracle tests in
//! `tests/engine_api.rs` and `tests/strategy_conformance.rs`): a
//! multi-strategy round over the class-sliced stage costs exactly
//! `⌈|ground|/chunk⌉` gradient dispatches however many requests consume
//! it; PB rounds cost `⌈|ground|/chunk⌉` group-sum dispatches; ENTROPY /
//! FORGETTING cost one eval-entry pass.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::grads::{self, ClassStage, GradOracle, RetryPolicy, StageWidth};
use crate::jsonlite::{arr, num, obj, s, Json};
use crate::rng::Rng;
use crate::runtime::{ModelState, Runtime};
use crate::selection::{parse_strategy, GradSource, SelectCtx, Selection, Strategy};

// ---------------------------------------------------------------------------
// ShardPlan
// ---------------------------------------------------------------------------

/// How a round's ground set is sharded for the two-level hierarchical OMP
/// path: the ground set is cut into contiguous shards, each shard staged
/// and solved independently (peak staged rows stay bounded), and a final
/// merge round re-stages only the shard winners and re-fits weights over
/// that reduced candidate pool.  `shards == 0` derives the shard count
/// from `max_staged_rows` (`⌈n / max_staged_rows⌉`); both zero — or an
/// effective count of 1 — means the flat path runs unchanged (pinned
/// bit-identical by `tests/shard_conformance.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct ShardPlan {
    /// explicit shard count (0 ⇒ derive from `max_staged_rows`)
    pub shards: usize,
    /// memory budget: max ground rows staged at once (0 ⇒ unbounded —
    /// shards stage together and the shard solves fan out in parallel)
    pub max_staged_rows: usize,
}

impl ShardPlan {
    /// Effective shard count for a ground set of `n` rows.
    pub fn shard_count(&self, n: usize) -> usize {
        let s = if self.shards > 0 {
            self.shards
        } else if self.max_staged_rows > 0 {
            n.div_ceil(self.max_staged_rows)
        } else {
            1
        };
        s.clamp(1, n.max(1))
    }

    fn to_json(self) -> Json {
        obj(vec![
            ("count", num(self.shards as f64)),
            ("max_staged_rows", num(self.max_staged_rows as f64)),
        ])
    }

    /// Lenient parse: absent/null ⇒ `None` (flat path); missing inner
    /// fields default to 0 so hand-written daemon requests can name only
    /// the knob they care about.
    fn from_json(j: &Json, k: &str) -> Option<ShardPlan> {
        match j.get(k) {
            None | Some(Json::Null) => None,
            Some(p) => Some(ShardPlan {
                shards: jusize(p, "count").unwrap_or(0),
                max_staged_rows: jusize(p, "max_staged_rows").unwrap_or(0),
            }),
        }
    }
}

// ---------------------------------------------------------------------------
// SketchPlan
// ---------------------------------------------------------------------------

/// How a round's correlation work is sketched (GRAFT-style): staged
/// `[n, P]` class matrices are random-projected to `[n, k]` with a
/// seeded JL projection (`crate::sketch`), Batch-OMP runs against the
/// sketched Gram, and the weights are optionally re-fit at full width on
/// the selected support.  A plan whose `width` is 0 — or at least the
/// staged column count — falls through to the flat path bit-identically
/// (pinned by `tests/sketch_conformance.rs`).  Composes with
/// [`ShardPlan`]: per-shard solves sketch, the merge refit runs
/// full-width.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SketchPlan {
    /// sketch width k (projected columns); 0 ⇒ sketching disabled
    pub width: usize,
    /// re-fit the selected support's weights at full width (non-negative
    /// ridge on the unsketched columns) — default on; off keeps the
    /// sketch-space weights
    pub refit: bool,
    /// extra salt folded into the projection seed so independent sweeps
    /// can decorrelate their projections at a fixed run seed
    pub seed_salt: u64,
}

impl Default for SketchPlan {
    fn default() -> SketchPlan {
        SketchPlan { width: 0, refit: true, seed_salt: 0 }
    }
}

impl SketchPlan {
    /// Whether this plan actually sketches a stage of `p` columns: a
    /// width of 0 or ≥ p is the identity (flat path).
    pub fn applies(&self, p: usize) -> bool {
        self.width > 0 && self.width < p
    }

    fn to_json(self) -> Json {
        obj(vec![
            ("width", num(self.width as f64)),
            ("refit", Json::Bool(self.refit)),
            // decimal string, like seed/rng_tag: salts above 2^53 must
            // survive the wire exactly
            ("seed_salt", s(&self.seed_salt.to_string())),
        ])
    }

    /// Lenient parse: absent/null ⇒ `None` (flat path); missing inner
    /// fields default (`refit` true, `seed_salt` 0) so hand-written
    /// daemon requests can name only the width.
    fn from_json(j: &Json, k: &str) -> Option<SketchPlan> {
        match j.get(k) {
            None | Some(Json::Null) => None,
            Some(p) => Some(SketchPlan {
                width: jusize(p, "width").unwrap_or(0),
                refit: p.get("refit").and_then(Json::as_bool).unwrap_or(true),
                seed_salt: ju64(p, "seed_salt").unwrap_or(0),
            }),
        }
    }
}

// ---------------------------------------------------------------------------
// SelectionRequest
// ---------------------------------------------------------------------------

/// A plain description of one selection round — everything the engine
/// needs to reproduce the round, and nothing tied to a live runtime, so
/// requests serialize, cross threads, and batch.
#[derive(Clone, Debug, PartialEq)]
pub struct SelectionRequest {
    /// strategy spec, e.g. `gradmatch-pb-warm` (see
    /// [`crate::selection::parse_strategy`]; the `-warm` suffix is the
    /// trainer's concern and is ignored by the engine)
    pub strategy: String,
    /// subset size k (samples)
    pub budget: usize,
    /// OMP ridge λ
    pub lambda: f32,
    /// OMP tolerance ε
    pub eps: f32,
    /// match validation gradients instead of training gradients (L = L_V)
    pub is_valid: bool,
    /// master run seed — combined with `rng_tag` into the round RNG
    pub seed: u64,
    /// per-round tag decorrelating rounds (the trainer uses 1000 + epoch)
    pub rng_tag: u64,
    /// ground set: dataset rows eligible for selection
    pub ground: Vec<usize>,
    /// optional two-level sharding plan (see [`ShardPlan`]); `None` — or
    /// an effective shard count of 1 — runs the flat path unchanged
    pub shards: Option<ShardPlan>,
    /// optional JL-sketching plan (see [`SketchPlan`]); `None` — or a
    /// width of 0 / ≥ the staged column count — runs the flat solve
    /// unchanged
    pub sketch: Option<SketchPlan>,
}

impl SelectionRequest {
    /// Build a request from an experiment config and a ground set; the
    /// budget is `budget_frac` of the ground size, clamped to `[1, n]`.
    /// (CLI flags reach here through
    /// [`crate::cli::Cli::experiment_config`].)
    pub fn from_config(cfg: &ExperimentConfig, ground: Vec<usize>) -> SelectionRequest {
        let n = ground.len();
        let budget = ((cfg.budget_frac * n as f64).round() as usize).clamp(1, n.max(1));
        SelectionRequest {
            strategy: cfg.strategy.clone(),
            budget,
            lambda: cfg.lambda as f32,
            eps: cfg.eps as f32,
            is_valid: cfg.is_valid,
            seed: cfg.seed,
            rng_tag: 0,
            ground,
            shards: if cfg.max_staged_rows > 0 {
                Some(ShardPlan { shards: 0, max_staged_rows: cfg.max_staged_rows })
            } else {
                None
            },
            sketch: if cfg.sketch_width > 0 {
                Some(SketchPlan { width: cfg.sketch_width, ..SketchPlan::default() })
            } else {
                None
            },
        }
    }

    /// The round's RNG stream.  One derivation for every driver — the
    /// synchronous trainer, the overlap worker, and one-shot engine
    /// calls — so a round is reproducible from `(seed, rng_tag)` alone.
    pub fn round_rng(&self) -> Rng {
        Rng::new(self.seed ^ 0xDA7A).split(self.rng_tag)
    }

    /// Serialize for result files / cross-process hand-off.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("strategy", s(&self.strategy)),
            ("budget", num(self.budget as f64)),
            ("lambda", num(self.lambda as f64)),
            ("eps", num(self.eps as f64)),
            ("is_valid", Json::Bool(self.is_valid)),
            // u64 as decimal strings: f64 JSON numbers lose integers
            // above 2^53, and the round RNG must survive hand-off exactly
            ("seed", s(&self.seed.to_string())),
            ("rng_tag", s(&self.rng_tag.to_string())),
            (
                "ground",
                arr(self.ground.iter().map(|&i| num(i as f64)).collect()),
            ),
        ];
        if let Some(plan) = self.shards {
            fields.push(("shards", plan.to_json()));
        }
        if let Some(plan) = self.sketch {
            fields.push(("sketch", plan.to_json()));
        }
        obj(fields)
    }

    /// Inverse of [`SelectionRequest::to_json`].
    pub fn from_json(j: &Json) -> Result<SelectionRequest> {
        Ok(SelectionRequest {
            strategy: jstr(j, "strategy")?,
            budget: jusize(j, "budget")?,
            lambda: jf64(j, "lambda")? as f32,
            eps: jf64(j, "eps")? as f32,
            is_valid: jbool(j, "is_valid")?,
            seed: ju64(j, "seed")?,
            rng_tag: ju64(j, "rng_tag")?,
            ground: jusize_arr(j, "ground")?,
            shards: ShardPlan::from_json(j, "shards"),
            sketch: SketchPlan::from_json(j, "sketch"),
        })
    }
}

// ---------------------------------------------------------------------------
// SelectionReport
// ---------------------------------------------------------------------------

/// How a round's answer was produced when the strategy solve could not
/// run to completion — the engine's degradation ladder, recorded
/// per-request in [`RoundStats::degradation`].  A selection round never
/// panics: a failed solve (exhausted dispatch retries, a poisoned
/// stage, a solver error) first reuses the engine's last successful
/// subset (Balles et al.'s observation that a slightly stale subset
/// still tracks the loss), and only with no prior subset at all falls
/// back to a seeded random subset (the model-agnostic floor MILO
/// motivates).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Degradation {
    /// the strategy solve completed normally
    #[default]
    None,
    /// solve failed; the last round's subset was served again
    ReusedLastRound,
    /// solve failed with no previous subset; a seeded random subset was
    /// served (deterministic in the request's `(seed, rng_tag)`)
    RandomFallback,
}

impl Degradation {
    /// Stable wire name (see [`SelectionReport::to_json`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            Degradation::None => "none",
            Degradation::ReusedLastRound => "reused-last-round",
            Degradation::RandomFallback => "random-fallback",
        }
    }

    fn from_str(v: &str) -> Result<Degradation> {
        match v {
            "none" => Ok(Degradation::None),
            "reused-last-round" => Ok(Degradation::ReusedLastRound),
            "random-fallback" => Ok(Degradation::RandomFallback),
            other => Err(anyhow!("json: unknown degradation '{other}'")),
        }
    }
}

/// Per-round observability — the staging/solve decomposition of one
/// request.  Timings are wall-clock; `stage_*` covers the shared
/// [`grads::stage_class_grads`] pass (target/score passes count as
/// solve time).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundStats {
    /// seconds spent staging gradients (0 when served from the cache)
    pub stage_secs: f64,
    /// seconds spent in everything after staging (targets, solves, merge)
    pub solve_secs: f64,
    /// padded runtime dispatches the staging pass issued for this request
    /// (`⌈|ground|/chunk⌉` on a cache miss, 0 on a hit)
    pub stage_dispatches: usize,
    /// staged gradients were served from the round's shared cache
    pub stage_shared: bool,
    /// per-class budgets from `split_budget` (empty for strategies that
    /// do not decompose per class)
    pub class_budgets: Vec<usize>,
    /// the per-class solves fanned out across the machine
    /// ([`crate::par::fanout_wins`]) rather than running serially
    pub fanout: bool,
    /// which engine round served this request: the number of
    /// [`SelectionEngine::reset_round`] calls before it ran.  `> 0` means
    /// the request rode a *reused* engine — the per-run counter
    /// `RunSummary::engine_reused_rounds` aggregates this.
    pub engine_round: usize,
    /// the staging pass scattered into buffers recycled from a previous
    /// engine round (no fresh `[|ground|, w]` allocation)
    pub stage_reused_buffers: bool,
    /// chunk dispatches retried under the round's
    /// [`grads::RetryPolicy`] (attempts beyond each dispatch's first);
    /// 0 on a fault-free round
    pub retries: usize,
    /// non-finite gradient rows quarantined by the staging pass (never
    /// staged, never selectable)
    pub quarantined: usize,
    /// how the answer was produced when the solve failed (see
    /// [`Degradation`]); `None` on a normal round
    pub degradation: Degradation,
    /// ground-set shards the round solved over: `> 1` for the two-level
    /// sharded path, 1 when a shard plan resolved to the flat path, 0
    /// for plan-less rounds and strategies that ignore the plan
    pub shards: usize,
    /// seconds the sharded path spent staging shard slices + the merge
    /// re-stage (a subset of `stage_secs`; 0 on the flat path)
    pub shard_stage_secs: f64,
    /// shard winners entering the merge round's candidate pool (0 on the
    /// flat path)
    pub merge_candidates: usize,
    /// most ground rows staged simultaneously — the memory high-water
    /// mark a [`ShardPlan::max_staged_rows`] budget bounds (`|ground|`
    /// when a plan resolved to the flat path; 0 for plan-less rounds)
    pub peak_staged_rows: usize,
    /// sketch width k the round's OMP solves ran at (0 when sketching
    /// did not apply — no plan, width ≥ the staged column count, or the
    /// strategy ignores the plan)
    pub sketch_width: usize,
    /// seconds spent projecting staged matrices/targets into sketch
    /// space (solve-side time — NOT part of `stage_secs`)
    pub sketch_secs: f64,
    /// seconds spent re-fitting the selected support's weights at full
    /// width (0 when `SketchPlan::refit` is off or sketching did not
    /// apply)
    pub refit_secs: f64,
    /// the round was served from a [`SelectionCache`] hit — no engine
    /// was built, no gradients staged, zero dispatches issued
    pub cache_hit: bool,
    /// the round's selection was stored into a [`SelectionCache`] for
    /// later arms sharing its signature
    pub cache_stored: bool,
    /// wall-clock seconds the hit saved: the cached entry's recorded
    /// solve cost (0 unless `cache_hit`)
    pub cache_saved_secs: f64,
}

/// The engine's answer to one [`SelectionRequest`]: the selection itself
/// plus the round's observability.
#[derive(Clone, Debug, PartialEq)]
pub struct SelectionReport {
    /// the request's strategy spec, echoed
    pub strategy: String,
    /// the request's budget, echoed
    pub budget: usize,
    pub selection: Selection,
    pub stats: RoundStats,
}

impl SelectionReport {
    /// Serialize via [`crate::jsonlite`] (used by `RunSummary` and the
    /// bench reports).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("strategy", s(&self.strategy)),
            ("budget", num(self.budget as f64)),
            (
                "selection",
                obj(vec![
                    (
                        "indices",
                        arr(self.selection.indices.iter().map(|&i| num(i as f64)).collect()),
                    ),
                    (
                        "weights",
                        arr(self.selection.weights.iter().map(|&w| num(w as f64)).collect()),
                    ),
                    (
                        "grad_error",
                        self.selection.grad_error.map(|e| num(e as f64)).unwrap_or(Json::Null),
                    ),
                ]),
            ),
            (
                "round",
                obj(vec![
                    ("stage_secs", num(self.stats.stage_secs)),
                    ("solve_secs", num(self.stats.solve_secs)),
                    ("stage_dispatches", num(self.stats.stage_dispatches as f64)),
                    ("stage_shared", Json::Bool(self.stats.stage_shared)),
                    (
                        "class_budgets",
                        arr(self.stats.class_budgets.iter().map(|&b| num(b as f64)).collect()),
                    ),
                    ("fanout", Json::Bool(self.stats.fanout)),
                    ("engine_round", num(self.stats.engine_round as f64)),
                    (
                        "stage_reused_buffers",
                        Json::Bool(self.stats.stage_reused_buffers),
                    ),
                    ("retries", num(self.stats.retries as f64)),
                    ("quarantined", num(self.stats.quarantined as f64)),
                    ("degradation", s(self.stats.degradation.as_str())),
                    ("shards", num(self.stats.shards as f64)),
                    ("shard_stage_secs", num(self.stats.shard_stage_secs)),
                    ("merge_candidates", num(self.stats.merge_candidates as f64)),
                    ("peak_staged_rows", num(self.stats.peak_staged_rows as f64)),
                    ("sketch_width", num(self.stats.sketch_width as f64)),
                    ("sketch_secs", num(self.stats.sketch_secs)),
                    ("refit_secs", num(self.stats.refit_secs)),
                    ("cache_hit", Json::Bool(self.stats.cache_hit)),
                    ("cache_stored", Json::Bool(self.stats.cache_stored)),
                    ("cache_saved_secs", num(self.stats.cache_saved_secs)),
                ]),
            ),
        ])
    }

    /// Inverse of [`SelectionReport::to_json`].
    pub fn from_json(j: &Json) -> Result<SelectionReport> {
        let sel = j
            .get("selection")
            .ok_or_else(|| anyhow!("report json: missing 'selection'"))?;
        let grad_error = match sel.get("grad_error") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64()
                    .ok_or_else(|| anyhow!("report json: bad 'grad_error'"))? as f32,
            ),
        };
        let weights = sel
            .get("weights")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("report json: missing 'weights'"))?
            .iter()
            .map(|v| v.as_f64().map(|w| w as f32))
            .collect::<Option<Vec<f32>>>()
            .ok_or_else(|| anyhow!("report json: bad weight"))?;
        let round = j
            .get("round")
            .ok_or_else(|| anyhow!("report json: missing 'round'"))?;
        Ok(SelectionReport {
            strategy: jstr(j, "strategy")?,
            budget: jusize(j, "budget")?,
            selection: Selection {
                indices: jusize_arr(sel, "indices")?,
                weights,
                grad_error,
            },
            stats: RoundStats {
                stage_secs: jf64(round, "stage_secs")?,
                solve_secs: jf64(round, "solve_secs")?,
                stage_dispatches: jusize(round, "stage_dispatches")?,
                stage_shared: jbool(round, "stage_shared")?,
                class_budgets: jusize_arr(round, "class_budgets")?,
                fanout: jbool(round, "fanout")?,
                engine_round: jusize(round, "engine_round")?,
                stage_reused_buffers: jbool(round, "stage_reused_buffers")?,
                // fault-tolerance fields are lenient: reports written
                // before the retry/quarantine/ladder counters existed
                // parse to the fault-free defaults
                retries: jusize(round, "retries").unwrap_or(0),
                quarantined: jusize(round, "quarantined").unwrap_or(0),
                degradation: match round.get("degradation").and_then(Json::as_str) {
                    Some(v) => Degradation::from_str(v)?,
                    None => Degradation::None,
                },
                // sharding counters are lenient too: pre-shard reports
                // parse to the flat-path defaults
                shards: jusize(round, "shards").unwrap_or(0),
                shard_stage_secs: jf64(round, "shard_stage_secs").unwrap_or(0.0),
                merge_candidates: jusize(round, "merge_candidates").unwrap_or(0),
                peak_staged_rows: jusize(round, "peak_staged_rows").unwrap_or(0),
                // sketch counters are lenient too: pre-sketch reports
                // parse to the unsketched defaults
                sketch_width: jusize(round, "sketch_width").unwrap_or(0),
                sketch_secs: jf64(round, "sketch_secs").unwrap_or(0.0),
                refit_secs: jf64(round, "refit_secs").unwrap_or(0.0),
                // cross-arm cache counters are lenient too: pre-cache
                // reports parse to the uncached defaults
                cache_hit: jbool(round, "cache_hit").unwrap_or(false),
                cache_stored: jbool(round, "cache_stored").unwrap_or(false),
                cache_saved_secs: jf64(round, "cache_saved_secs").unwrap_or(0.0),
            },
        })
    }
}

// -- small jsonlite field readers -------------------------------------------

fn jstr(j: &Json, k: &str) -> Result<String> {
    j.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("json: missing string '{k}'"))
}

fn jf64(j: &Json, k: &str) -> Result<f64> {
    j.get(k)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("json: missing number '{k}'"))
}

fn jusize(j: &Json, k: &str) -> Result<usize> {
    j.get(k)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("json: missing integer '{k}'"))
}

fn jbool(j: &Json, k: &str) -> Result<bool> {
    j.get(k)
        .and_then(Json::as_bool)
        .ok_or_else(|| anyhow!("json: missing bool '{k}'"))
}

/// u64 field: decimal string (exact), with integral-number fallback for
/// hand-written documents.
fn ju64(j: &Json, k: &str) -> Result<u64> {
    match j.get(k) {
        Some(Json::Str(v)) => v
            .parse::<u64>()
            .map_err(|e| anyhow!("json: bad u64 '{k}': {e}")),
        Some(Json::Num(v)) if *v >= 0.0 && v.fract() == 0.0 && *v <= 9_007_199_254_740_992.0 => {
            Ok(*v as u64)
        }
        _ => Err(anyhow!("json: missing u64 '{k}'")),
    }
}

fn jusize_arr(j: &Json, k: &str) -> Result<Vec<usize>> {
    j.get(k)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("json: missing array '{k}'"))?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Option<Vec<usize>>>()
        .ok_or_else(|| anyhow!("json: bad integer in '{k}'"))
}

// ---------------------------------------------------------------------------
// SelectionCache — cross-arm selection memoization (MILO-style)
// ---------------------------------------------------------------------------

/// Everything that pins a round's solved subset *except* the model being
/// trained: the dataset scope, the strategy spec, and the round
/// signature (seed / rng epoch-tag / budget / ground-set FNV /
/// [`ShardPlan`] / [`SketchPlan`] / λ, ε, L-vs-L_V).  The model and
/// learning rate are deliberately NOT part of the key — reusing one
/// arm's subsets while tuning those is exactly the MILO-style
/// decoupling `benches/sweep_transfer.rs` measures.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    scope: u64,
    strategy: String,
    seed: u64,
    rng_tag: u64,
    budget: usize,
    ground_fnv: u64,
    shards: Option<ShardPlan>,
    sketch: Option<SketchPlan>,
    lambda_bits: u32,
    eps_bits: u32,
    is_valid: bool,
}

impl CacheKey {
    /// Key for `req` under a caller-chosen dataset `scope` fingerprint
    /// (the coordinator hashes the dataset name + split/imbalance knobs;
    /// the daemon hashes the tenant's run config).
    pub fn for_request(scope: u64, req: &SelectionRequest) -> CacheKey {
        CacheKey {
            scope,
            strategy: req.strategy.clone(),
            seed: req.seed,
            rng_tag: req.rng_tag,
            budget: req.budget,
            ground_fnv: ground_fingerprint(&req.ground),
            shards: req.shards,
            sketch: req.sketch,
            lambda_bits: req.lambda.to_bits(),
            eps_bits: req.eps.to_bits(),
            is_valid: req.is_valid,
        }
    }
}

struct CacheEntry {
    selection: Selection,
    /// wall-clock the original solve cost — credited to
    /// `RoundStats::cache_saved_secs` on a hit
    cost_secs: f64,
    /// logical insert/touch time driving LRU eviction
    last_used: u64,
}

struct CacheInner {
    cap: usize,
    tick: u64,
    map: HashMap<CacheKey, CacheEntry>,
    hits: u64,
    stores: u64,
    evictions: u64,
}

/// Cross-arm selection memoization: a bounded LRU of solved
/// [`Selection`]s keyed by [`CacheKey`], so the second and later sweep
/// arms sharing a round signature replay the subset and pay **zero**
/// staging dispatches for that round (pinned by `tests/sweep_cache.rs`).
///
/// The coordinator owns one per sweep/run-batch when
/// `selection.reuse_across_arms` is on; the daemon owns one per process
/// (`--selection-cache-cap`), scoped per tenant run config.  Interior
/// mutability is a `Mutex` so a single instance serves the
/// single-threaded coordinator and the daemon's worker pool alike.
pub struct SelectionCache {
    inner: Mutex<CacheInner>,
}

impl SelectionCache {
    /// `cap` bounds the number of memoized rounds; 0 disables storage
    /// (every lookup misses).
    pub fn new(cap: usize) -> SelectionCache {
        SelectionCache {
            inner: Mutex::new(CacheInner {
                cap,
                tick: 0,
                map: HashMap::new(),
                hits: 0,
                stores: 0,
                evictions: 0,
            }),
        }
    }

    /// Cached selection for `key`, touching its LRU slot.  Returns the
    /// subset plus the wall-clock the original solve cost.
    pub fn get(&self, key: &CacheKey) -> Option<(Selection, f64)> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                let out = (e.selection.clone(), e.cost_secs);
                inner.hits += 1;
                Some(out)
            }
            None => None,
        }
    }

    /// Memoize a solved round.  Past `cap`, the least-recently-used
    /// entry is evicted first; re-storing an existing key refreshes it.
    pub fn put(&self, key: CacheKey, selection: Selection, cost_secs: f64) {
        let mut inner = self.inner.lock().unwrap();
        if inner.cap == 0 {
            return;
        }
        inner.tick += 1;
        let tick = inner.tick;
        if !inner.map.contains_key(&key) {
            while inner.map.len() >= inner.cap {
                let oldest = inner
                    .map
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| k.clone());
                match oldest {
                    Some(k) => {
                        inner.map.remove(&k);
                        inner.evictions += 1;
                    }
                    None => break,
                }
            }
        }
        inner
            .map
            .insert(key, CacheEntry { selection, cost_secs, last_used: tick });
        inner.stores += 1;
    }

    /// `(depth, hits, stores, evictions)` — surfaced by the daemon's
    /// `stats` reply and the coordinator's run summary.
    pub fn stats(&self) -> (usize, u64, u64, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.map.len(), inner.hits, inner.stores, inner.evictions)
    }

    /// The full hit/miss protocol for one round, shared by the trainer,
    /// the daemon, and the conformance tests so their key/get/put logic
    /// cannot drift: a hit replays the memoized subset as a report with
    /// `cache_hit` set and ZERO staging work; a miss runs `solve`, then
    /// memoizes the result — unless the solve degraded, because a
    /// reused-last-round or random-fallback subset must never poison
    /// later arms.
    pub fn round<F>(
        &self,
        scope: u64,
        req: &SelectionRequest,
        solve: F,
    ) -> Result<SelectionReport>
    where
        F: FnOnce() -> Result<SelectionReport>,
    {
        let key = CacheKey::for_request(scope, req);
        if let Some((selection, cost_secs)) = self.get(&key) {
            return Ok(SelectionReport {
                strategy: req.strategy.clone(),
                budget: req.budget,
                selection,
                stats: RoundStats {
                    cache_hit: true,
                    cache_saved_secs: cost_secs,
                    ..RoundStats::default()
                },
            });
        }
        let mut report = solve()?;
        if report.stats.degradation == Degradation::None {
            let cost = report.stats.stage_secs + report.stats.solve_secs;
            self.put(key, report.selection.clone(), cost);
            report.stats.cache_stored = true;
        }
        Ok(report)
    }
}

/// FNV-1a fold of one `u64` into a running scope hash — the coordinator
/// and daemon build their dataset-scope fingerprints from this so the
/// two ends hash identically simple ingredients.
pub fn scope_fold(h: u64, v: u64) -> u64 {
    let mut h = h ^ v;
    h = h.wrapping_mul(0x1_0000_0000_01b3);
    h
}

/// Scope fingerprint from a string plus numeric knobs (FNV-1a over the
/// bytes, then each knob folded in).
pub fn scope_fingerprint(name: &str, knobs: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    for &k in knobs {
        h = scope_fold(h, k);
    }
    h
}

// ---------------------------------------------------------------------------
// RoundShared — the round-scoped staging cache + observability probe
// ---------------------------------------------------------------------------

/// FNV-1a over the ground indices — the cache key component that lets two
/// requests share a stage only when they select from the same ground set
/// (and, via [`SelectionCache`], across sweep arms).
pub fn ground_fingerprint(ground: &[usize]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &i in ground {
        h ^= i as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h ^ ground.len() as u64
}

/// Round-scoped engine state every request of the round borrows (through
/// `SelectCtx::round`): the staged-gradient cache — keyed by
/// `(StageWidth, ground fingerprint)` — and the per-request
/// observability probe.  The first request at a given key pays the
/// `⌈|ground|/chunk⌉`-dispatch staging pass; every later request reuses
/// the store for free.  Stages are always built with targets (the
/// accumulation costs host flops, not dispatches) so target-free
/// consumers like CRAIG share the target-bearing store with GRAD-MATCH.
#[derive(Default)]
pub struct RoundShared {
    stages: RefCell<HashMap<(StageWidth, u64), Arc<Vec<ClassStage>>>>,
    /// validation class means keyed by the live-flags vector (an
    /// `is_valid` sweep pays the per-class `[P]` readbacks once)
    val_means: RefCell<HashMap<Vec<bool>, Arc<Vec<Option<Vec<f32>>>>>>,
    /// staged buffers recycled across [`RoundShared::reset`] calls, keyed
    /// like `stages`: the trainer re-stages the same ground set every
    /// round, so the next round's scatter reuses last round's matrices
    /// instead of reallocating `[|ground|, w]`
    pool: RefCell<HashMap<(StageWidth, u64), Vec<ClassStage>>>,
    /// completed `reset` calls — the engine-round index stamped into
    /// every report's `RoundStats::engine_round`
    rounds: Cell<usize>,
    probe: RefCell<RoundStats>,
    /// retry policy applied at the chunk-dispatch seam for every
    /// acquisition pass of the round (run-scoped: survives `reset`)
    retry: Cell<RetryPolicy>,
    /// the active request's sharding plan (installed per-request by the
    /// engine before the strategy runs; `None` ⇒ flat path)
    shard_plan: Cell<Option<ShardPlan>>,
    /// the active request's sketching plan (installed per-request, like
    /// the shard plan; `None` ⇒ full-width solves)
    sketch_plan: Cell<Option<SketchPlan>>,
}

impl RoundShared {
    pub fn new() -> RoundShared {
        RoundShared::default()
    }

    /// The engine-round index: how many [`RoundShared::reset`] calls have
    /// completed (0 for a fresh engine).
    pub fn round_index(&self) -> usize {
        self.rounds.get()
    }

    /// Invalidate the round: staged gradients and validation means are
    /// only valid for the snapshot they were computed against, so drop
    /// the caches — but park uniquely-owned staged buffers in the reuse
    /// pool (allocations survive the reset) and advance the engine-round
    /// index.  The pool is rebuilt from scratch each reset (only the
    /// immediately-previous round's buffers are retained), so an engine
    /// whose ground sets vary across rounds cannot accumulate stale
    /// staged matrices for the life of the run.  The probe restarts
    /// clean.
    pub fn reset(&self) {
        let mut pool = self.pool.borrow_mut();
        pool.clear();
        for (key, staged) in self.stages.borrow_mut().drain() {
            if let Ok(bufs) = Arc::try_unwrap(staged) {
                pool.insert(key, bufs);
            }
        }
        self.val_means.borrow_mut().clear();
        *self.probe.borrow_mut() = RoundStats::default();
        self.rounds.set(self.rounds.get() + 1);
    }

    /// Fetch (or stage once) the per-class gradient matrices for `ground`
    /// at `width`, recording the staging time and dispatch count into the
    /// probe on a miss and the shared flag on a hit.  A miss first checks
    /// the cross-round reuse pool so re-staging the same ground set
    /// recycles the previous round's buffers.
    pub fn class_stages(
        &self,
        oracle: &mut dyn GradOracle,
        ds: &Dataset,
        ground: &[usize],
        h: usize,
        c: usize,
        width: StageWidth,
    ) -> Result<Arc<Vec<ClassStage>>> {
        let key = (width, ground_fingerprint(ground));
        if let Some(hit) = self.stages.borrow().get(&key) {
            self.probe.borrow_mut().stage_shared = true;
            return Ok(hit.clone());
        }
        let chunk = oracle.chunk_rows().max(1);
        let prev = self.pool.borrow_mut().remove(&key).unwrap_or_default();
        let t0 = Instant::now();
        let (staged, reused, quarantined) =
            grads::stage_class_grads_reusing(oracle, ds, ground, h, c, width, true, prev)?;
        let staged = Arc::new(staged);
        {
            let mut probe = self.probe.borrow_mut();
            probe.stage_secs += t0.elapsed().as_secs_f64();
            probe.stage_dispatches += ground.len().div_ceil(chunk);
            probe.stage_reused_buffers |= reused;
            probe.quarantined += quarantined;
        }
        self.stages.borrow_mut().insert(key, staged.clone());
        Ok(staged)
    }

    /// Fetch (or compute once) the validation-side class means for a set
    /// of live-class flags — the L_V matching targets.  Cached like the
    /// stages: the readback-heavy fused per-class mean passes run once
    /// per distinct flag set, however many requests consume them.
    pub fn val_class_means(
        &self,
        oracle: &mut dyn GradOracle,
        val: &Dataset,
        c: usize,
        flags: &[bool],
    ) -> Result<Arc<Vec<Option<Vec<f32>>>>> {
        if let Some(hit) = self.val_means.borrow().get(flags) {
            return Ok(hit.clone());
        }
        let means = Arc::new(grads::live_val_class_means_with(oracle, val, c, flags)?);
        self.val_means.borrow_mut().insert(flags.to_vec(), means.clone());
        Ok(means)
    }

    /// Record the round's per-class budgets.
    pub fn note_budgets(&self, budgets: &[usize]) {
        self.probe.borrow_mut().class_budgets = budgets.to_vec();
    }

    /// Record the fan-out-vs-serial decision.
    pub fn note_fanout(&self, fanout: bool) {
        self.probe.borrow_mut().fanout = fanout;
    }

    /// The retry policy acquisition passes of this round dispatch under.
    pub fn retry_policy(&self) -> RetryPolicy {
        self.retry.get()
    }

    /// Install a retry policy for the rest of the run (run-scoped:
    /// survives [`RoundShared::reset`]).
    pub fn set_retry_policy(&self, policy: RetryPolicy) {
        self.retry.set(policy);
    }

    /// Fold one acquisition pass's retried dispatches into the probe.
    pub fn note_retries(&self, n: usize) {
        if n > 0 {
            self.probe.borrow_mut().retries += n;
        }
    }

    /// Record how the request's answer was produced when the solve
    /// failed (the degradation ladder's rung).
    pub fn note_degradation(&self, rung: Degradation) {
        self.probe.borrow_mut().degradation = rung;
    }

    /// Install the active request's sharding plan (engine-internal; the
    /// strategy reads it back through `SelectCtx::shard_plan`).
    pub fn set_shard_plan(&self, plan: Option<ShardPlan>) {
        self.shard_plan.set(plan);
    }

    /// The active request's sharding plan, if any.
    pub fn shard_plan(&self) -> Option<ShardPlan> {
        self.shard_plan.get()
    }

    /// Install the active request's sketching plan (engine-internal; the
    /// strategy reads it back through `SelectCtx::sketch_plan`).
    pub fn set_sketch_plan(&self, plan: Option<SketchPlan>) {
        self.sketch_plan.set(plan);
    }

    /// The active request's sketching plan, if any.
    pub fn sketch_plan(&self) -> Option<SketchPlan> {
        self.sketch_plan.get()
    }

    /// Record one sketched solve's outcome: the width the OMP ran at and
    /// the projection/refit wall-clock.  Secs accumulate (the sharded
    /// path sketches per shard); the width records the round's solve
    /// width.  Sketch/refit time is solve-side — it is deliberately NOT
    /// folded into `stage_secs`, so `solve_secs = total - stage_secs`
    /// still covers it.
    pub fn note_sketch(&self, width: usize, sketch_secs: f64, refit_secs: f64) {
        let mut probe = self.probe.borrow_mut();
        probe.sketch_width = probe.sketch_width.max(width);
        probe.sketch_secs += sketch_secs;
        probe.refit_secs += refit_secs;
    }

    /// Fold one shard-scoped staging pass (a shard slice or the merge
    /// re-stage) into the probe.  Shard stage time/dispatches count into
    /// BOTH the flat `stage_secs`/`stage_dispatches` totals (so
    /// `solve_secs = total - stage_secs` stays correct) and the
    /// shard-specific `shard_stage_secs`.
    pub fn note_shard_stage(&self, secs: f64, dispatches: usize, quarantined: usize, reused: bool) {
        let mut probe = self.probe.borrow_mut();
        probe.stage_secs += secs;
        probe.shard_stage_secs += secs;
        probe.stage_dispatches += dispatches;
        probe.quarantined += quarantined;
        probe.stage_reused_buffers |= reused;
    }

    /// Record the round's sharding outcome: shard count, merge-round
    /// candidate-pool size, and the staged-rows high-water mark.
    pub fn note_shards(&self, shards: usize, merge_candidates: usize, peak_staged_rows: usize) {
        let mut probe = self.probe.borrow_mut();
        probe.shards = shards;
        probe.merge_candidates = merge_candidates;
        probe.peak_staged_rows = probe.peak_staged_rows.max(peak_staged_rows);
    }

    /// Drain the probe for the request that just finished (the cache
    /// itself persists for the rest of the round).
    pub fn take_stats(&self) -> RoundStats {
        std::mem::take(&mut *self.probe.borrow_mut())
    }
}

// ---------------------------------------------------------------------------
// SelectionEngine
// ---------------------------------------------------------------------------

/// Gradient source backing an engine: the live PJRT runtime + an owned
/// model snapshot (owned so [`SelectionEngine::reset_round`] can install
/// each round's fresh parameters into one long-lived engine), or an
/// explicit oracle (tests/benches — covers the whole strategy catalog
/// device-free; XLA solve arms fall back to the Rust solvers).
enum Backend<'a> {
    Live {
        rt: &'a Runtime,
        state: ModelState,
    },
    Oracle {
        oracle: RefCell<&'a mut dyn GradOracle>,
        h: usize,
        c: usize,
    },
}

/// One selection round as a service: owns the gradient source and the
/// shared staging cache, answers [`SelectionRequest`]s with
/// [`SelectionReport`]s.  See the module docs for the sharing contract.
pub struct SelectionEngine<'a> {
    backend: Backend<'a>,
    train: &'a Dataset,
    val: &'a Dataset,
    shared: RoundShared,
    /// mini-batch size handed to strategy constructors (PB ground sets)
    batch: usize,
    /// the most recent subset this engine served (solved or degraded) —
    /// the degradation ladder's first rung
    last_good: RefCell<Option<Selection>>,
}

impl<'a> SelectionEngine<'a> {
    /// Live engine over a runtime and one model snapshot.  Build ONE
    /// engine per run and call [`SelectionEngine::reset_round`] with each
    /// later snapshot instead of rebuilding.
    pub fn new(
        rt: &'a Runtime,
        state: ModelState,
        train: &'a Dataset,
        val: &'a Dataset,
    ) -> SelectionEngine<'a> {
        SelectionEngine {
            batch: state.meta.batch,
            backend: Backend::Live { rt, state },
            train,
            val,
            shared: RoundShared::default(),
            last_good: RefCell::new(None),
        }
    }

    /// Device-free engine over an explicit [`GradOracle`] (`h`/`c` give
    /// the class column layout; the oracle's P must equal `h*c + c`).
    /// Serves EVERY spec in [`crate::selection::strategy_specs`]: the
    /// oracle seam covers per-sample/fused gradients, the PB group sums,
    /// and the eval-entry streams, and the XLA solve arms fall back to
    /// the Rust solvers.  PB grouping follows the oracle's
    /// [`GradOracle::batch_rows`].
    pub fn with_oracle(
        oracle: &'a mut dyn GradOracle,
        train: &'a Dataset,
        val: &'a Dataset,
        h: usize,
        c: usize,
    ) -> SelectionEngine<'a> {
        let batch = oracle.batch_rows();
        SelectionEngine {
            batch,
            backend: Backend::Oracle { oracle: RefCell::new(oracle), h, c },
            train,
            val,
            shared: RoundShared::default(),
            last_good: RefCell::new(None),
        }
    }

    /// The round's shared staging cache (what `SelectCtx::round` borrows).
    pub fn shared(&self) -> &RoundShared {
        &self.shared
    }

    /// Install the retry policy applied at the chunk-dispatch seam for
    /// the rest of the run (default: [`RetryPolicy::default`]).
    pub fn set_retry_policy(&self, policy: RetryPolicy) {
        self.shared.set_retry_policy(policy);
    }

    /// Start the next selection round on this engine: invalidate the
    /// round-scoped caches (staged gradients are only valid for the
    /// snapshot they were computed against) while keeping the staging
    /// buffers poolable for the next pass, and install the fresh
    /// parameter snapshot on live engines.  Oracle engines pass `None` —
    /// the caller mutates its oracle (e.g. a salt bump) to model the
    /// update.
    pub fn reset_round(&mut self, state: Option<ModelState>) {
        self.shared.reset();
        if let Some(snap) = state {
            if let Backend::Live { state: current, .. } = &mut self.backend {
                *current = snap;
            }
        }
    }

    /// Answer one request, resolving the strategy spec fresh (unknown
    /// specs fail with the full [`crate::selection::strategy_specs`]
    /// catalog, like the legacy parser).  Stateful baselines (FORGETTING)
    /// lose their cross-round memory on this path — drive those through
    /// [`SelectionEngine::select_with`] with a caller-held instance, as
    /// the trainer does.
    pub fn select(&self, req: &SelectionRequest) -> Result<SelectionReport> {
        let (mut strategy, _warm) = parse_strategy(&req.strategy, self.batch)?;
        self.select_with(strategy.as_mut(), req)
    }

    /// Answer one request with a caller-held strategy instance (stateful
    /// baselines keep their memory; the trainer keeps one instance per
    /// run).  Works on both backends — the strategy sees the engine's
    /// gradient source through the [`GradSource`] seam.
    pub fn select_with(
        &self,
        strategy: &mut dyn Strategy,
        req: &SelectionRequest,
    ) -> Result<SelectionReport> {
        let t0 = Instant::now();
        let mut rng = req.round_rng();
        self.shared.set_shard_plan(req.shards);
        self.shared.set_sketch_plan(req.sketch);
        let solved = match &self.backend {
            Backend::Live { rt, state } => strategy.select(&mut SelectCtx {
                src: GradSource::Live { rt: *rt, state },
                train: self.train,
                ground: &req.ground,
                val: self.val,
                budget: req.budget,
                lambda: req.lambda,
                eps: req.eps,
                is_valid: req.is_valid,
                rng: &mut rng,
                round: Some(&self.shared),
            }),
            Backend::Oracle { oracle, h, c } => {
                let mut guard = oracle.borrow_mut();
                strategy.select(&mut SelectCtx {
                    src: GradSource::Oracle { oracle: &mut **guard, h: *h, c: *c },
                    train: self.train,
                    ground: &req.ground,
                    val: self.val,
                    budget: req.budget,
                    lambda: req.lambda,
                    eps: req.eps,
                    is_valid: req.is_valid,
                    rng: &mut rng,
                    round: Some(&self.shared),
                })
            }
        };
        // the degradation ladder: a failed solve is downgraded, never
        // surfaced — every request gets *an* answer, with the rung
        // recorded in the report (the probe keeps whatever staging cost
        // the failed attempt already paid)
        let selection = match solved {
            Ok(sel) => sel,
            Err(e) => {
                let (sel, rung) = self.degrade(req, &e);
                self.shared.note_degradation(rung);
                sel
            }
        };
        *self.last_good.borrow_mut() = Some(selection.clone());
        Ok(self.report(req, selection, t0))
    }

    /// Strategy solve failed: serve the last subset this engine produced
    /// when one exists, else a seeded random subset — deterministic in
    /// the request's `(seed, rng_tag)`, so a degraded round is as
    /// reproducible as a normal one.
    fn degrade(&self, req: &SelectionRequest, err: &anyhow::Error) -> (Selection, Degradation) {
        degrade_selection(self.last_good.borrow().as_ref(), req, err)
    }

    /// Answer a batch of requests against this round's model state —
    /// the sweep entry point: every request that stages at the same
    /// `(width, ground)` key shares one staging pass.
    pub fn select_batch(&self, reqs: &[SelectionRequest]) -> Result<Vec<SelectionReport>> {
        reqs.iter().map(|r| self.select(r)).collect()
    }

    fn report(&self, req: &SelectionRequest, selection: Selection, t0: Instant) -> SelectionReport {
        finish_report(&self.shared, req, selection, t0)
    }
}

/// The degradation ladder, shared by both engine flavors: reuse the last
/// good subset when one exists, else a seeded random subset — deterministic
/// in the request's `(seed, rng_tag)`.
fn degrade_selection(
    last_good: Option<&Selection>,
    req: &SelectionRequest,
    err: &anyhow::Error,
) -> (Selection, Degradation) {
    if let Some(prev) = last_good {
        eprintln!(
            "engine: solve failed ({err:#}); reusing last round's subset ({} rows)",
            prev.indices.len()
        );
        return (prev.clone(), Degradation::ReusedLastRound);
    }
    let n = req.ground.len();
    let k = req.budget.min(n);
    eprintln!("engine: solve failed ({err:#}); no previous subset — random fallback ({k} rows)");
    let mut rng = req.round_rng().split(0xFA11);
    let picks = rng.sample_indices(n, k);
    let selection = Selection {
        indices: picks.into_iter().map(|i| req.ground[i]).collect(),
        weights: vec![1.0; k],
        grad_error: None,
    };
    (selection, Degradation::RandomFallback)
}

/// Drain the round probe into a finished report (both engine flavors).
fn finish_report(
    shared: &RoundShared,
    req: &SelectionRequest,
    selection: Selection,
    t0: Instant,
) -> SelectionReport {
    let total = t0.elapsed().as_secs_f64();
    let mut stats = shared.take_stats();
    stats.solve_secs = (total - stats.stage_secs).max(0.0);
    stats.engine_round = shared.round_index();
    SelectionReport {
        strategy: req.strategy.clone(),
        budget: req.budget,
        selection,
        stats,
    }
}

// ---------------------------------------------------------------------------
// PooledEngine — the owned, Send engine the selection daemon pools per run
// ---------------------------------------------------------------------------

/// An owned oracle-backed engine: the daemon's per-run pool slot.
///
/// [`SelectionEngine`] borrows its gradient source and datasets, which is
/// right for a single run driving its own rounds but cannot live in a
/// long-lived multi-tenant pool.  `PooledEngine` owns everything — a boxed
/// [`GradOracle`] stack (e.g. `FaultyOracle<SynthGrads>` under a fault
/// plan), `Arc` datasets — and is `Send`, so `par::map_tasks` can carry one
/// run's engine onto whichever worker thread picks that run up while the
/// round-ordering guarantee holds (the pool checks a run's slot *out*, so
/// two rounds of one run can never race).
///
/// Semantics match the oracle arm of [`SelectionEngine`] exactly: same
/// shared staging cache, same degradation ladder, same report shape —
/// pinned by `pooled_engine_matches_selection_engine` below.
pub struct PooledEngine {
    oracle: Box<dyn GradOracle + Send>,
    h: usize,
    c: usize,
    /// mini-batch size handed to strategy constructors (PB ground sets)
    batch: usize,
    train: Arc<Dataset>,
    val: Arc<Dataset>,
    shared: RoundShared,
    last_good: Option<Selection>,
}

impl PooledEngine {
    /// Build an engine owning its oracle and datasets.  `h`/`c` give the
    /// class column layout; the oracle's P must equal `h*c + c` (the
    /// sliced-stage contract), which is validated here so a misconfigured
    /// tenant fails its *first* request with a typed error instead of a
    /// staging panic mid-round.
    pub fn new(
        oracle: Box<dyn GradOracle + Send>,
        train: Arc<Dataset>,
        val: Arc<Dataset>,
        h: usize,
        c: usize,
    ) -> Result<PooledEngine> {
        if oracle.p() != h * c + c {
            return Err(anyhow!(
                "oracle P={} does not match class layout h*c+c={} (h={h}, c={c})",
                oracle.p(),
                h * c + c
            ));
        }
        let batch = oracle.batch_rows();
        Ok(PooledEngine {
            oracle,
            h,
            c,
            batch,
            train,
            val,
            shared: RoundShared::default(),
            last_good: None,
        })
    }

    /// The engine's shared staging cache (stats probe lives here).
    pub fn shared(&self) -> &RoundShared {
        &self.shared
    }

    /// Install the retry policy applied at the chunk-dispatch seam.
    pub fn set_retry_policy(&self, policy: RetryPolicy) {
        self.shared.set_retry_policy(policy);
    }

    /// Start the next selection round: invalidate the round-scoped caches,
    /// pool the staging buffers, advance the engine-round index.
    pub fn reset_round(&mut self) {
        self.shared.reset();
    }

    /// Answer one request (see [`SelectionEngine::select`]).
    pub fn select(&mut self, req: &SelectionRequest) -> Result<SelectionReport> {
        let (mut strategy, _warm) = parse_strategy(&req.strategy, self.batch)?;
        self.select_with(strategy.as_mut(), req)
    }

    /// Answer one request with a caller-held strategy instance (see
    /// [`SelectionEngine::select_with`]).
    pub fn select_with(
        &mut self,
        strategy: &mut dyn Strategy,
        req: &SelectionRequest,
    ) -> Result<SelectionReport> {
        let t0 = Instant::now();
        let mut rng = req.round_rng();
        self.shared.set_shard_plan(req.shards);
        self.shared.set_sketch_plan(req.sketch);
        let solved = strategy.select(&mut SelectCtx {
            src: GradSource::Oracle { oracle: &mut *self.oracle, h: self.h, c: self.c },
            train: &self.train,
            ground: &req.ground,
            val: &self.val,
            budget: req.budget,
            lambda: req.lambda,
            eps: req.eps,
            is_valid: req.is_valid,
            rng: &mut rng,
            round: Some(&self.shared),
        });
        let selection = match solved {
            Ok(sel) => sel,
            Err(e) => {
                let (sel, rung) = degrade_selection(self.last_good.as_ref(), req, &e);
                self.shared.note_degradation(rung);
                sel
            }
        };
        self.last_good = Some(selection.clone());
        Ok(finish_report(&self.shared, req, selection, t0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrips() {
        let req = SelectionRequest {
            strategy: "gradmatch-pb-warm".into(),
            budget: 37,
            lambda: 0.5,
            eps: 1e-10,
            is_valid: true,
            // above 2^53: must survive exactly (u64s travel as strings)
            seed: u64::MAX - 7,
            rng_tag: 1004,
            ground: vec![3, 1, 4, 1, 5, 9],
            shards: Some(ShardPlan { shards: 3, max_staged_rows: 2 }),
            // salt above 2^53: must survive exactly (travels as a string)
            sketch: Some(SketchPlan { width: 16, refit: false, seed_salt: u64::MAX - 3 }),
        };
        let parsed = Json::parse(&req.to_json().dump()).unwrap();
        let back = SelectionRequest::from_json(&parsed).unwrap();
        assert_eq!(req, back);
        // no plans ⇒ the fields are omitted on the wire and parse back None
        let mut flat = req.clone();
        flat.shards = None;
        flat.sketch = None;
        let parsed = Json::parse(&flat.to_json().dump()).unwrap();
        assert!(parsed.get("shards").is_none());
        assert!(parsed.get("sketch").is_none());
        assert_eq!(SelectionRequest::from_json(&parsed).unwrap(), flat);
    }

    #[test]
    fn sketch_plan_applies_and_lenient_parse() {
        // identity widths: 0 (disabled) and >= p fall through to flat
        assert!(!SketchPlan::default().applies(64));
        assert!(!SketchPlan { width: 64, ..Default::default() }.applies(64));
        assert!(!SketchPlan { width: 100, ..Default::default() }.applies(64));
        assert!(SketchPlan { width: 8, ..Default::default() }.applies(64));
        // lenient parse: a request naming only the width gets refit=true
        // and salt 0; null/absent plans parse to None
        let j = Json::parse(r#"{"sketch": {"width": 12}}"#).unwrap();
        assert_eq!(
            SketchPlan::from_json(&j, "sketch"),
            Some(SketchPlan { width: 12, refit: true, seed_salt: 0 })
        );
        let null = Json::parse(r#"{"sketch": null}"#).unwrap();
        assert_eq!(SketchPlan::from_json(&null, "sketch"), None);
        assert_eq!(SketchPlan::from_json(&Json::parse("{}").unwrap(), "sketch"), None);
    }

    #[test]
    fn shard_plan_count_derivation() {
        // explicit count wins
        assert_eq!(ShardPlan { shards: 4, max_staged_rows: 0 }.shard_count(100), 4);
        // derived from the memory budget: ⌈n / max_staged_rows⌉
        assert_eq!(ShardPlan { shards: 0, max_staged_rows: 30 }.shard_count(100), 4);
        assert_eq!(ShardPlan { shards: 0, max_staged_rows: 100 }.shard_count(100), 1);
        // both zero ⇒ flat; counts clamp to [1, n]
        assert_eq!(ShardPlan::default().shard_count(100), 1);
        assert_eq!(ShardPlan { shards: 500, max_staged_rows: 0 }.shard_count(10), 10);
        assert_eq!(ShardPlan { shards: 3, max_staged_rows: 0 }.shard_count(0), 1);
    }

    #[test]
    fn request_from_config_clamps_budget() {
        let cfg = ExperimentConfig { budget_frac: 0.1, ..Default::default() };
        let req = SelectionRequest::from_config(&cfg, (0..50).collect());
        assert_eq!(req.budget, 5);
        assert_eq!(req.strategy, cfg.strategy);
        // degenerate ground sets still produce a sane request
        let tiny = SelectionRequest::from_config(&cfg, vec![7]);
        assert_eq!(tiny.budget, 1);
        let empty = SelectionRequest::from_config(&cfg, Vec::new());
        assert_eq!(empty.budget, 1);
        assert!(empty.ground.is_empty());
    }

    #[test]
    fn round_rng_is_reproducible_and_tag_sensitive() {
        let mut req = SelectionRequest::from_config(&ExperimentConfig::default(), vec![0, 1, 2]);
        req.rng_tag = 1003;
        let (mut a, mut b) = (req.round_rng(), req.round_rng());
        assert_eq!(a.next_u64(), b.next_u64());
        let mut other = req.clone();
        other.rng_tag = 1004;
        assert_ne!(req.round_rng().next_u64(), other.round_rng().next_u64());
    }

    #[test]
    fn report_json_roundtrips() {
        let rep = SelectionReport {
            strategy: "gradmatch".into(),
            budget: 12,
            selection: Selection {
                indices: vec![5, 2, 9],
                weights: vec![1.5, 0.25, 3.0],
                grad_error: Some(0.125),
            },
            stats: RoundStats {
                stage_secs: 0.5,
                solve_secs: 1.25,
                stage_dispatches: 4,
                stage_shared: false,
                class_budgets: vec![4, 0, 8],
                fanout: true,
                engine_round: 3,
                stage_reused_buffers: true,
                retries: 2,
                quarantined: 5,
                degradation: Degradation::ReusedLastRound,
                shards: 4,
                shard_stage_secs: 0.375,
                merge_candidates: 9,
                peak_staged_rows: 64,
                sketch_width: 16,
                sketch_secs: 0.0625,
                refit_secs: 0.03125,
                cache_hit: true,
                cache_stored: true,
                cache_saved_secs: 0.75,
            },
        };
        let parsed = Json::parse(&rep.to_json().dump()).unwrap();
        let back = SelectionReport::from_json(&parsed).unwrap();
        assert_eq!(rep, back);
        // grad_error = None survives as JSON null
        let mut no_err = rep.clone();
        no_err.selection.grad_error = None;
        let parsed = Json::parse(&no_err.to_json().dump()).unwrap();
        assert_eq!(SelectionReport::from_json(&parsed).unwrap(), no_err);
    }

    #[test]
    fn report_json_without_fault_fields_parses_to_defaults() {
        // reports written before the fault-tolerance counters existed
        // must keep parsing (fault-free defaults)
        let text = r#"{
            "strategy": "gradmatch", "budget": 2,
            "selection": {"indices": [1, 2], "weights": [1.0, 1.0], "grad_error": null},
            "round": {
                "stage_secs": 0.1, "solve_secs": 0.2, "stage_dispatches": 3,
                "stage_shared": false, "class_budgets": [], "fanout": false,
                "engine_round": 0, "stage_reused_buffers": false
            }
        }"#;
        let rep = SelectionReport::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(rep.stats.retries, 0);
        assert_eq!(rep.stats.quarantined, 0);
        assert_eq!(rep.stats.degradation, Degradation::None);
        // pre-shard reports parse to the flat-path defaults too
        assert_eq!(rep.stats.shards, 0);
        assert_eq!(rep.stats.shard_stage_secs, 0.0);
        assert_eq!(rep.stats.merge_candidates, 0);
        assert_eq!(rep.stats.peak_staged_rows, 0);
        // and pre-sketch reports parse to the unsketched defaults
        assert_eq!(rep.stats.sketch_width, 0);
        assert_eq!(rep.stats.sketch_secs, 0.0);
        assert_eq!(rep.stats.refit_secs, 0.0);
        // and pre-cache reports parse to the uncached defaults
        assert!(!rep.stats.cache_hit);
        assert!(!rep.stats.cache_stored);
        assert_eq!(rep.stats.cache_saved_secs, 0.0);
    }

    #[test]
    fn degradation_wire_names_roundtrip() {
        for rung in [
            Degradation::None,
            Degradation::ReusedLastRound,
            Degradation::RandomFallback,
        ] {
            assert_eq!(Degradation::from_str(rung.as_str()).unwrap(), rung);
        }
        assert!(Degradation::from_str("panic").is_err());
    }

    #[test]
    fn pooled_engine_is_send() {
        fn assert_send<T: Send>() {}
        // the whole point of PooledEngine: par::map_tasks can carry a
        // run's engine onto a worker thread
        assert_send::<PooledEngine>();
    }

    #[test]
    fn pooled_engine_matches_selection_engine() {
        use crate::grads::SynthGrads;
        use crate::tensor::Matrix;

        let (h, c) = (3usize, 2usize);
        let p = h * c + c;
        let make = |n: usize, seed: u64| {
            let mut rng = Rng::new(seed);
            let x = Matrix::from_vec(n, 4, (0..n * 4).map(|_| rng.gaussian_f32()).collect());
            let y: Vec<i32> = (0..n).map(|i| (i % c) as i32).collect();
            Dataset { x, y, classes: c }
        };
        let train = Arc::new(make(24, 11));
        let val = Arc::new(make(8, 12));
        let mut req = SelectionRequest {
            strategy: "gradmatch".into(),
            budget: 6,
            lambda: 0.5,
            eps: 1e-10,
            is_valid: false,
            seed: 42,
            rng_tag: 1000,
            ground: (0..24).collect(),
            shards: None,
            sketch: None,
        };

        let mut borrowed = SynthGrads::new(8, p);
        let mut eng = SelectionEngine::with_oracle(&mut borrowed, &train, &val, h, c);
        let mut pooled = PooledEngine::new(
            Box::new(SynthGrads::new(8, p)),
            train.clone(),
            val.clone(),
            h,
            c,
        )
        .unwrap();

        // round 1, and a reused round 2, must match the borrowing engine
        for round in 0..2 {
            if round > 0 {
                eng.reset_round(None);
                pooled.reset_round();
                req.rng_tag = 1001;
            }
            let want = eng.select(&req).unwrap();
            let got = pooled.select(&req).unwrap();
            assert_eq!(want.selection, got.selection, "round {round} diverged");
            assert_eq!(got.stats.engine_round, round);
            assert_eq!(got.stats.degradation, Degradation::None);
        }

        // a mismatched class layout is a typed construction error
        let bad = PooledEngine::new(
            Box::new(SynthGrads::new(8, p + 1)),
            train.clone(),
            val.clone(),
            h,
            c,
        );
        assert!(bad.is_err());
    }

    #[test]
    fn ground_fingerprint_separates_sets() {
        let a = ground_fingerprint(&[1, 2, 3]);
        let b = ground_fingerprint(&[3, 2, 1]);
        let c = ground_fingerprint(&[1, 2]);
        assert_eq!(a, ground_fingerprint(&[1, 2, 3]));
        assert_ne!(a, b, "order matters — stages scatter in ground order");
        assert_ne!(a, c);
    }

    fn cache_req(tag: u64) -> SelectionRequest {
        SelectionRequest {
            strategy: "gradmatch".into(),
            budget: 4,
            lambda: 0.5,
            eps: 1e-10,
            is_valid: false,
            seed: 42,
            rng_tag: tag,
            ground: (0..16).collect(),
            shards: None,
            sketch: None,
        }
    }

    fn cache_sel(mark: usize) -> Selection {
        Selection { indices: vec![mark, mark + 1], weights: vec![1.0, 2.0], grad_error: None }
    }

    #[test]
    fn selection_cache_hit_miss_and_counters() {
        let cache = SelectionCache::new(8);
        let key = CacheKey::for_request(1, &cache_req(1000));
        assert!(cache.get(&key).is_none());
        cache.put(key.clone(), cache_sel(3), 0.5);
        let (sel, cost) = cache.get(&key).expect("stored entry must hit");
        assert_eq!(sel, cache_sel(3));
        assert_eq!(cost, 0.5);
        // a different scope is a different dataset — must miss
        assert!(cache.get(&CacheKey::for_request(2, &cache_req(1000))).is_none());
        let (depth, hits, stores, evictions) = cache.stats();
        assert_eq!((depth, hits, stores, evictions), (1, 1, 1, 0));
    }

    #[test]
    fn selection_cache_key_is_signature_sensitive() {
        let base = cache_req(1000);
        let key = CacheKey::for_request(7, &base);
        // every round-signature knob must force a distinct key
        let mut seed = base.clone();
        seed.seed = 43;
        let mut tag = base.clone();
        tag.rng_tag = 1020;
        let mut budget = base.clone();
        budget.budget = 5;
        let mut strat = base.clone();
        strat.strategy = "craig".into();
        let mut ground = base.clone();
        ground.ground = (0..15).collect();
        let mut shards = base.clone();
        shards.shards = Some(ShardPlan { shards: 2, max_staged_rows: 0 });
        let mut sketch = base.clone();
        sketch.sketch = Some(SketchPlan { width: 4, ..SketchPlan::default() });
        let mut valid = base.clone();
        valid.is_valid = true;
        for (name, req) in [
            ("seed", &seed),
            ("rng_tag", &tag),
            ("budget", &budget),
            ("strategy", &strat),
            ("ground", &ground),
            ("shards", &shards),
            ("sketch", &sketch),
            ("is_valid", &valid),
        ] {
            assert_ne!(key, CacheKey::for_request(7, req), "{name} must change the key");
        }
        // and an identical request reproduces it exactly
        assert_eq!(key, CacheKey::for_request(7, &base.clone()));
    }

    #[test]
    fn selection_cache_lru_evicts_oldest() {
        let cache = SelectionCache::new(2);
        let k = |tag: u64| CacheKey::for_request(0, &cache_req(tag));
        cache.put(k(1), cache_sel(1), 0.1);
        cache.put(k(2), cache_sel(2), 0.2);
        // touch k(1) so k(2) becomes the LRU entry
        assert!(cache.get(&k(1)).is_some());
        cache.put(k(3), cache_sel(3), 0.3);
        assert!(cache.get(&k(2)).is_none(), "LRU entry must be evicted");
        assert!(cache.get(&k(1)).is_some());
        assert!(cache.get(&k(3)).is_some());
        let (depth, _, _, evictions) = cache.stats();
        assert_eq!(depth, 2);
        assert_eq!(evictions, 1);
        // cap 0 disables storage entirely
        let off = SelectionCache::new(0);
        off.put(k(1), cache_sel(1), 0.1);
        assert!(off.get(&k(1)).is_none());
    }

    #[test]
    fn selection_cache_round_protocol() {
        let cache = SelectionCache::new(4);
        let req = cache_req(1000);
        let solved = SelectionReport {
            strategy: req.strategy.clone(),
            budget: req.budget,
            selection: cache_sel(5),
            stats: RoundStats {
                stage_secs: 0.25,
                solve_secs: 0.5,
                stage_dispatches: 2,
                ..RoundStats::default()
            },
        };
        // miss: solve runs, result is stored and marked
        let first = cache
            .round(9, &req, || Ok(solved.clone()))
            .unwrap();
        assert!(first.stats.cache_stored && !first.stats.cache_hit);
        // hit: the closure must NOT run — it would panic
        let second = cache
            .round(9, &req, || panic!("hit must not re-solve"))
            .unwrap();
        assert!(second.stats.cache_hit);
        assert_eq!(second.selection, solved.selection, "hit replays bit-identically");
        assert_eq!(second.stats.stage_dispatches, 0);
        assert_eq!(second.stats.cache_saved_secs, 0.75);
        // a degraded solve is served but never memoized
        let mut degraded = solved.clone();
        degraded.stats.degradation = Degradation::RandomFallback;
        let other = cache_req(1001);
        let served = cache.round(9, &other, || Ok(degraded.clone())).unwrap();
        assert!(!served.stats.cache_stored);
        assert!(cache
            .get(&CacheKey::for_request(9, &other))
            .is_none(), "degraded rounds must not poison the cache");
    }

    #[test]
    fn scope_fingerprint_separates_ingredients() {
        let a = scope_fingerprint("synmnist", &[256, 0]);
        assert_eq!(a, scope_fingerprint("synmnist", &[256, 0]));
        assert_ne!(a, scope_fingerprint("syncifar", &[256, 0]));
        assert_ne!(a, scope_fingerprint("synmnist", &[128, 0]));
        assert_ne!(a, scope_fingerprint("synmnist", &[256, 1]));
    }
}
