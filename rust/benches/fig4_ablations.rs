//! Figure 4 ablations (a/b: selection interval R; c: per-batch vs non-PB;
//! d: warm-start; f: κ sweep; g: λ sweep) — miniature regenerations with
//! the paper's qualitative shape checks.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;
    let mut all_ok = true;

    // --- Fig 4a/b: varying R at a 5% budget -------------------------------
    bh::section("Fig. 4a/b — varying selection interval R (5% synmnist)");
    bh::table_header(&["strategy", "R", "acc%", "total-s", "select-s"]);
    let mut r20_time = 0.0;
    let mut r2_time = 0.0;
    for r_int in [2usize, 4, 8] {
        for strat in ["gradmatch", "gradmatch-pb", "craig-pb"] {
            let mut cfg = bh::bench_config("synmnist", "lenet_s");
            cfg.budget_frac = 0.05;
            cfg.epochs = 16;
            cfg.r_interval = r_int;
            cfg.strategy = strat.into();
            let run = coord.run_one(&cfg, cfg.seed)?;
            bh::table_row(&[
                strat.into(),
                format!("{r_int}"),
                format!("{:.2}", run.test_acc * 100.0),
                format!("{:.2}", run.total_secs),
                format!("{:.2}", run.select_secs),
            ]);
            if strat == "gradmatch" && r_int == 8 {
                r20_time = run.select_secs;
            }
            if strat == "gradmatch" && r_int == 2 {
                r2_time = run.select_secs;
            }
        }
    }
    all_ok &= bh::shape_check(
        "4a: larger R spends less selection time",
        r20_time < r2_time,
    );

    // --- Fig 4c: PB vs non-PB ----------------------------------------------
    bh::section("Fig. 4c — per-batch vs per-sample variants (syncifar100)");
    bh::table_header(&["variant", "acc%", "select-s", "total-s"]);
    let mut pb_sel = 0.0;
    let mut nonpb_sel = 0.0;
    for strat in ["gradmatch", "gradmatch-pb", "craig", "craig-pb"] {
        let mut cfg = bh::bench_config("syncifar100", "resnet_s");
        cfg.budget_frac = 0.20;
        cfg.epochs = 10;
        cfg.r_interval = 5;
        cfg.strategy = strat.into();
        let run = coord.run_one(&cfg, cfg.seed)?;
        bh::table_row(&[
            strat.into(),
            format!("{:.2}", run.test_acc * 100.0),
            format!("{:.2}", run.select_secs),
            format!("{:.2}", run.total_secs),
        ]);
        match strat {
            "gradmatch" => nonpb_sel = run.select_secs,
            "gradmatch-pb" => pb_sel = run.select_secs,
            _ => {}
        }
    }
    all_ok &= bh::shape_check("4c: PB selection cheaper than non-PB", pb_sel < nonpb_sel);

    // --- Fig 4d: warm vs non-warm across budgets ---------------------------
    bh::section("Fig. 4d — warm-start effect across budgets (syncifar100)");
    bh::table_header(&["budget%", "gradmatch-pb", "gradmatch-pb-warm"]);
    let mut warm_wins = 0usize;
    let budgets = [0.05, 0.10, 0.30];
    for &b in &budgets {
        let mut accs = Vec::new();
        for strat in ["gradmatch-pb", "gradmatch-pb-warm"] {
            let mut cfg = bh::bench_config("syncifar100", "resnet_s");
            cfg.budget_frac = b;
            cfg.epochs = 12;
            cfg.r_interval = 4;
            cfg.strategy = strat.into();
            accs.push(coord.run_one(&cfg, cfg.seed)?.test_acc);
        }
        if accs[1] >= accs[0] {
            warm_wins += 1;
        }
        bh::table_row(&[
            format!("{:.0}", b * 100.0),
            format!("{:.2}", accs[0] * 100.0),
            format!("{:.2}", accs[1] * 100.0),
        ]);
    }
    all_ok &= bh::shape_check("4d: warm-start helps on most budgets", warm_wins * 2 >= budgets.len());

    // --- Fig 4f: κ sweep ----------------------------------------------------
    bh::section("Fig. 4f — warm-start fraction κ (10% syncifar100)");
    bh::table_header(&["kappa", "acc%"]);
    let mut kappa_accs = Vec::new();
    for kappa in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let mut cfg = bh::bench_config("syncifar100", "resnet_s");
        cfg.budget_frac = 0.10;
        cfg.epochs = 12;
        cfg.r_interval = 4;
        cfg.strategy = "gradmatch-pb-warm".into();
        cfg.kappa = kappa;
        let run = coord.run_one(&cfg, cfg.seed)?;
        bh::table_row(&[format!("{kappa}"), format!("{:.2}", run.test_acc * 100.0)]);
        kappa_accs.push(run.test_acc);
    }
    let mid = kappa_accs[2];
    all_ok &= bh::shape_check(
        "4f: κ=0.5 at least matches the κ=0 endpoint",
        mid >= kappa_accs[0] - 0.02,
    );

    // --- Fig 4g: λ sweep ----------------------------------------------------
    bh::section("Fig. 4g — OMP regularizer λ (10% synmnist)");
    bh::table_header(&["lambda", "acc%", "grad-err"]);
    for lambda in [0.0, 0.1, 0.5, 5.0, 50.0] {
        let mut cfg = bh::bench_config("synmnist", "lenet_s");
        cfg.budget_frac = 0.10;
        cfg.epochs = 12;
        cfg.r_interval = 4;
        cfg.strategy = "gradmatch".into();
        cfg.lambda = lambda;
        let run = coord.run_one(&cfg, cfg.seed)?;
        bh::table_row(&[
            format!("{lambda}"),
            format!("{:.2}", run.test_acc * 100.0),
            run.mean_grad_error
                .map(|e| format!("{e:.4}"))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("\nfig4_ablations: {}", if all_ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
