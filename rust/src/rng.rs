//! Deterministic PRNG substrate (the `rand` crate is not in the offline
//! vendor set, so the project carries its own).
//!
//! [`Rng`] is xoshiro256\*\* seeded through SplitMix64 — the standard
//! recommendation for seeding xoshiro state.  Everything the coordinator
//! randomizes (dataset synthesis, shuffling, subset sampling, stochastic
//! greedy) flows through this type so runs are reproducible from a single
//! `u64` seed, and independent components get decorrelated streams via
//! [`Rng::split`].

/// SplitMix64 step — used for seeding and stream splitting.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256\*\* generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second gaussian from Box–Muller
    spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed; any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream for a named sub-component.
    ///
    /// Streams derived with different tags (or from different parents) are
    /// decorrelated; deriving twice with the same tag gives the same stream,
    /// which keeps e.g. data synthesis stable across strategy runs.
    pub fn split(&self, tag: u64) -> Rng {
        let mut sm = self.s[0]
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(tag)
            .wrapping_add(self.s[2].rotate_left(17));
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick: bias is negligible for simulation workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (caches the paired deviate).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Standard normal as `f32`.
    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Bernoulli draw.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn split_streams_are_stable_and_distinct() {
        let root = Rng::new(7);
        let mut a1 = root.split(1);
        let mut a2 = root.split(1);
        let mut b = root.split(2);
        assert_eq!(a1.next_u64(), a2.next_u64());
        assert_ne!(a1.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.usize(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn sample_indices_full_set() {
        let mut r = Rng::new(9);
        let mut s = r.sample_indices(10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn bool_probability_extremes() {
        let mut r = Rng::new(10);
        assert!(!(0..100).any(|_| r.bool(0.0)));
        assert!((0..100).all(|_| r.bool(1.0)));
    }
}
