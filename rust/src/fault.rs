//! Fault injection for the gradient-oracle seam: a deterministic,
//! seeded [`FaultyOracle`] wrapper over any [`GradOracle`] that injects
//! transient dispatch errors, non-finite gradient rows, and latency
//! spikes on a configurable schedule.
//!
//! This is the test substrate for the fault-tolerance layer: the retry
//! policy at the chunk-dispatch seam ([`crate::grads::Retrying`]), the
//! staging quarantine of non-finite rows
//! ([`crate::grads::stage_class_grads_reusing`]), and the engine's
//! degradation ladder ([`crate::engine::Degradation`]).  Everything is
//! driven by the plan's seed and per-attempt counters, so a given
//! `(plan, workload)` pair replays the exact same fault sequence on
//! every run — tests can pin subset identity across clean and faulty
//! runs instead of asserting statistics.
//!
//! With [`FaultPlan::none`] the wrapper is bit-for-bit transparent: no
//! RNG draws, no sleeps, and every call forwarded unchanged (pinned by
//! `tests/fault_injection.rs` and the conformance suite).  Injected
//! dispatch failures fire *before* the inner oracle runs, so a
//! retry-then-success sequence leaves the inner oracle's dispatch
//! counters identical to a fault-free run.

use anyhow::{anyhow, Result};

use crate::data::PaddedChunk;
use crate::grads::{EvalEntries, GradOracle};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Schedule of injected faults.  All channels are independent and off by
/// default (`FaultPlan::none`); rates are per dispatch attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// seeds the per-attempt fault draws (failure and corruption streams
    /// are split independently, so toggling one never shifts the other)
    pub seed: u64,
    /// probabilistic transient-failure rate in `[0, 1]` per attempt
    pub dispatch_fail: f64,
    /// deterministic schedule: fail every k-th attempt (0 = off) —
    /// guarantees a retried attempt succeeds, which is how the "10%
    /// dispatch failures, zero degradation" contract stays flake-free
    pub fail_every: usize,
    /// deterministic hard outage: fail every attempt numbered
    /// `>= fail_from` (0 = off) — models an accelerator that dies
    /// mid-run, which is what forces the degradation ladder past the
    /// retry policy
    pub fail_from: u64,
    /// probabilistic rate in `[0, 1]` of corrupting one live row of a
    /// `grads_chunk` result with NaN/Inf
    pub nan_rate: f64,
    /// latency spike every k-th attempt (0 = off)
    pub spike_every: usize,
    /// spike duration in milliseconds
    pub spike_ms: u64,
}

impl FaultPlan {
    /// A plan that injects nothing — the transparent baseline.
    pub fn none(seed: u64) -> Self {
        FaultPlan {
            seed,
            dispatch_fail: 0.0,
            fail_every: 0,
            fail_from: 0,
            nan_rate: 0.0,
            spike_every: 0,
            spike_ms: 0,
        }
    }

    /// Parse a `key=value,key=value` spec (the `serve --fault-plan` flag).
    ///
    /// Keys mirror the struct fields: `seed`, `dispatch_fail`, `fail_every`,
    /// `fail_from`, `nan_rate`, `spike_every`, `spike_ms`.  Unset keys keep
    /// the [`FaultPlan::none`] defaults (seed 0); an unknown key or an
    /// unparsable value is an error naming the offending pair.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::none(0);
        for pair in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = pair
                .split_once('=')
                .ok_or_else(|| anyhow!("fault-plan entry '{pair}' is not key=value"))?;
            let (key, val) = (key.trim(), val.trim());
            let bad = || anyhow!("fault-plan entry '{pair}': bad value");
            match key {
                "seed" => plan.seed = val.parse().map_err(|_| bad())?,
                "dispatch_fail" => plan.dispatch_fail = val.parse().map_err(|_| bad())?,
                "fail_every" => plan.fail_every = val.parse().map_err(|_| bad())?,
                "fail_from" => plan.fail_from = val.parse().map_err(|_| bad())?,
                "nan_rate" => plan.nan_rate = val.parse().map_err(|_| bad())?,
                "spike_every" => plan.spike_every = val.parse().map_err(|_| bad())?,
                "spike_ms" => plan.spike_ms = val.parse().map_err(|_| bad())?,
                _ => {
                    return Err(anyhow!(
                        "fault-plan entry '{pair}': unknown key (expected seed, \
                         dispatch_fail, fail_every, fail_from, nan_rate, \
                         spike_every, spike_ms)"
                    ))
                }
            }
            for rate in [plan.dispatch_fail, plan.nan_rate] {
                if !(0.0..=1.0).contains(&rate) {
                    return Err(anyhow!("fault-plan entry '{pair}': rate outside [0, 1]"));
                }
            }
        }
        Ok(plan)
    }
}

/// A [`GradOracle`] decorator that injects the faults of a [`FaultPlan`].
///
/// `plan` is public so a test can re-arm the schedule between rounds
/// (e.g. clean round one, then `plan.dispatch_fail = 1.0` to force the
/// degradation ladder).  The `injected_*` counters and [`poisoned_rows`]
/// ledger let assertions tie observed behavior (retries, quarantined
/// counts, never-selected indices) back to exactly what was injected.
///
/// Generic over the inner oracle (`T: GradOracle`), so it wraps a borrowed
/// oracle in tests (`&mut SynthGrads`, via the `&mut T` blanket impl) or an
/// owned one in the daemon's per-run pool (`FaultyOracle<SynthGrads>` boxed
/// as `Box<dyn GradOracle + Send>`).
///
/// [`poisoned_rows`]: FaultyOracle::poisoned_rows
pub struct FaultyOracle<T: GradOracle> {
    inner: T,
    pub plan: FaultPlan,
    /// dispatch attempts observed (drives the deterministic schedules)
    pub attempts: u64,
    /// transient failures returned instead of dispatching
    pub injected_failures: usize,
    /// `grads_chunk` rows corrupted with non-finite values
    pub injected_nan_rows: usize,
    /// latency spikes slept through
    pub injected_spikes: usize,
    /// dataset row index of every corrupted gradient row, in injection
    /// order — the quarantine tests' ground truth
    pub poisoned_rows: Vec<usize>,
}

impl<T: GradOracle> FaultyOracle<T> {
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        FaultyOracle {
            inner,
            plan,
            attempts: 0,
            injected_failures: 0,
            injected_nan_rows: 0,
            injected_spikes: 0,
            poisoned_rows: Vec::new(),
        }
    }

    /// Per-attempt gate: spike, then maybe fail *before* the inner
    /// dispatch (so inner counters only ever count successes).
    fn gate(&mut self, what: &str) -> Result<()> {
        self.attempts += 1;
        if self.plan.spike_every > 0 && self.attempts % self.plan.spike_every as u64 == 0 {
            self.injected_spikes += 1;
            std::thread::sleep(std::time::Duration::from_millis(self.plan.spike_ms));
        }
        let scheduled =
            self.plan.fail_every > 0 && self.attempts % self.plan.fail_every as u64 == 0;
        let outage = self.plan.fail_from > 0 && self.attempts >= self.plan.fail_from;
        let drawn = self.plan.dispatch_fail > 0.0
            && Rng::new(self.plan.seed ^ 0xD15F).split(self.attempts).f64()
                < self.plan.dispatch_fail;
        if scheduled || outage || drawn {
            self.injected_failures += 1;
            return Err(anyhow!(
                "injected transient fault: {what} attempt {}",
                self.attempts
            ));
        }
        Ok(())
    }

    /// Corrupt one live row of a successful `grads_chunk` result with
    /// NaN/Inf, recording which dataset row was poisoned.
    fn maybe_poison(&mut self, chunk: &PaddedChunk, gm: &mut Matrix) {
        if self.plan.nan_rate <= 0.0 || chunk.live == 0 {
            return;
        }
        let mut rng = Rng::new(self.plan.seed ^ 0x4EAF).split(self.attempts);
        if rng.f64() >= self.plan.nan_rate {
            return;
        }
        let slot = rng.usize(chunk.live);
        let row = gm.row_mut(slot);
        row[0] = f32::NAN;
        let last = row.len() - 1;
        row[last] = f32::INFINITY;
        self.injected_nan_rows += 1;
        self.poisoned_rows.push(chunk.indices[slot]);
    }
}

impl<T: GradOracle> GradOracle for FaultyOracle<T> {
    fn chunk_rows(&self) -> usize {
        self.inner.chunk_rows()
    }

    fn p(&self) -> usize {
        self.inner.p()
    }

    fn batch_rows(&self) -> usize {
        self.inner.batch_rows()
    }

    fn grads_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        self.gate("grads_chunk")?;
        let mut gm = self.inner.grads_chunk(chunk)?;
        self.maybe_poison(chunk, &mut gm);
        Ok(gm)
    }

    fn mean_grad_chunk(&mut self, chunk: &PaddedChunk) -> Result<Vec<f32>> {
        self.gate("mean_grad_chunk")?;
        self.inner.mean_grad_chunk(chunk)
    }

    fn batch_gradsum_chunk(&mut self, chunk: &PaddedChunk) -> Result<Matrix> {
        self.gate("batch_gradsum_chunk")?;
        self.inner.batch_gradsum_chunk(chunk)
    }

    fn eval_chunk(&mut self, chunk: &PaddedChunk) -> Result<EvalEntries> {
        self.gate("eval_chunk")?;
        self.inner.eval_chunk(chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{padded_chunks, Dataset};
    use crate::grads::SynthGrads;

    /// Tiny synthetic dataset with the given class labels.
    fn toy_dataset(d: usize, y: Vec<i32>, classes: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let n = y.len();
        let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
        Dataset { x, y, classes }
    }

    fn chunks(ds: &crate::data::Dataset, rows: usize) -> Vec<PaddedChunk> {
        let idx: Vec<usize> = (0..ds.y.len()).collect();
        padded_chunks(ds, &idx, rows).collect()
    }

    #[test]
    fn no_fault_plan_is_transparent() {
        let p = 9;
        let ds = toy_dataset(4, vec![0, 1, 2, 0, 1, 2, 0, 1], 3, 31);
        let mut clean = SynthGrads::new(4, p);
        let mut inner = SynthGrads::new(4, p);
        let mut faulty = FaultyOracle::new(&mut inner, FaultPlan::none(5));
        for chunk in chunks(&ds, 4) {
            let a = clean.grads_chunk(&chunk).unwrap();
            let b = faulty.grads_chunk(&chunk).unwrap();
            assert_eq!(a.data, b.data, "zero-fault wrapper must be bit-for-bit");
            assert_eq!(clean.mean_grad_chunk(&chunk).unwrap(), faulty.mean_grad_chunk(&chunk).unwrap());
        }
        assert_eq!(faulty.injected_failures, 0);
        assert_eq!(faulty.injected_nan_rows, 0);
        assert_eq!(inner.grad_calls, clean.grad_calls);
    }

    #[test]
    fn scheduled_failures_fire_before_the_inner_dispatch() {
        let p = 9;
        let ds = toy_dataset(4, vec![0, 1, 2, 0], 3, 32);
        let mut inner = SynthGrads::new(4, p);
        let mut plan = FaultPlan::none(5);
        plan.fail_every = 2; // attempts 2, 4, … fail
        let mut faulty = FaultyOracle::new(&mut inner, plan);
        let chunk = &chunks(&ds, 4)[0];
        assert!(faulty.grads_chunk(chunk).is_ok());
        assert!(faulty.grads_chunk(chunk).is_err());
        assert!(faulty.grads_chunk(chunk).is_ok());
        assert_eq!(faulty.injected_failures, 1);
        assert_eq!(inner.grad_calls, 2, "failed attempts never reach the inner oracle");
    }

    #[test]
    fn hard_outage_fails_every_attempt_from_the_cutoff() {
        let p = 9;
        let ds = toy_dataset(4, vec![0, 1, 2, 0], 3, 34);
        let mut inner = SynthGrads::new(4, p);
        let mut plan = FaultPlan::none(5);
        plan.fail_from = 3; // attempts 3, 4, … all fail — the dead accelerator
        let mut faulty = FaultyOracle::new(&mut inner, plan);
        let chunk = &chunks(&ds, 4)[0];
        assert!(faulty.grads_chunk(chunk).is_ok());
        assert!(faulty.grads_chunk(chunk).is_ok());
        assert!(faulty.grads_chunk(chunk).is_err());
        assert!(faulty.grads_chunk(chunk).is_err());
        assert_eq!(inner.grad_calls, 2, "the outage never reaches the inner oracle");
    }

    #[test]
    fn plan_parse_roundtrip_and_errors() {
        let p = FaultPlan::parse(
            "seed=7, dispatch_fail=0.1, fail_every=4, fail_from=9, nan_rate=0.5, \
             spike_every=3, spike_ms=20",
        )
        .unwrap();
        assert_eq!(
            p,
            FaultPlan {
                seed: 7,
                dispatch_fail: 0.1,
                fail_every: 4,
                fail_from: 9,
                nan_rate: 0.5,
                spike_every: 3,
                spike_ms: 20,
            }
        );
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::none(0));
        assert_eq!(FaultPlan::parse("seed=3").unwrap(), FaultPlan::none(3));
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("seed").is_err());
        assert!(FaultPlan::parse("dispatch_fail=nope").is_err());
        assert!(FaultPlan::parse("nan_rate=1.5").is_err());
    }

    #[test]
    fn wraps_owned_oracles_too() {
        // the daemon boxes an owned FaultyOracle<SynthGrads> behind the
        // GradOracle seam — pin that the owned form injects identically
        let p = 9;
        let ds = toy_dataset(4, vec![0, 1, 2, 0], 3, 35);
        let mut plan = FaultPlan::none(5);
        plan.fail_every = 2;
        let mut owned: Box<dyn GradOracle + Send> =
            Box::new(FaultyOracle::new(SynthGrads::new(4, p), plan));
        let chunk = &chunks(&ds, 4)[0];
        assert!(owned.grads_chunk(chunk).is_ok());
        assert!(owned.grads_chunk(chunk).is_err());
        assert!(owned.grads_chunk(chunk).is_ok());
    }

    #[test]
    fn nan_injection_is_recorded_and_deterministic() {
        let p = 9;
        let ds = toy_dataset(4, vec![0, 1, 2, 0, 1, 2, 0, 1], 3, 33);
        let mut run = |seed: u64| {
            let mut inner = SynthGrads::new(4, p);
            let mut plan = FaultPlan::none(seed);
            plan.nan_rate = 1.0;
            let mut faulty = FaultyOracle::new(&mut inner, plan);
            let mut poisoned_values = Vec::new();
            for chunk in chunks(&ds, 4) {
                let gm = faulty.grads_chunk(&chunk).unwrap();
                for slot in 0..chunk.live {
                    if !gm.row(slot).iter().all(|v| v.is_finite()) {
                        poisoned_values.push(chunk.indices[slot]);
                    }
                }
            }
            (poisoned_values, faulty.poisoned_rows.clone(), faulty.injected_nan_rows)
        };
        let (observed, ledger, count) = run(5);
        assert_eq!(observed, ledger, "ledger must name exactly the corrupted rows");
        assert_eq!(count, 2, "nan_rate=1.0 corrupts one row per dispatch");
        let (again, ledger2, _) = run(5);
        assert_eq!(observed, again, "same seed → same fault sequence");
        assert_eq!(ledger, ledger2);
    }
}
