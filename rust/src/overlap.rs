//! Overlapped selection: run data selection in a background worker so the
//! training loop never stalls on a selection round.
//!
//! The paper amortizes selection cost by selecting only every `R` epochs;
//! this module removes it from the critical path entirely — the trainer
//! keeps stepping on the *stale* subset while the worker computes the next
//! one against a parameter snapshot, and swaps it in when ready (a
//! double-buffered subset).  On a multi-core box this hides the full
//! selection latency; on one core it still bounds tail latency per epoch.
//!
//! The worker is a [`SelectionEngine`] client: it holds one
//! [`SelectionRequest`] template (strategy spec, budget, λ/ε, ground set,
//! seed) and ONE engine for its lifetime — each submission
//! `reset_round`s the engine with the parameter snapshot it carries
//! (staging buffers recycle across rounds) — and ships the full
//! [`SelectionReport`] back, so overlapped rounds carry the same
//! staging/solve observability and engine-reuse counters as synchronous
//! ones.  The worker owns
//! its **own** PJRT runtime (the xla client handles are not `Send`, and
//! executables are compiled per thread) plus clones of the train/val
//! splits; only parameter snapshots ([`ModelState`], plain host buffers)
//! and reports cross the channels.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::data::Dataset;
use crate::engine::{SelectionEngine, SelectionReport, SelectionRequest};
use crate::runtime::{ModelState, Runtime};
use crate::selection::parse_strategy;

/// A queued round: parameter snapshot + the tag that seeds the per-round
/// RNG (so overlapped and synchronous runs draw the same shuffles for a
/// given epoch — both derive through
/// [`SelectionRequest::round_rng`]).
pub struct SelectRequest {
    pub state: ModelState,
    pub rng_tag: u64,
}

/// Background selection worker.
pub struct AsyncSelector {
    req_tx: Option<Sender<SelectRequest>>,
    res_rx: Receiver<Result<SelectionReport>>,
    handle: Option<JoinHandle<()>>,
    /// requests in flight (0 or 1 — the trainer never stacks requests)
    pub inflight: usize,
}

/// Static configuration the worker needs to serve rounds.
#[derive(Clone)]
pub struct SelectorConfig {
    pub artifacts_dir: String,
    /// round-request template (strategy/budget/λ/ε/ground/seed); the
    /// worker stamps `rng_tag` per submission
    pub request: SelectionRequest,
}

impl AsyncSelector {
    /// Spawn the worker with its own runtime + dataset copies.
    pub fn spawn(cfg: SelectorConfig, train: Dataset, val: Dataset) -> Result<AsyncSelector> {
        let (req_tx, req_rx) = channel::<SelectRequest>();
        let (res_tx, res_rx) = channel::<Result<SelectionReport>>();
        let handle = std::thread::Builder::new()
            .name("gradmatch-selector".into())
            .spawn(move || {
                // own runtime + strategy; failures are reported per request
                let rt = match Runtime::load(&cfg.artifacts_dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = res_tx.send(Err(anyhow!("selector runtime: {e}")));
                        return;
                    }
                };
                let batch = rt
                    .manifest
                    .models
                    .values()
                    .next()
                    .map(|m| m.batch)
                    .unwrap_or(128);
                // one strategy instance for the worker's lifetime, so
                // stateful baselines keep their cross-round memory
                let mut strategy = match parse_strategy(&cfg.request.strategy, batch) {
                    Ok((s, _)) => s,
                    Err(e) => {
                        let _ = res_tx.send(Err(e));
                        return;
                    }
                };
                // ONE engine for the worker's lifetime: each submission
                // resets the round (recycling staging buffers) and
                // installs the snapshot it carries
                let mut engine: Option<SelectionEngine<'_>> = None;
                while let Ok(req) = req_rx.recv() {
                    let mut round = cfg.request.clone();
                    round.rng_tag = req.rng_tag;
                    if engine.is_none() {
                        engine = Some(SelectionEngine::new(&rt, req.state, &train, &val));
                    } else {
                        engine.as_mut().unwrap().reset_round(Some(req.state));
                    }
                    let out = engine.as_ref().unwrap().select_with(strategy.as_mut(), &round);
                    if res_tx.send(out).is_err() {
                        break; // trainer gone
                    }
                }
            })
            .map_err(|e| anyhow!("spawning selector thread: {e}"))?;
        Ok(AsyncSelector {
            req_tx: Some(req_tx),
            res_rx,
            handle: Some(handle),
            inflight: 0,
        })
    }

    /// Submit a snapshot for selection (non-blocking). At most one request
    /// should be in flight; the trainer checks `inflight` first.  A shut
    /// down or dead worker is an `Err`, never a panic — the trainer
    /// logs it and falls back to synchronous rounds.
    pub fn request(&mut self, state: ModelState, rng_tag: u64) -> Result<()> {
        self.req_tx
            .as_ref()
            .ok_or_else(|| anyhow!("selector shut down"))?
            .send(SelectRequest { state, rng_tag })
            .map_err(|_| anyhow!("selector thread died"))?;
        self.inflight += 1;
        Ok(())
    }

    /// Non-blocking poll for a finished round.
    pub fn try_recv(&mut self) -> Result<Option<SelectionReport>> {
        match self.res_rx.try_recv() {
            Ok(res) => {
                self.inflight = self.inflight.saturating_sub(1);
                res.map(Some)
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(anyhow!("selector thread died")),
        }
    }

    /// Blocking wait for a finished round.
    pub fn recv(&mut self) -> Result<SelectionReport> {
        let res = self
            .res_rx
            .recv()
            .map_err(|_| anyhow!("selector thread died"))?;
        self.inflight = self.inflight.saturating_sub(1);
        res
    }
}

impl Drop for AsyncSelector {
    fn drop(&mut self) {
        // closing the request channel lets the worker loop exit
        self.req_tx.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
