//! Shared fixtures for the integration tests.

use gradmatch::data::{DatasetCard, Splits};
use gradmatch::runtime::Runtime;

/// Artifact dir for tests — honors `GRADMATCH_ARTIFACTS`.
pub fn artifacts_dir() -> String {
    std::env::var("GRADMATCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

/// Shared runtime (compiling executables once per test binary).
pub fn runtime() -> Runtime {
    Runtime::load(artifacts_dir()).expect("artifacts missing — run `make artifacts`")
}

/// Small lenet_s-compatible dataset (784-dim) for fast integration runs.
pub fn tiny_mnist(n: usize) -> Splits {
    let card = DatasetCard::by_name("synmnist").unwrap();
    card.generate(7, n)
}

pub fn assert_close(a: f32, b: f32, tol: f32, what: &str) {
    assert!(
        (a - b).abs() <= tol,
        "{what}: {a} vs {b} (tol {tol})"
    );
}
