//! Daemon stress bench — many concurrent clients against the
//! selection-as-a-service daemon (`gradmatch serve`), including the
//! adversarial ones, reporting throughput and tail latency into the perf
//! trajectory (`BENCH_daemon.json`):
//!
//! - **throughput**: 6 tenants with mixed strategies hammering rounds
//!   back-to-back — rounds/sec, p50/p99 round latency
//! - **adversarial**: well-formed tenants racing hostile-corpus clients,
//!   oversized requests, mid-round disconnectors and a stalled writer
//!   against a deliberately small queue — shed rate, success rate, and
//!   the p99 the well-formed clients still see
//! - **fault plan**: scheduled dispatch failures + NaN corruption under
//!   every engine — rounds must still serve (retry/quarantine/ladder),
//!   with the fault counters surfaced end-to-end
//!
//! All daemons bind ephemeral unix sockets; nothing here needs artifacts
//! or a device.

use std::time::{Duration, Instant};

use gradmatch::bench_harness as bh;
use gradmatch::engine::SelectionRequest;
use gradmatch::fault::FaultPlan;
use gradmatch::jsonlite::{hostile_corpus, Json};
use gradmatch::server::{
    ephemeral_socket_path, serve, Bind, DaemonClient, DaemonStats, SelectSpec, ServeOpts,
};

fn spec(run_id: &str, strategy: &str, rng_tag: u64) -> SelectSpec {
    let mut s = SelectSpec::new(
        run_id,
        SelectionRequest {
            strategy: strategy.to_string(),
            budget: 16,
            lambda: 0.5,
            eps: 1e-10,
            is_valid: false,
            seed: 42,
            rng_tag,
            ground: (0..128).collect(),
            shards: None,
            sketch: None,
        },
    );
    s.n_train = 128;
    s.chunk = 32;
    s.h = 4;
    s
}

fn start(
    tag: &str,
    mut f: impl FnMut(&mut ServeOpts),
) -> (std::thread::JoinHandle<anyhow::Result<DaemonStats>>, Bind) {
    let bind = Bind::Unix(ephemeral_socket_path(tag));
    let mut opts = ServeOpts::new(bind.clone());
    f(&mut opts);
    let handle = std::thread::spawn(move || serve(opts));
    (handle, bind)
}

fn connect(bind: &Bind) -> DaemonClient {
    DaemonClient::connect_retry(bind, Duration::from_secs(10)).expect("daemon up")
}

fn rtype(j: &Json) -> &str {
    j.get("type").and_then(Json::as_str).unwrap_or("<none>")
}

const STRATEGIES: [&str; 4] = ["gradmatch", "gradmatch-pb", "craig", "random"];

fn main() {
    let mut rep = bh::BenchReport::new("daemon_stress");
    let mut all_ok = true;

    // -- phase 1: clean throughput -----------------------------------------
    bh::section("daemon stress — throughput (6 tenants, mixed strategies)");
    let (daemon, bind) = start("stress-throughput", |o| {
        o.engine_cap = 4; // < tenants: the LRU eviction path runs hot
    });
    connect(&bind).ping().unwrap();
    const TENANTS: usize = 6;
    const ROUNDS: usize = 8;
    let wall = Instant::now();
    let mut clients = Vec::new();
    for t in 0..TENANTS {
        let bind = bind.clone();
        clients.push(std::thread::spawn(move || {
            let mut client = connect(&bind);
            let run = format!("tenant-{t}");
            let strategy = STRATEGIES[t % STRATEGIES.len()];
            let mut lat = Vec::with_capacity(ROUNDS);
            for r in 0..ROUNDS {
                let t0 = Instant::now();
                let resp = client.select(&spec(&run, strategy, 1000 + r as u64)).unwrap();
                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                assert_eq!(rtype(&resp), "report", "{}", resp.dump());
            }
            lat
        }));
    }
    let mut lat_ms: Vec<f64> = Vec::new();
    for c in clients {
        lat_ms.extend(c.join().unwrap());
    }
    let wall_s = wall.elapsed().as_secs_f64();
    let total = (TENANTS * ROUNDS) as f64;
    let rps = total / wall_s;
    let p50 = bh::percentile(&lat_ms, 0.50);
    let p99 = bh::percentile(&lat_ms, 0.99);
    println!(
        "  {total:.0} rounds in {wall_s:.2}s — {rps:.1} rounds/sec, p50 {p50:.2}ms, p99 {p99:.2}ms"
    );
    connect(&bind).shutdown().unwrap();
    let snap = daemon.join().unwrap().unwrap();
    all_ok &= bh::shape_check(
        "throughput: every round served, none shed",
        snap.rounds_served == TENANTS as u64 * ROUNDS as u64 && snap.shed_overloaded == 0,
    );
    all_ok &= bh::shape_check(
        "throughput: engine pool evicted under cap pressure",
        snap.engines_evicted > 0 && snap.engines_pooled <= 4,
    );
    rep.note("daemon/rounds_per_sec", rps);
    rep.note("daemon/p50_ms", p50);
    rep.note("daemon/p99_ms", p99);
    rep.note("daemon/engines_built", snap.engines_built as f64);
    rep.note("daemon/engines_evicted", snap.engines_evicted as f64);

    // -- phase 2: adversarial mix ------------------------------------------
    bh::section("daemon stress — adversarial mix (small queue, hostile clients)");
    let (daemon, bind) = start("stress-adversarial", |o| {
        let mut plan = FaultPlan::none(3);
        plan.spike_every = 1;
        plan.spike_ms = 30; // slow the rounds so the tiny queue overflows
        o.fault_plan = Some(plan);
        o.queue_cap = 4;
        o.max_request_bytes = 2048;
        o.read_timeout_ms = 500; // shed stalled writers fast
    });
    connect(&bind).ping().unwrap();
    let wall = Instant::now();
    let mut adversaries = Vec::new();
    // hostile-corpus clients: every line must come back a typed error
    for _ in 0..2 {
        let bind = bind.clone();
        adversaries.push(std::thread::spawn(move || {
            let mut client = connect(&bind);
            for line in hostile_corpus() {
                // blanks get no reply; lines past this daemon's 2048-byte
                // cap would (correctly) close the connection — the
                // dedicated oversized clients cover that path
                if line.trim().is_empty() || line.len() > 1024 {
                    continue;
                }
                client.send_raw(&line).unwrap();
                let resp = client.recv().unwrap();
                assert_eq!(rtype(&resp), "error", "{line:?} → {}", resp.dump());
            }
        }));
    }
    // oversized clients: one fat line, typed reject, connection dropped
    for _ in 0..2 {
        let bind = bind.clone();
        adversaries.push(std::thread::spawn(move || {
            let mut client = connect(&bind);
            let fat = format!("{{\"pad\":\"{}\"}}", "x".repeat(4096));
            client.send_raw(&fat).unwrap();
            let resp = client.recv().unwrap();
            assert_eq!(rtype(&resp), "error");
        }));
    }
    // mid-round disconnectors: submit a real round, vanish
    for i in 0..2 {
        let bind = bind.clone();
        adversaries.push(std::thread::spawn(move || {
            let mut client = connect(&bind);
            client.send(&spec("vanisher", "gradmatch", i).to_json()).unwrap();
        }));
    }
    // a stalled writer: half a request, then silence — the read timeout
    // must shed it instead of pinning a handler forever
    {
        let bind = bind.clone();
        adversaries.push(std::thread::spawn(move || {
            let mut client = connect(&bind);
            client.send_raw("{\"type\":\"sel").ok(); // no newline follows
            std::thread::sleep(Duration::from_millis(900));
        }));
    }
    // the well-formed tenants, racing all of the above
    const GOOD: usize = 6;
    const GOOD_ROUNDS: usize = 6;
    let mut good = Vec::new();
    for t in 0..GOOD {
        let bind = bind.clone();
        good.push(std::thread::spawn(move || {
            let mut client = connect(&bind);
            let run = format!("good-{t}");
            let strategy = STRATEGIES[t % STRATEGIES.len()];
            let mut served: Vec<f64> = Vec::new();
            let mut shed = 0usize;
            for r in 0..GOOD_ROUNDS {
                let t0 = Instant::now();
                let resp = client.select(&spec(&run, strategy, 500 + r as u64)).unwrap();
                match rtype(&resp) {
                    "report" => served.push(t0.elapsed().as_secs_f64() * 1e3),
                    "error" => {
                        assert_eq!(
                            resp.get("code").and_then(Json::as_str),
                            Some("overloaded"),
                            "only backpressure may reject a well-formed round: {}",
                            resp.dump()
                        );
                        shed += 1;
                        std::thread::sleep(Duration::from_millis(25)); // back off
                    }
                    other => panic!("unexpected '{other}': {}", resp.dump()),
                }
            }
            (served, shed)
        }));
    }
    let mut served_ms: Vec<f64> = Vec::new();
    let mut shed_total = 0usize;
    for g in good {
        let (served, shed) = g.join().unwrap();
        served_ms.extend(served);
        shed_total += shed;
    }
    for a in adversaries {
        a.join().unwrap();
    }
    let wall_s = wall.elapsed().as_secs_f64();
    let attempts = (GOOD * GOOD_ROUNDS) as f64;
    let shed_rate = shed_total as f64 / attempts;
    let p99_adv = bh::percentile(&served_ms, 0.99);
    println!(
        "  {attempts:.0} well-formed rounds in {wall_s:.2}s under abuse — {} served, {} shed ({:.0}% shed rate), p99 {p99_adv:.2}ms",
        served_ms.len(),
        shed_total,
        shed_rate * 100.0
    );
    // the daemon must still be healthy after the storm
    let mut survivor = connect(&bind);
    all_ok &= bh::shape_check("adversarial: daemon answers after the storm", {
        let resp = survivor.select(&spec("survivor", "gradmatch", 9)).unwrap();
        rtype(&resp) == "report"
    });
    survivor.shutdown().unwrap();
    let snap = daemon.join().unwrap().unwrap();
    all_ok &= bh::shape_check(
        "adversarial: every well-formed attempt got a typed answer",
        served_ms.len() + shed_total == attempts as usize,
    );
    all_ok &= bh::shape_check(
        "adversarial: hostile lines were rejected, not served",
        snap.bad_requests > 40 && snap.oversized >= 2,
    );
    all_ok &= bh::shape_check(
        "adversarial: the stalled writer was shed by the read timeout",
        snap.read_timeouts >= 1,
    );
    rep.note("daemon/shed_rate", shed_rate);
    rep.note("daemon/adversarial_p99_ms", p99_adv);
    rep.note("daemon/adversarial_served", served_ms.len() as f64);
    rep.note("daemon/adversarial_bad_requests", snap.bad_requests as f64);
    rep.note("daemon/adversarial_oversized", snap.oversized as f64);
    rep.note("daemon/adversarial_read_timeouts", snap.read_timeouts as f64);

    // -- phase 3: fault plan under every engine ----------------------------
    bh::section("daemon stress — fault plan (scheduled failures + NaN rows)");
    let (daemon, bind) = start("stress-faults", |o| {
        let mut plan = FaultPlan::none(9);
        plan.fail_every = 3; // every 3rd dispatch fails (retry succeeds)
        plan.nan_rate = 0.2; // corrupted rows must be quarantined
        o.fault_plan = Some(plan);
    });
    let mut client = connect(&bind);
    let mut retries = 0u64;
    let mut quarantined = 0u64;
    for run in ["faulty-a", "faulty-b"] {
        for r in 0..4u64 {
            let resp = client.select(&spec(run, "gradmatch", 700 + r)).unwrap();
            assert_eq!(rtype(&resp), "report", "{}", resp.dump());
            retries += resp
                .path(&["report", "round", "retries"])
                .and_then(Json::as_usize)
                .unwrap_or(0) as u64;
            quarantined += resp
                .path(&["report", "round", "quarantined"])
                .and_then(Json::as_usize)
                .unwrap_or(0) as u64;
        }
    }
    client.shutdown().unwrap();
    let snap = daemon.join().unwrap().unwrap();
    println!(
        "  8 rounds under faults — {retries} retried dispatches, {quarantined} quarantined rows, degradation [none {} / reused {} / random {}]",
        snap.degradation[0], snap.degradation[1], snap.degradation[2]
    );
    all_ok &= bh::shape_check("faults: all rounds served", snap.rounds_served == 8);
    all_ok &= bh::shape_check("faults: the retry path actually ran", retries > 0);
    all_ok &= bh::shape_check(
        "faults: daemon counters mirror the reports",
        snap.retries == retries && snap.quarantined == quarantined,
    );
    rep.note("daemon/fault_retries", retries as f64);
    rep.note("daemon/fault_quarantined", quarantined as f64);
    rep.note(
        "daemon/fault_degraded_rounds",
        (snap.degradation[1] + snap.degradation[2]) as f64,
    );
    rep.note("daemon/all_shape_checks", if all_ok { 1.0 } else { 0.0 });

    rep.write(&bh::bench_out_path("BENCH_daemon.json")).unwrap();
    if !all_ok {
        eprintln!("daemon_stress: shape checks FAILED");
        std::process::exit(1);
    }
}
