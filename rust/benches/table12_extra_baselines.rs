//! Table 12 + Figure 3l: the extra baselines (feature-space facility
//! location, entropy/uncertainty, forgetting events) vs GRAD-MATCH-PB-WARM
//! at a 30% budget, plus the "smaller models" comparison — full training
//! on a narrow proxy model vs GRAD-MATCH on the big one.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;
    let mut ok = true;

    for (ds, model) in [("syncifar10", "resnet_s"), ("syncifar100", "resnet_s")] {
        bh::section(&format!("Table 12 — extra baselines at 30%, {ds}"));
        bh::table_header(&["strategy", "acc%", "total-s"]);
        let mut accs = std::collections::HashMap::new();
        for strat in ["featurefl", "entropy", "forgetting", "random", "gradmatch-pb-warm"] {
            let mut cfg = bh::bench_config(ds, model);
            cfg.strategy = strat.into();
            cfg.budget_frac = 0.30;
            cfg.epochs = 10;
            cfg.r_interval = 5;
            let r = coord.run_one(&cfg, cfg.seed)?;
            bh::table_row(&[
                strat.into(),
                format!("{:.2}", r.test_acc * 100.0),
                format!("{:.2}", r.total_secs),
            ]);
            accs.insert(strat, r.test_acc);
        }
        ok &= bh::shape_check(
            &format!("{ds}: gradmatch-pb-warm beats every Table-12 baseline"),
            ["featurefl", "entropy", "forgetting"]
                .iter()
                .all(|s| accs["gradmatch-pb-warm"] >= accs[s] - 0.01),
        );
    }

    // Fig. 3l — smaller models: full training on the narrow proxy vs
    // GRAD-MATCH-PB-WARM on the big model at 30%
    bh::section("Fig. 3l — smaller models vs subset selection (synmnist)");
    bh::table_header(&["config", "acc%", "time-s", "speedup-vs-big-full"]);
    let mut big = bh::bench_config("synmnist", "lenet_s");
    big.epochs = 10;
    let full_big = coord.full_baseline(&big, big.seed)?;
    bh::table_row(&[
        "full lenet_s".into(),
        format!("{:.2}", full_big.test_acc * 100.0),
        format!("{:.2}", full_big.total_secs),
        "1.00".into(),
    ]);
    // narrow proxy (MobileNet stand-in)
    let mut narrow = bh::bench_config("synmnist", "lenet_narrow");
    narrow.epochs = 10;
    let full_narrow = coord.full_baseline(&narrow, narrow.seed)?;
    bh::table_row(&[
        "full lenet_narrow".into(),
        format!("{:.2}", full_narrow.test_acc * 100.0),
        format!("{:.2}", full_narrow.total_secs),
        format!("{:.2}", full_big.total_secs / full_narrow.total_secs.max(1e-9)),
    ]);
    let mut gm = big.clone();
    gm.strategy = "gradmatch-pb-warm".into();
    gm.budget_frac = 0.30;
    gm.r_interval = 5;
    let gm_run = coord.run_one(&gm, gm.seed)?;
    bh::table_row(&[
        "gm-pb-warm 30% lenet_s".into(),
        format!("{:.2}", gm_run.test_acc * 100.0),
        format!("{:.2}", gm_run.total_secs),
        format!("{:.2}", full_big.total_secs / gm_run.total_secs.max(1e-9)),
    ]);
    ok &= bh::shape_check(
        "3l: subset selection on the big model beats the narrow model's accuracy",
        gm_run.test_acc >= full_narrow.test_acc - 0.01,
    );
    println!("\ntable12_extra_baselines: {}", if ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
