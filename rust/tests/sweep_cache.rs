//! Conformance for the cross-arm [`SelectionCache`] (MILO-style subset
//! reuse), pinned device-free on the counting oracle:
//!
//! - **zero-dispatch hits** — the first arm of a signature-shared round
//!   pays the full `⌈n/chunk⌉` staging cost; the second arm replays the
//!   memoized subset bit-identically with ZERO oracle dispatches (the
//!   solve closure is never even invoked);
//! - **key sensitivity** — seed, rng_tag, budget, strategy spec,
//!   [`ShardPlan`], [`SketchPlan`], and the dataset scope each force a
//!   miss: no signature ingredient may silently alias another arm;
//! - **LRU bound** — past the cap the least-recently-used entry is
//!   evicted and re-misses, while a touched entry survives;
//! - **transparency** — the cache wrapper's miss path returns exactly
//!   the direct engine solve (selection and dispatch counts) for every
//!   `strategy_specs()` spec, so `reuse_across_arms = false` — which
//!   skips the wrapper entirely — cannot change any result;
//!
//! plus live-runtime coverage (skips without HLO artifacts) for the
//! coordinator plumbing: re-running an identical arm hits the cache and
//! reproduces the run, `runs = 0` clamps to one seed-run, and the full
//! skyline is solved exactly once per `baseline_fingerprint`.

mod common;

use gradmatch::config::ExperimentConfig;
use gradmatch::coordinator::Coordinator;
use gradmatch::data::Dataset;
use gradmatch::engine::{
    SelectionCache, SelectionEngine, SelectionRequest, ShardPlan, SketchPlan,
};
use gradmatch::grads::SynthGrads;
use gradmatch::rng::Rng;
use gradmatch::selection::strategy_specs;
use gradmatch::tensor::Matrix;

const CHUNK: usize = 16;
const BATCH: usize = 4;
/// An arbitrary dataset-scope fingerprint shared by "arms" in these tests.
const SCOPE: u64 = 0xA17E_5C0F;

/// Balanced synthetic dataset sized exactly `n` (`y = i mod classes`) —
/// keeps the `⌈n/chunk⌉` dispatch arithmetic exact.
fn balanced(seed: u64, n: usize, classes: usize, d: usize) -> Dataset {
    let mut rng = Rng::new(seed);
    let y: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

/// Imbalanced fixture (heavy head, long tail) so per-class and scoring
/// strategies all have work in the transparency sweep.
fn imbalanced(seed: u64, classes: usize, d: usize) -> Dataset {
    let mut y: Vec<i32> = Vec::new();
    for cls in 0..classes {
        let n_c = match cls % 3 {
            0 => 37,
            1 => 11,
            _ => 4,
        };
        y.extend(std::iter::repeat(cls as i32).take(n_c));
    }
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut y);
    let n = y.len();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

fn request(strategy: &str, ground: Vec<usize>, budget: usize) -> SelectionRequest {
    SelectionRequest {
        strategy: strategy.into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: 7,
        ground,
        shards: None,
        sketch: None,
    }
}

/// Oracle dispatch total across every kind of call — a cache hit must
/// leave all of them at zero.
fn dispatches(o: &SynthGrads) -> usize {
    o.grad_calls + o.mean_calls + o.gradsum_calls + o.eval_calls
}

#[test]
fn second_arm_is_served_with_zero_staging_dispatches() {
    // arm 1 pays the full staging pass; arm 2 (fresh engine + oracle,
    // same round signature) must not touch its oracle at all
    let (classes, h, d) = (3usize, 2usize, 5usize);
    let p = h * classes + classes;
    let n = 96usize;
    let train = balanced(81, n, classes, d);
    let val = balanced(82, 24, classes, d);
    let ground: Vec<usize> = (0..n).collect();
    let req = request("gradmatch", ground, n / 4);
    let cache = SelectionCache::new(64);

    let mut oracle1 = SynthGrads::new(CHUNK, p);
    let cold = cache
        .round(SCOPE, &req, || {
            let engine = SelectionEngine::with_oracle(&mut oracle1, &train, &val, h, classes);
            engine.select(&req)
        })
        .unwrap();
    assert!(!cold.stats.cache_hit);
    assert!(cold.stats.cache_stored, "a clean solve must be memoized");
    assert_eq!(oracle1.grad_calls, n.div_ceil(CHUNK), "cold arm pays ⌈n/chunk⌉");
    assert_eq!(cold.stats.stage_dispatches, n.div_ceil(CHUNK));

    let mut oracle2 = SynthGrads::new(CHUNK, p);
    let hit = cache
        .round(SCOPE, &req, || {
            let engine = SelectionEngine::with_oracle(&mut oracle2, &train, &val, h, classes);
            engine.select(&req)
        })
        .unwrap();
    assert!(hit.stats.cache_hit);
    assert!(!hit.stats.cache_stored);
    assert_eq!(dispatches(&oracle2), 0, "a hit performs ZERO staging dispatches");
    assert_eq!(hit.stats.stage_dispatches, 0);
    assert_eq!(
        hit.selection, cold.selection,
        "the replayed subset must be bit-identical to the cold solve"
    );
    assert_eq!(hit.strategy, cold.strategy);
    assert_eq!(hit.budget, cold.budget);
    assert!(hit.stats.cache_saved_secs >= 0.0);

    // the sharded path is memoized the same way: cold pays the per-shard
    // passes + merge re-stage, the hit pays nothing
    let mut sharded_req = req.clone();
    sharded_req.shards = Some(ShardPlan { shards: 2, max_staged_rows: 0 });
    let mut oracle3 = SynthGrads::new(CHUNK, p);
    let sharded_cold = cache
        .round(SCOPE, &sharded_req, || {
            let engine = SelectionEngine::with_oracle(&mut oracle3, &train, &val, h, classes);
            engine.select(&sharded_req)
        })
        .unwrap();
    assert!(!sharded_cold.stats.cache_hit, "a shard plan is its own signature");
    assert!(dispatches(&oracle3) > 0);
    let mut oracle4 = SynthGrads::new(CHUNK, p);
    let sharded_hit = cache
        .round(SCOPE, &sharded_req, || {
            let engine = SelectionEngine::with_oracle(&mut oracle4, &train, &val, h, classes);
            engine.select(&sharded_req)
        })
        .unwrap();
    assert!(sharded_hit.stats.cache_hit);
    assert_eq!(dispatches(&oracle4), 0);
    assert_eq!(sharded_hit.selection, sharded_cold.selection);
}

#[test]
fn every_signature_ingredient_forces_a_miss() {
    let (classes, h, d) = (3usize, 3usize, 5usize);
    let p = h * classes + classes;
    let n = 64usize;
    let train = balanced(91, n, classes, d);
    let val = balanced(92, 24, classes, d);
    let ground: Vec<usize> = (0..n).collect();
    let base = request("gradmatch", ground.clone(), n / 4);
    let cache = SelectionCache::new(64);

    // prime the cache with the base signature
    let mut oracle = SynthGrads::new(CHUNK, p);
    cache
        .round(SCOPE, &base, || {
            let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
            engine.select(&base)
        })
        .unwrap();

    // each single-ingredient variation must re-pay real staging work
    let variations: Vec<(&str, u64, SelectionRequest)> = vec![
        ("seed", SCOPE, {
            let mut r = base.clone();
            r.seed = 43;
            r
        }),
        ("rng_tag", SCOPE, {
            let mut r = base.clone();
            r.rng_tag = 8;
            r
        }),
        ("budget", SCOPE, {
            let mut r = base.clone();
            r.budget = n / 4 - 1;
            r
        }),
        ("strategy", SCOPE, {
            let mut r = base.clone();
            r.strategy = "craig".into();
            r
        }),
        ("shards", SCOPE, {
            let mut r = base.clone();
            r.shards = Some(ShardPlan { shards: 2, max_staged_rows: 0 });
            r
        }),
        ("sketch", SCOPE, {
            let mut r = base.clone();
            r.sketch = Some(SketchPlan { width: 3, refit: true, seed_salt: 5 });
            r
        }),
        ("scope", SCOPE ^ 1, base.clone()),
    ];
    for (what, scope, req) in variations {
        let mut oracle = SynthGrads::new(CHUNK, p);
        let report = cache
            .round(scope, &req, || {
                let engine =
                    SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
                engine.select(&req)
            })
            .unwrap();
        assert!(!report.stats.cache_hit, "changing '{what}' must force a miss");
        assert!(dispatches(&oracle) > 0, "'{what}' variation must re-pay staging");
    }

    // and the unchanged signature still hits — the misses above did not
    // evict or corrupt the original entry
    let hit = cache
        .round(SCOPE, &base, || panic!("identical signature must hit"))
        .unwrap();
    assert!(hit.stats.cache_hit);
}

#[test]
fn lru_cap_evicts_the_oldest_entry_which_re_misses() {
    let (classes, h, d) = (3usize, 2usize, 5usize);
    let p = h * classes + classes;
    let n = 48usize;
    let train = balanced(101, n, classes, d);
    let val = balanced(102, 24, classes, d);
    let ground: Vec<usize> = (0..n).collect();
    let key = |tag: u64| {
        let mut r = request("gradmatch", ground.clone(), n / 4);
        r.rng_tag = tag;
        r
    };
    let solve = |req: &SelectionRequest, cache: &SelectionCache| {
        let mut oracle = SynthGrads::new(CHUNK, p);
        let report = cache
            .round(SCOPE, req, || {
                let engine =
                    SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
                engine.select(req)
            })
            .unwrap();
        (report, dispatches(&oracle))
    };

    let cache = SelectionCache::new(2);
    let (r1, c1) = solve(&key(1), &cache);
    let (_r2, c2) = solve(&key(2), &cache);
    assert!(!r1.stats.cache_hit && c1 > 0 && c2 > 0);

    // touch key 1 so key 2 becomes the LRU victim
    let touched = cache
        .round(SCOPE, &key(1), || panic!("key 1 must still be cached"))
        .unwrap();
    assert!(touched.stats.cache_hit);

    // a third key over cap 2 evicts key 2 (oldest by last use), not key 1
    let (_r3, c3) = solve(&key(3), &cache);
    assert!(c3 > 0);
    let again = cache
        .round(SCOPE, &key(1), || panic!("the touched entry must survive eviction"))
        .unwrap();
    assert!(again.stats.cache_hit);
    let (r2_again, c2_again) = solve(&key(2), &cache);
    assert!(
        !r2_again.stats.cache_hit && c2_again > 0,
        "the evicted entry must re-pay the full solve"
    );
    let (_depth, _hits, _stores, evictions) = cache.stats();
    assert!(evictions >= 1, "the cap must have evicted at least once");
}

#[test]
fn miss_path_is_bit_transparent_for_every_spec() {
    // reuse_across_arms = false skips the cache wrapper entirely; this
    // pins the complementary invariant — the wrapper's MISS path is the
    // direct engine solve, selection- and dispatch-identical — so turning
    // the flag on cannot change any first-arm result either
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(111, classes, d);
    let val = imbalanced(112, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let budget = n / 4;

    for spec in strategy_specs() {
        let req = request(spec, ground.clone(), budget);

        let mut direct_oracle = SynthGrads::with_batch(CHUNK, p, BATCH);
        let direct = {
            let engine =
                SelectionEngine::with_oracle(&mut direct_oracle, &train, &val, h, classes);
            engine.select(&req).unwrap()
        };

        let cache = SelectionCache::new(64); // fresh per spec: always a miss
        let mut wrapped_oracle = SynthGrads::with_batch(CHUNK, p, BATCH);
        let wrapped = cache
            .round(SCOPE, &req, || {
                let engine =
                    SelectionEngine::with_oracle(&mut wrapped_oracle, &train, &val, h, classes);
                engine.select(&req)
            })
            .unwrap();

        assert_eq!(
            wrapped.selection, direct.selection,
            "{spec}: the wrapper's miss path must not perturb the solve"
        );
        assert_eq!(
            (
                wrapped_oracle.grad_calls,
                wrapped_oracle.mean_calls,
                wrapped_oracle.gradsum_calls,
                wrapped_oracle.eval_calls
            ),
            (
                direct_oracle.grad_calls,
                direct_oracle.mean_calls,
                direct_oracle.gradsum_calls,
                direct_oracle.eval_calls
            ),
            "{spec}: miss-path dispatch counts must equal the direct solve"
        );
        assert_eq!(wrapped.stats.stage_dispatches, direct.stats.stage_dispatches, "{spec}");
        assert!(!wrapped.stats.cache_hit, "{spec}");
    }
}

// ---------------------------------------------------------------------------
// live-runtime coordinator plumbing (skips without HLO artifacts)
// ---------------------------------------------------------------------------

fn mini_cfg(strategy: &str) -> ExperimentConfig {
    ExperimentConfig {
        dataset: "synmnist".into(),
        model: "lenet_narrow".into(),
        strategy: strategy.into(),
        budget_frac: 0.10,
        epochs: 8,
        r_interval: 4,
        lr0: 0.05,
        n_train: 800,
        eval_every: 0,
        artifacts_dir: common::artifacts_dir(),
        ..Default::default()
    }
}

#[test]
fn rerunning_an_identical_arm_hits_the_cache_and_reproduces_the_run() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let mut cfg = mini_cfg("gradmatch-pb");
    cfg.reuse_across_arms = true;
    let r1 = coord.run_one(&cfg, 42).unwrap();
    assert!(r1.cache_store_rounds >= 1, "first arm must memoize its rounds");
    assert_eq!(r1.cache_hit_rounds, 0, "nothing to hit on a cold cache");
    let r2 = coord.run_one(&cfg, 42).unwrap();
    assert!(r2.cache_hit_rounds >= 1, "the identical arm must replay rounds");
    assert_eq!(
        r2.test_acc, r1.test_acc,
        "replayed subsets must reproduce the run exactly"
    );
    assert!(r2.cache_hit_secs_saved >= 0.0);
    let (depth, hits, stores, _evictions) = coord.selection_cache_stats();
    assert!(depth >= 1);
    assert!(hits >= r2.cache_hit_rounds as u64);
    assert!(stores >= r1.cache_store_rounds as u64);
}

#[test]
fn reuse_off_keeps_the_cache_untouched() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let cfg = mini_cfg("gradmatch-pb"); // reuse_across_arms defaults off
    let r = coord.run_one(&cfg, 42).unwrap();
    assert_eq!(r.cache_hit_rounds, 0);
    assert_eq!(r.cache_store_rounds, 0);
    assert_eq!(coord.selection_cache_stats(), (0, 0, 0, 0));
}

#[test]
fn sweep_clamps_runs_and_solves_the_skyline_once_per_fingerprint() {
    if !common::runtime_available() {
        return;
    }
    let mut coord = Coordinator::new(&common::artifacts_dir()).unwrap();
    let mut cfg = mini_cfg("gradmatch-pb");
    cfg.epochs = 4;
    cfg.r_interval = 2;
    cfg.runs = 0; // run_multi must clamp to one seed-run per arm
    let rows = coord.sweep(&cfg, &["random", "gradmatch-pb"], &[0.1, 0.3]).unwrap();
    assert_eq!(rows.len(), 4);
    assert_eq!(coord.baseline_solves(), 1, "one sweep, one full skyline");
    for row in &rows {
        assert_eq!(row.acc_std, 0.0, "a single clamped run has no spread");
        assert_eq!(row.full_acc, rows[0].full_acc, "all arms share the skyline");
    }
    // a second sweep over the same base config reuses the cached skyline
    let rows2 = coord.sweep(&cfg, &["random"], &[0.1]).unwrap();
    assert_eq!(coord.baseline_solves(), 1);
    assert_eq!(rows2[0].full_acc, rows[0].full_acc);
    // the PR-10 regression: differing only in n_train must re-solve — the
    // old (dataset, model, epochs, seed) key silently reused the skyline
    let mut other = cfg.clone();
    other.n_train = 600;
    coord.sweep(&other, &["random"], &[0.1]).unwrap();
    assert_eq!(coord.baseline_solves(), 2, "n_train must split the skyline cache");
}
