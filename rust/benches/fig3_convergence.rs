//! Figure 3j (+3k in miniature): test accuracy vs cumulative training time
//! for all strategies at a 30% budget — the convergence plot.  GRAD-MATCH
//! variants should reach high accuracy faster (in accounted time) than the
//! non-PB baselines, and extending the schedule should close the gap to
//! full training (3k).

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;
    let mut cfg = bh::bench_config("syncifar100", "resnet_s");
    cfg.budget_frac = 0.30;
    cfg.epochs = 15;
    cfg.r_interval = 5;
    cfg.eval_every = 3;

    bh::section("Fig. 3j — convergence at 30% syncifar100");
    let full = coord.full_baseline(&cfg, cfg.seed)?;
    println!("full: acc {:.2}% time {:.1}s", full.test_acc * 100.0, full.total_secs);

    let mut final_accs = Vec::new();
    for strat in ["random", "glister", "craig-pb", "gradmatch-pb", "gradmatch-pb-warm"] {
        let mut c = cfg.clone();
        c.strategy = strat.into();
        let r = coord.run_one(&c, c.seed)?;
        println!("\n{strat} (final acc {:.2}%, total {:.1}s):", r.test_acc * 100.0, r.total_secs);
        for &(e, t, a) in &r.convergence {
            println!("  epoch {e:>3}  {t:>6.2}s  {:>6.2}%", a * 100.0);
        }
        final_accs.push((strat, r.test_acc, r.total_secs));
    }

    // Fig. 3k: extend gradmatch-pb-warm past the standard endpoint
    bh::section("Fig. 3k — extended training (gradmatch-pb-warm, 30%)");
    let mut ext = cfg.clone();
    ext.strategy = "gradmatch-pb-warm".into();
    ext.epochs = cfg.epochs * 2;
    let r = coord.run_one(&ext, ext.seed)?;
    for &(e, t, a) in &r.convergence {
        let mark = if e + 1 == cfg.epochs { " <- standard endpoint (*)" } else { "" };
        println!("  epoch {e:>3}  {t:>6.2}s  {:>6.2}%{mark}", a * 100.0);
    }
    let parity = r.convergence.iter().find(|&&(_, _, a)| a >= full.test_acc);
    let mut all_ok = true;
    match parity {
        Some(&(e, t, _)) => {
            println!("parity with full at epoch {e} ({t:.1}s) — {:.2}x faster overall", full.total_secs / t.max(1e-9));
            all_ok &= bh::shape_check("3k: parity reached while faster than full", t < full.total_secs);
        }
        None => {
            all_ok &= bh::shape_check(
                "3k: extended run within 3pp of full",
                (full.test_acc - r.test_acc) < 0.03,
            );
        }
    }
    let gm = final_accs.iter().find(|(s, _, _)| *s == "gradmatch-pb-warm").unwrap();
    let rnd = final_accs.iter().find(|(s, _, _)| *s == "random").unwrap();
    all_ok &= bh::shape_check("3j: gradmatch-pb-warm >= random at 30%", gm.1 >= rnd.1);
    println!("\nfig3_convergence: {}", if all_ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
