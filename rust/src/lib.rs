//! GRAD-MATCH: gradient-matching data subset selection for efficient training.
//!
//! Reproduction of *Killamsetty et al., "GRAD-MATCH: Gradient Matching based
//! Data Subset Selection for Efficient Deep Model Training", ICML 2021* as a
//! three-layer system:
//!
//! - **Layer 1 (Pallas, build time)** — the gradient-matching compute kernels
//!   (per-sample last-layer gradients, OMP residual correlations, pairwise
//!   gradient distances) in `python/compile/kernels/`.
//! - **Layer 2 (JAX, build time)** — the classifier forward/backward and the
//!   selection-support entry points in `python/compile/model.py`, lowered once
//!   to HLO text under `artifacts/` by `python/compile/aot.py`.
//! - **Layer 3 (this crate, run time)** — the adaptive data-selection
//!   coordinator: dataset substrate, gradient cache, selection strategies
//!   (GRAD-MATCH / GRAD-MATCH-PB / CRAIG / CRAIG-PB / GLISTER / RANDOM /
//!   FULL-EARLYSTOP plus warm-start wrappers), the weighted-SGD training loop,
//!   and the experiment harness. Python is never on the training path.

// Math/substrate core — always built (works with --no-default-features).
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod data;
pub mod jsonlite;
pub mod linalg;
pub mod metrics;
pub mod omp;
pub mod par;
pub mod rng;
pub mod stats;
pub mod submod;
pub mod tensor;
pub mod testutil;
pub mod theory;

// XLA/PJRT interop layer — gated behind the (default-on) `xla` feature so
// the crate builds with no xla dependency at all.  The vendored stub makes
// these compile everywhere; real execution needs the xla_extension tree.
#[cfg(feature = "xla")]
pub mod checkpoint;
#[cfg(feature = "xla")]
pub mod coordinator;
#[cfg(feature = "xla")]
pub mod grads;
#[cfg(feature = "xla")]
pub mod overlap;
#[cfg(feature = "xla")]
pub mod runtime;
#[cfg(feature = "xla")]
pub mod selection;
#[cfg(feature = "xla")]
pub mod trainer;
