//! Contracts of the selection-engine API (SelectionRequest →
//! SelectionEngine → SelectionReport):
//!
//! - **shared staging** — a multi-strategy round through one engine
//!   performs exactly `⌈n/chunk⌉` staging dispatches (counting oracle,
//!   device-free), where solo engines pay one pass each;
//! - **equivalence** — engine-path selections are index/weight-identical
//!   to the legacy `parse_strategy` + `Strategy::select` path for every
//!   spec in `paper_strategies()` (live runtime; skips without
//!   artifacts);
//! - **serialization** — `SelectionReport` and `SelectionRequest`
//!   round-trip through `jsonlite`.

mod common;

use gradmatch::data::Dataset;
use gradmatch::engine::{Degradation, RoundStats, SelectionEngine, SelectionReport, SelectionRequest};
use gradmatch::grads::{stage_class_grads_with, StageWidth, SynthGrads};
use gradmatch::jsonlite::Json;
use gradmatch::rng::Rng;
use gradmatch::selection::{
    paper_strategies, parse_strategy, solve_classes_omp, split_budget, staged_targets, GradSource,
    SelectCtx, Selection,
};
use gradmatch::tensor::Matrix;

/// Imbalanced synthetic dataset: heavy head, long tail.
fn imbalanced(seed: u64, classes: usize, d: usize) -> Dataset {
    let mut y: Vec<i32> = Vec::new();
    for cls in 0..classes {
        let n_c = match cls % 3 {
            0 => 40,
            1 => 12,
            _ => 3,
        };
        y.extend(std::iter::repeat(cls as i32).take(n_c));
    }
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut y);
    let n = y.len();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

fn request(strategy: &str, ground: Vec<usize>, budget: usize) -> SelectionRequest {
    SelectionRequest {
        strategy: strategy.into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: 7,
        ground,
        shards: None,
        sketch: None,
    }
}

#[test]
fn three_strategy_round_shares_one_staging_pass() {
    // the acceptance contract: a sweep round (gradmatch, gradmatch-warm,
    // craig) against ONE model state costs exactly ⌈n/chunk⌉ gradient
    // dispatches and zero mean dispatches — the engine's shared cache
    // serves requests 2 and 3 for free
    let (classes, h, d, chunk) = (6usize, 4usize, 5usize, 16usize);
    let p = h * classes + classes;
    let train = imbalanced(11, classes, d);
    let val = imbalanced(12, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let specs = ["gradmatch", "gradmatch-warm", "craig"];

    let mut oracle = SynthGrads::new(chunk, p);
    let reports: Vec<SelectionReport> = {
        let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
        let reqs: Vec<SelectionRequest> =
            specs.iter().map(|s| request(s, ground.clone(), n / 4)).collect();
        engine.select_batch(&reqs).unwrap()
    };
    assert_eq!(oracle.grad_calls, n.div_ceil(chunk), "one shared staged pass");
    assert_eq!(oracle.mean_calls, 0, "train targets are free — no mean pass");

    // the reports narrate the sharing: the first request pays the pass,
    // the rest ride the cache
    assert!(!reports[0].stats.stage_shared);
    assert_eq!(reports[0].stats.stage_dispatches, n.div_ceil(chunk));
    for rep in &reports[1..] {
        assert!(rep.stats.stage_shared, "{}: should ride the cache", rep.strategy);
        assert_eq!(rep.stats.stage_dispatches, 0, "{}", rep.strategy);
    }
    for rep in &reports {
        assert!(!rep.selection.indices.is_empty(), "{}", rep.strategy);
        assert_eq!(rep.selection.indices.len(), rep.selection.weights.len());
        assert!(rep.selection.indices.iter().all(|&i| i < n), "{}", rep.strategy);
        assert_eq!(
            rep.stats.class_budgets.iter().sum::<usize>(),
            n / 4,
            "{}: per-class budgets account for the whole budget",
            rep.strategy
        );
    }

    // solo engines pay one pass each — the waste the shared cache removes
    let mut solo_calls = 0usize;
    for spec in specs {
        let mut solo = SynthGrads::new(chunk, p);
        {
            let engine = SelectionEngine::with_oracle(&mut solo, &train, &val, h, classes);
            engine.select(&request(spec, ground.clone(), n / 4)).unwrap();
        }
        solo_calls += solo.grad_calls;
    }
    assert_eq!(solo_calls, 3 * n.div_ceil(chunk));
}

#[test]
fn oracle_engine_matches_the_stateless_pipeline() {
    // engine-path gradmatch == hand-run stage → budgets → targets →
    // solve over an identical oracle (the engine adds no numerics)
    let (classes, h, d, chunk) = (5usize, 3usize, 4usize, 8usize);
    let p = h * classes + classes;
    let train = imbalanced(21, classes, d);
    let val = imbalanced(22, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let budget = n / 3;

    let mut oracle = SynthGrads::new(chunk, p);
    let got = {
        let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
        engine.select(&request("gradmatch", ground.clone(), budget)).unwrap()
    };

    let mut ref_oracle = SynthGrads::new(chunk, p);
    let stages = stage_class_grads_with(
        &mut ref_oracle,
        &train,
        &ground,
        h,
        classes,
        StageWidth::ClassSlice,
        true,
    )
    .unwrap();
    let sizes: Vec<usize> = stages.iter().map(|s| s.rows.len()).collect();
    let budgets = split_budget(budget, &sizes);
    let targets = staged_targets(&stages, h, classes, true, None);
    let want = solve_classes_omp(&stages, &budgets, &targets, 0.5, 1e-10, true).unwrap();

    assert_eq!(got.selection.indices, want.indices);
    for (a, b) in got.selection.weights.iter().zip(&want.weights) {
        assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
    }
    assert_eq!(got.stats.class_budgets, budgets);
}

#[test]
fn engine_reuse_is_keyed_by_ground_set_and_width() {
    // a different ground set (or stage width) must NOT be served from the
    // cache — staged rows depend on both
    let (classes, h, d, chunk) = (4usize, 3usize, 4usize, 8usize);
    let p = h * classes + classes;
    let train = imbalanced(31, classes, d);
    let val = imbalanced(32, classes, d);
    let n = train.len();
    let full: Vec<usize> = (0..n).collect();
    let half: Vec<usize> = (0..n / 2).collect();

    let mut oracle = SynthGrads::new(chunk, p);
    {
        let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
        engine.select(&request("gradmatch", full.clone(), n / 4)).unwrap();
        engine.select(&request("gradmatch", half.clone(), n / 8)).unwrap();
        // distinct width: the PerClass variant stages full-P rows
        engine.select(&request("gradmatch-perclass", full.clone(), n / 4)).unwrap();
        // and back to the cached entries — no further passes
        engine.select(&request("craig", full.clone(), n / 4)).unwrap();
        engine.select(&request("craig", half.clone(), n / 8)).unwrap();
    }
    let want =
        n.div_ceil(chunk) + (n / 2).div_ceil(chunk) + n.div_ceil(chunk);
    assert_eq!(oracle.grad_calls, want, "three distinct (width, ground) keys");
}

#[test]
fn report_and_request_roundtrip_through_jsonlite() {
    let rep = SelectionReport {
        strategy: "craig".into(),
        budget: 9,
        selection: Selection {
            indices: vec![1, 4, 7],
            weights: vec![2.0, 1.0, 6.5],
            grad_error: None,
        },
        stats: RoundStats {
            stage_secs: 0.001,
            solve_secs: 0.125,
            stage_dispatches: 3,
            stage_shared: true,
            class_budgets: vec![3, 3, 3],
            fanout: false,
            engine_round: 1,
            stage_reused_buffers: true,
            retries: 2,
            quarantined: 1,
            degradation: Degradation::RandomFallback,
            ..RoundStats::default()
        },
    };
    let back =
        SelectionReport::from_json(&Json::parse(&rep.to_json().dump()).unwrap()).unwrap();
    assert_eq!(rep, back);

    let req = request("gradmatch-pb-warm", vec![0, 5, 3], 2);
    let back =
        SelectionRequest::from_json(&Json::parse(&req.to_json().dump()).unwrap()).unwrap();
    assert_eq!(req, back);
}

// ---------------------------------------------------------------------------
// live-runtime equivalence (skips without HLO artifacts)
// ---------------------------------------------------------------------------

const MODEL: &str = "lenet_narrow";

#[test]
fn engine_path_matches_legacy_strategy_select_for_all_paper_specs() {
    if !common::runtime_available() {
        return;
    }
    let rt = common::runtime();
    let st = rt.init(MODEL, 5).unwrap();
    let splits = common::tiny_mnist(600);
    let ground: Vec<usize> = (0..splits.train.len()).collect();
    let budget = 60usize;

    for spec in paper_strategies() {
        let req = request(spec, ground.clone(), budget);

        // engine path: fresh round-scoped engine, spec resolved inside
        let engine = SelectionEngine::new(&rt, st.clone(), &splits.train, &splits.val);
        let report = engine.select(&req).unwrap();

        // legacy path: parse + select with an identically-derived RNG and
        // private staging (round: None)
        let (mut strategy, _warm) = parse_strategy(spec, st.meta.batch).unwrap();
        let mut rng = req.round_rng();
        let want = strategy
            .select(&mut SelectCtx {
                src: GradSource::Live { rt: &rt, state: &st },
                train: &splits.train,
                ground: &ground,
                val: &splits.val,
                budget,
                lambda: req.lambda,
                eps: req.eps,
                is_valid: req.is_valid,
                rng: &mut rng,
                round: None,
            })
            .unwrap();

        assert_eq!(
            report.selection.indices, want.indices,
            "{spec}: engine selection must equal the legacy path"
        );
        assert_eq!(
            report.selection.weights, want.weights,
            "{spec}: engine weights must equal the legacy path"
        );
        assert_eq!(report.selection.grad_error, want.grad_error, "{spec}");
        assert_eq!(report.strategy, spec);
    }
}

#[test]
fn live_multi_strategy_round_shares_staging() {
    if !common::runtime_available() {
        return;
    }
    // gradmatch + craig in one live round: request 2 must report the
    // cache hit (dispatch accounting is pinned device-free above)
    let rt = common::runtime();
    let st = rt.init(MODEL, 6).unwrap();
    let splits = common::tiny_mnist(400);
    let ground: Vec<usize> = (0..splits.train.len()).collect();
    let engine = SelectionEngine::new(&rt, st, &splits.train, &splits.val);
    let reports = engine
        .select_batch(&[
            request("gradmatch", ground.clone(), 40),
            request("craig", ground.clone(), 40),
        ])
        .unwrap();
    assert!(!reports[0].stats.stage_shared);
    assert!(reports[0].stats.stage_dispatches > 0);
    assert!(reports[1].stats.stage_shared, "craig must reuse gradmatch's staged pass");
    assert_eq!(reports[1].stats.stage_dispatches, 0);
    assert!(!reports[1].selection.indices.is_empty());
}
