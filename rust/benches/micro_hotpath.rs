//! Hot-path micro benches — the profiling substrate for the §Perf pass
//! (EXPERIMENTS.md).  Measures each layer's unit costs in isolation:
//!
//! - L3→PJRT `train_step` latency (the per-step training cost)
//! - `grads_chunk` / `mean_grad_chunk` (selection gradient acquisition)
//! - `corr_chunk` (Pallas) vs Rust GEMV (the OMP inner loop, both backends)
//! - `sqdist_chunk` (Pallas) vs Rust pairwise distances (CRAIG)
//! - end-to-end OMP and lazy-greedy selection on realistic ground sets
//! - literal building overhead (host-side marshalling)

use gradmatch::bench_harness as bh;
use gradmatch::data::DatasetCard;
use gradmatch::omp::{omp_select, CorrBackend, OmpOpts, RustCorr, XlaCorr};
use gradmatch::rng::Rng;
use gradmatch::runtime::Runtime;
use gradmatch::submod::{lazy_greedy, naive_greedy, sim_from_sqdist, FacilityLocation};
use gradmatch::tensor::Matrix;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(bh::artifacts_dir())?;
    let mut rng = Rng::new(42);

    for model in ["lenet_s", "resnet_s"] {
        let meta = rt.model(model)?.clone();
        bh::section(&format!("micro — {model} (d={} h={} c={} P={})", meta.d, meta.h, meta.c, meta.p));

        // --- train_step -----------------------------------------------------
        let card = DatasetCard::all()
            .into_iter()
            .find(|c| c.model == model)
            .unwrap();
        let splits = card.generate(1, 600);
        let mut st = rt.init(model, 1)?;
        let mut x = vec![0.0f32; meta.batch * meta.d];
        let mut y = vec![0i32; meta.batch];
        for s in 0..meta.batch {
            x[s * meta.d..(s + 1) * meta.d].copy_from_slice(splits.train.x.row(s));
            y[s] = splits.train.y[s];
        }
        let w = vec![1.0f32; meta.batch];
        bh::bench_iters(&format!("{model}/train_step (B={}, 16-literal)", meta.batch), 30, || {
            rt.train_step(&mut st, &x, &y, &w, 0.01).unwrap()
        });
        let mut fs = gradmatch::runtime::FusedState::from_state(&st)?;
        bh::bench_iters(&format!("{model}/train_step_fused (packed state)"), 30, || {
            rt.train_step_fused(&mut fs, &x, &y, &w, 0.01).unwrap()
        });

        // --- gradient acquisition -------------------------------------------
        let idx: Vec<usize> = (0..meta.chunk.min(600)).collect();
        let chunk = gradmatch::data::padded_chunks(&splits.train, &idx, meta.chunk)
            .next()
            .unwrap();
        bh::bench_iters(&format!("{model}/grads_chunk ({}xP)", meta.chunk), 10, || {
            rt.grads_chunk(&st, &chunk.x, &chunk.y, &chunk.mask).unwrap()
        });
        bh::bench_iters(&format!("{model}/mean_grad_chunk (fused)"), 10, || {
            rt.mean_grad_chunk(&st, &chunk.x, &chunk.y, &chunk.mask).unwrap()
        });

        // --- OMP inner loop: Pallas corr vs Rust GEMV ------------------------
        let n = meta.chunk * 4;
        let g = Matrix::from_vec(n, meta.p, (0..n * meta.p).map(|_| rng.gaussian_f32()).collect());
        let r: Vec<f32> = (0..meta.p).map(|_| rng.gaussian_f32()).collect();
        let mut xla = XlaCorr::new(&rt, model, &g)?;
        bh::bench_iters(&format!("{model}/corr {}x{} (XLA+Pallas)", n, meta.p), 10, || {
            xla.corr(&r).unwrap()
        });
        let mut rust = RustCorr { g: &g };
        bh::bench_iters(&format!("{model}/corr {}x{} (Rust gemv)", n, meta.p), 10, || {
            rust.corr(&r).unwrap()
        });

        // --- full OMP over the ground set ------------------------------------
        let target: Vec<f32> = (0..meta.p).map(|_| rng.gaussian_f32()).collect();
        let opts = OmpOpts { k: 16, lambda: 0.5, eps: 1e-12 };
        bh::bench_iters(&format!("{model}/omp k=16 n={n} (XLA)"), 3, || {
            omp_select(&mut xla, &|j| g.row(j).to_vec(), &target, opts).unwrap()
        });
        bh::bench_iters(&format!("{model}/omp k=16 n={n} (Rust)"), 3, || {
            omp_select(&mut rust, &|j| g.row(j).to_vec(), &target, opts).unwrap()
        });

        // --- CRAIG distances --------------------------------------------------
        let a = Matrix::from_vec(
            meta.chunk,
            meta.p,
            (0..meta.chunk * meta.p).map(|_| rng.gaussian_f32()).collect(),
        );
        bh::bench_iters(&format!("{model}/sqdist {0}x{0} (XLA+Pallas)", meta.chunk), 5, || {
            rt.sqdist_chunk(model, &a, &a).unwrap()
        });
        bh::bench_iters(&format!("{model}/sqdist {0}x{0} (Rust)", meta.chunk), 2, || {
            let mut d = Matrix::zeros(meta.chunk, meta.chunk);
            for i in 0..meta.chunk {
                for j in i..meta.chunk {
                    let v = gradmatch::tensor::sqdist(a.row(i), a.row(j));
                    d.set(i, j, v);
                    d.set(j, i, v);
                }
            }
            d
        });
    }

    // --- lazy vs naive greedy (backend-independent) --------------------------
    bh::section("micro — submodular greedy");
    let n = 600;
    let gm = Matrix::from_vec(n, 64, (0..n * 64).map(|_| rng.gaussian_f32()).collect());
    let mut dist = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            let v = gradmatch::tensor::sqdist(gm.row(i), gm.row(j));
            dist.set(i, j, v);
            dist.set(j, i, v);
        }
    }
    let sim = sim_from_sqdist(&dist);
    bh::bench_iters(&format!("lazy_greedy n={n} k=60"), 5, || {
        lazy_greedy(&mut FacilityLocation::new(&sim), 60)
    });
    bh::bench_iters(&format!("naive_greedy n={n} k=60"), 2, || {
        naive_greedy(&mut FacilityLocation::new(&sim), 60)
    });
    let lazy = lazy_greedy(&mut FacilityLocation::new(&sim), 60);
    let naive = naive_greedy(&mut FacilityLocation::new(&sim), 60);
    println!(
        "  lazy evals {} vs naive evals {} ({}x fewer)",
        lazy.evals,
        naive.evals,
        naive.evals / lazy.evals.max(1)
    );
    Ok(())
}
