//! Subset-transferability bench (`BENCH_transfer.json`): quantifies what
//! `selection.reuse_across_arms` actually trades away.  Across a
//! strategies × budgets grid, arm A solves its rounds fresh and memoizes
//! them in a [`SelectionCache`]; arm B — the same round signature over a
//! *perturbed* gradient landscape (the device-free stand-in for a sweep
//! arm tuning the model or learning rate, which the cache key
//! deliberately ignores) — is measured both ways:
//!
//! - **per-arm**: B re-solves against its own gradients (the
//!   `reuse_across_arms = false` cost), and
//! - **reused**: B replays A's memoized subset via a cache hit (zero
//!   oracle dispatches).
//!
//! The accuracy proxy is the paper's gradient-matching error
//! `‖Σ wᵢgᵢ − Σ g‖ / ‖Σ g‖` evaluated on B's OWN gradients, so
//! `err_reused − err_fresh` is the staleness cost of transferring the
//! subset (Balles et al.'s caution, measured), and the wall-clock pair
//! is the amortization win.
//!
//! Hard checks (exit code 1 on failure — CI runs this under `--bench`):
//! - every reused round is a cache hit with ZERO oracle dispatches and
//!   is bit-identical to arm A's subset;
//! - under a small perturbation, the reused subset's matching error
//!   stays in the fresh solve's regime (ratio + absolute tolerance);
//! - the reused path is not slower than the per-arm path in aggregate.

use gradmatch::bench_harness as bh;
use gradmatch::data::Dataset;
use gradmatch::engine::{SelectionCache, SelectionEngine, SelectionReport, SelectionRequest};
use gradmatch::grads::{self, SynthGrads};
use gradmatch::rng::Rng;
use gradmatch::selection::Selection;
use gradmatch::tensor::Matrix;

const CHUNK: usize = 256;
const CLASSES: usize = 10;
const H: usize = 8;
const D: usize = 8;
const N: usize = 3_000;
/// gaussian drift applied to arm B's inputs (unit-scale features)
const DRIFT: f32 = 0.05;
const SCOPE: u64 = 0x7A45_FE12;

const STRATEGIES: [&str; 2] = ["gradmatch-rust", "gradmatch-pb-rust"];
const BUDGETS: [usize; 2] = [150, 300];

fn labels(n: usize) -> Vec<i32> {
    (0..n).map(|i| (i % CLASSES) as i32).collect()
}

fn request(strategy: &str, budget: usize) -> SelectionRequest {
    SelectionRequest {
        strategy: strategy.into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: 7,
        ground: (0..N).collect(),
        shards: None,
        sketch: None,
    }
}

fn solve(train: &Dataset, val: &Dataset, p: usize, req: &SelectionRequest) -> (SelectionReport, usize) {
    let mut oracle = SynthGrads::new(CHUNK, p);
    let rep = {
        let engine = SelectionEngine::with_oracle(&mut oracle, train, val, H, CLASSES);
        engine.select(req).expect("round must solve")
    };
    let calls = oracle.grad_calls + oracle.mean_calls + oracle.gradsum_calls + oracle.eval_calls;
    (rep, calls)
}

/// Paper-style matching error of a weighted subset against the full
/// ground gradient sum (same metric as `benches/shard_scale.rs`).
fn subset_error(store: &grads::GradientStore, sel: &Selection) -> f64 {
    let p = store.g.cols;
    let mut full = vec![0.0f64; p];
    for r in 0..store.g.rows {
        for (j, &v) in store.g.row(r).iter().enumerate() {
            full[j] += v as f64;
        }
    }
    let mut sub = vec![0.0f64; p];
    for (slot, &row) in sel.indices.iter().enumerate() {
        let w = sel.weights[slot] as f64;
        for (j, &v) in store.g.row(row).iter().enumerate() {
            sub[j] += w * v as f64;
        }
    }
    let num: f64 = full.iter().zip(&sub).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    let den: f64 = full.iter().map(|a| a * a).sum::<f64>().sqrt();
    num / den.max(1e-12)
}

fn main() {
    let p = H * CLASSES + CLASSES;
    let mut report = bh::BenchReport::new("sweep_transfer");
    let mut ok = true;

    // arm A's data, and arm B's drifted copy of it (same labels — only
    // the gradient landscape moves, exactly what model/lr tuning does)
    let mut rng = Rng::new(31);
    let xs_a: Vec<f32> = (0..N * D).map(|_| rng.gaussian_f32()).collect();
    let xs_b: Vec<f32> = xs_a.iter().map(|&v| v + DRIFT * rng.gaussian_f32()).collect();
    let train_a = Dataset { x: Matrix::from_vec(N, D, xs_a), y: labels(N), classes: CLASSES };
    let train_b = Dataset { x: Matrix::from_vec(N, D, xs_b), y: labels(N), classes: CLASSES };
    let val = {
        let mut vrng = Rng::new(32);
        let n_val = 300;
        Dataset {
            x: Matrix::from_vec(n_val, D, (0..n_val * D).map(|_| vrng.gaussian_f32()).collect()),
            y: labels(n_val),
            classes: CLASSES,
        }
    };

    // B's own per-sample gradients, shared by every arm's error metric
    let ground: Vec<usize> = (0..N).collect();
    let mut store_oracle = SynthGrads::new(CHUNK, p);
    let store_b = grads::per_sample_grads_with(&mut store_oracle, &train_b, &ground)
        .expect("per-sample gradients for the error metric");

    let cache = SelectionCache::new(64);
    let mut wall_perarm = 0.0f64;
    let mut wall_reused = 0.0f64;
    let mut dispatches_perarm = 0usize;
    let mut dispatches_reused = 0usize;
    let mut deltas: Vec<f64> = Vec::new();

    for strat in STRATEGIES {
        for budget in BUDGETS {
            let tag = format!("{strat}_{budget}");
            bh::section(&format!("sweep_transfer — arm {tag} (n={N}, drift={DRIFT})"));
            let req = request(strat, budget);

            // arm A: the cold solve that seeds the cache
            let (arm_a, _) = bh::timed(|| {
                cache
                    .round(SCOPE, &req, || {
                        let mut oracle = SynthGrads::new(CHUNK, p);
                        let engine =
                            SelectionEngine::with_oracle(&mut oracle, &train_a, &val, H, CLASSES);
                        engine.select(&req)
                    })
                    .expect("arm A must solve")
            });
            ok &= bh::shape_check(
                &format!("{tag}: arm A is a cold store"),
                !arm_a.stats.cache_hit && arm_a.stats.cache_stored,
            );

            // arm B, per-arm path: a fresh solve on B's own gradients
            let ((fresh_b, fresh_calls), t_fresh) =
                bh::timed(|| solve(&train_b, &val, p, &req));
            // arm B, reused path: the cache replays arm A's subset
            let mut hit_oracle = SynthGrads::new(CHUNK, p);
            let (reused_b, t_reused) = bh::timed(|| {
                cache
                    .round(SCOPE, &req, || {
                        let engine = SelectionEngine::with_oracle(
                            &mut hit_oracle,
                            &train_b,
                            &val,
                            H,
                            CLASSES,
                        );
                        engine.select(&req)
                    })
                    .expect("reused arm must be served")
            });
            let hit_calls = hit_oracle.grad_calls
                + hit_oracle.mean_calls
                + hit_oracle.gradsum_calls
                + hit_oracle.eval_calls;
            ok &= bh::shape_check(
                &format!("{tag}: reused arm is a zero-dispatch cache hit"),
                reused_b.stats.cache_hit && hit_calls == 0,
            );
            ok &= bh::shape_check(
                &format!("{tag}: reused subset is bit-identical to arm A's"),
                reused_b.selection == arm_a.selection,
            );

            let err_fresh = subset_error(&store_b, &fresh_b.selection);
            let err_reused = subset_error(&store_b, &reused_b.selection);
            let delta = err_reused - err_fresh;
            println!(
                "  err: fresh {err_fresh:.4}  reused {err_reused:.4}  delta {delta:+.4}  \
                 wall: per-arm {t_fresh:.3}s  reused {t_reused:.3}s"
            );
            // tolerance: a DRIFT-sized perturbation must not push the
            // transferred subset out of the fresh solve's quality regime
            const TOL_RATIO: f64 = 2.0;
            const TOL_ABS: f64 = 0.05;
            ok &= bh::shape_check(
                &format!(
                    "{tag}: reused err {err_reused:.4} <= {TOL_RATIO}x fresh {err_fresh:.4} + {TOL_ABS}"
                ),
                err_reused <= TOL_RATIO * err_fresh + TOL_ABS,
            );

            wall_perarm += t_fresh;
            wall_reused += t_reused;
            dispatches_perarm += fresh_calls;
            dispatches_reused += hit_calls;
            deltas.push(delta);
            report.note(&format!("transfer_{tag}/err_fresh"), err_fresh);
            report.note(&format!("transfer_{tag}/err_reused"), err_reused);
            report.note(&format!("transfer_{tag}/err_delta"), delta);
            report.note(&format!("transfer_{tag}/secs_perarm"), t_fresh);
            report.note(&format!("transfer_{tag}/secs_reused"), t_reused);
        }
    }

    // one headline record so the bench shows up in the timing table
    let rec_req = request(STRATEGIES[0], BUDGETS[0]);
    report.rec("transfer/perarm_solve", 3, || {
        solve(&train_b, &val, p, &rec_req).0.selection.indices.len()
    });
    report.rec("transfer/reused_round", 3, || {
        cache
            .round(SCOPE, &rec_req, || panic!("primed round must hit"))
            .expect("hit")
            .selection
            .indices
            .len()
    });

    let arms = (STRATEGIES.len() * BUDGETS.len()) as f64;
    let mean_delta = deltas.iter().sum::<f64>() / arms;
    let (depth, hits, stores, _evictions) = cache.stats();
    println!(
        "  grid: {arms} arms  mean err delta {mean_delta:+.4}  \
         wall per-arm {wall_perarm:.3}s vs reused {wall_reused:.3}s  \
         cache depth {depth} hits {hits} stores {stores}"
    );
    ok &= bh::shape_check(
        "reused grid wall-clock <= per-arm grid wall-clock",
        wall_reused <= wall_perarm,
    );
    ok &= bh::shape_check(
        &format!("every arm hit once ({hits} hits >= {arms} arms)"),
        hits as f64 >= arms,
    );
    report.note("transfer/arms", arms);
    report.note("transfer/mean_err_delta", mean_delta);
    report.note("transfer/wall_secs_perarm", wall_perarm);
    report.note("transfer/wall_secs_reused", wall_reused);
    report.note(
        "transfer/amortized_speedup",
        wall_perarm / wall_reused.max(1e-9),
    );
    report.note("transfer/dispatches_perarm", dispatches_perarm as f64);
    report.note("transfer/dispatches_reused", dispatches_reused as f64);
    report.note("transfer/cache_hits", hits as f64);
    report.note("transfer/checks_passed", if ok { 1.0 } else { 0.0 });

    report.write(&bh::bench_out_path("BENCH_transfer.json")).expect("write bench report");
    if !ok {
        std::process::exit(1);
    }
}
