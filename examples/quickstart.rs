//! Quickstart: train a classifier on 10% of a synthetic MNIST-scale dataset
//! with GRAD-MATCH-PB-WARM and compare against RANDOM and full training.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Flags (all optional): `--dataset synmnist --budget 0.1 --epochs 40
//! --n-train 4000 --seed 42`.

use anyhow::Result;
use gradmatch::cli::Cli;
use gradmatch::coordinator::Coordinator;

fn main() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    args.insert(0, "train".into());
    let cli = Cli::parse(&args)?;

    let mut cfg = cli.experiment_config()?;
    // quickstart defaults: small but real
    if cli.flag("epochs").is_none() {
        cfg.epochs = 40;
    }
    if cli.flag("n-train").is_none() {
        cfg.n_train = 4000;
    }
    if cli.flag("eval-every").is_none() {
        cfg.eval_every = 10;
    }
    cfg.r_interval = cfg.r_interval.min(10);

    println!("GRAD-MATCH quickstart — dataset={} model={} budget={:.0}%", cfg.dataset, cfg.model, cfg.budget_frac * 100.0);
    let mut coord = Coordinator::new(&cfg.artifacts_dir)?;

    let full = coord.full_baseline(&cfg, cfg.seed)?;
    println!(
        "\nFULL      : acc {:>6.2}%  time {:>7.2}s  energy(sim) {:.5} kWh",
        full.test_acc * 100.0,
        full.total_secs,
        full.energy_kwh
    );

    for strat in ["random", "gradmatch-pb", "gradmatch-pb-warm"] {
        let mut c = cfg.clone();
        c.strategy = strat.into();
        let r = coord.run_one(&c, c.seed)?;
        println!(
            "{strat:<10}: acc {:>6.2}%  time {:>7.2}s (select {:>5.2}s = stage {:.2}s + solve {:.2}s, {} dispatches)  speedup {:>5.2}x  rel-err {:>5.2}%",
            r.test_acc * 100.0,
            r.total_secs,
            r.select_secs,
            r.select_stage_secs,
            r.select_solve_secs,
            r.stage_dispatches,
            full.total_secs / r.total_secs.max(1e-9),
            100.0 * (full.test_acc - r.test_acc) / full.test_acc
        );
    }
    println!("\n(energy numbers are simulated — see DESIGN.md §4)");
    Ok(())
}
