//! Timing + energy accounting for the experiment tables.
//!
//! The paper measures wall-clock (V100 hours) and energy (pyJoules, KWH).
//! pyJoules/RAPL counters are unavailable in this sandbox, so energy is
//! **simulated** with a phase-power model: each accounted phase (subset
//! training, data selection, evaluation) contributes `P_phase × duration`.
//! This preserves the structure the paper reports — energy tracks time with
//! selection overhead attributed at a different power draw (GPU busy vs
//! CPU-side selection).  All energy numbers downstream are labeled
//! simulated; see DESIGN.md §4.

use std::collections::BTreeMap;
use std::time::Instant;

/// Phases whose time/energy is accounted separately.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// weighted-SGD steps on the subset (or full set)
    Train,
    /// data selection (gradients + OMP / greedy)
    Select,
    /// test/val evaluation
    Eval,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Train => "train",
            Phase::Select => "select",
            Phase::Eval => "eval",
        }
    }
}

/// Simulated device power per phase, watts.
///
/// Defaults model a single-accelerator box: training saturates the device
/// (~250 W, V100-ish board power), selection is dominated by gradient
/// chunk execution + host-side OMP (~180 W), eval is short forward passes
/// (~200 W).  Only *ratios* matter for the paper-shaped comparisons.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    pub train_w: f64,
    pub select_w: f64,
    pub eval_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel { train_w: 250.0, select_w: 180.0, eval_w: 200.0 }
    }
}

/// Accumulates per-phase durations and derives simulated energy.
#[derive(Clone, Debug, Default)]
pub struct PhaseClock {
    totals: BTreeMap<&'static str, f64>,
}

impl PhaseClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(phase, t0.elapsed().as_secs_f64());
        out
    }

    /// Add raw seconds to a phase.
    pub fn add(&mut self, phase: Phase, secs: f64) {
        *self.totals.entry(phase.name()).or_insert(0.0) += secs;
    }

    /// Seconds accumulated in a phase.
    pub fn secs(&self, phase: Phase) -> f64 {
        self.totals.get(phase.name()).copied().unwrap_or(0.0)
    }

    /// Total accounted seconds.
    pub fn total_secs(&self) -> f64 {
        self.totals.values().sum()
    }

    /// Simulated energy in kWh under a power model.
    pub fn energy_kwh(&self, pm: &PowerModel) -> f64 {
        let j = self.secs(Phase::Train) * pm.train_w
            + self.secs(Phase::Select) * pm.select_w
            + self.secs(Phase::Eval) * pm.eval_w;
        j / 3.6e6
    }

    /// Merge another clock into this one.
    pub fn merge(&mut self, other: &PhaseClock) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_insert(0.0) += v;
        }
    }
}

/// Minimal stopwatch for benches.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Format seconds human-readably (`1.23s`, `4m05s`).
pub fn fmt_secs(s: f64) -> String {
    if s < 60.0 {
        format!("{s:.2}s")
    } else {
        let m = (s / 60.0).floor();
        format!("{m:.0}m{:04.1}s", s - m * 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate() {
        let mut c = PhaseClock::new();
        c.add(Phase::Train, 2.0);
        c.add(Phase::Train, 3.0);
        c.add(Phase::Select, 1.0);
        assert_eq!(c.secs(Phase::Train), 5.0);
        assert_eq!(c.secs(Phase::Select), 1.0);
        assert_eq!(c.secs(Phase::Eval), 0.0);
        assert_eq!(c.total_secs(), 6.0);
    }

    #[test]
    fn energy_matches_hand_computation() {
        let mut c = PhaseClock::new();
        c.add(Phase::Train, 3600.0); // 1h at 250W = 0.25 kWh
        c.add(Phase::Select, 3600.0); // 1h at 180W = 0.18 kWh
        let e = c.energy_kwh(&PowerModel::default());
        assert!((e - 0.43).abs() < 1e-9, "{e}");
    }

    #[test]
    fn energy_is_monotone_in_time() {
        let pm = PowerModel::default();
        let mut a = PhaseClock::new();
        a.add(Phase::Train, 10.0);
        let mut b = PhaseClock::new();
        b.add(Phase::Train, 20.0);
        assert!(b.energy_kwh(&pm) > a.energy_kwh(&pm));
    }

    #[test]
    fn time_closure_records_something() {
        let mut c = PhaseClock::new();
        let v = c.time(Phase::Eval, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(c.secs(Phase::Eval) >= 0.004);
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseClock::new();
        a.add(Phase::Train, 1.0);
        let mut b = PhaseClock::new();
        b.add(Phase::Train, 2.0);
        b.add(Phase::Eval, 0.5);
        a.merge(&b);
        assert_eq!(a.secs(Phase::Train), 3.0);
        assert_eq!(a.secs(Phase::Eval), 0.5);
    }

    #[test]
    fn fmt_secs_formats() {
        assert_eq!(fmt_secs(1.234), "1.23s");
        assert_eq!(fmt_secs(65.0), "1m05.0s");
    }
}
