//! Table 11: GRAD-MATCH internal variants — PerClass (full-P per-class
//! OMP), PerClassPerGradient (the default: per-class + last-layer class
//! slice), PerBatch.  Paper shape: PerClass is the slowest selection by
//! far; PerClassPerGradient is comparable in accuracy and much faster;
//! PerBatch has the best efficiency.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;
    let variants = [
        ("PerClassPerGradient", "gradmatch"),
        ("PerClass", "gradmatch-perclass"),
        ("PerBatch", "gradmatch-pb"),
    ];
    let mut ok = true;
    for (ds, model) in [("syncifar10", "resnet_s"), ("syncifar100", "resnet_s")] {
        bh::section(&format!("Table 11 — GRAD-MATCH variants on {ds}"));
        bh::table_header(&["variant", "acc@10%", "acc@30%", "sel-s@10%", "sel-s@30%"]);
        let mut sel_times = std::collections::HashMap::new();
        for (label, spec) in variants {
            let mut accs = Vec::new();
            let mut sels = Vec::new();
            for &b in &[0.10, 0.30] {
                let mut cfg = bh::bench_config(ds, model);
                cfg.strategy = spec.into();
                cfg.budget_frac = b;
                cfg.epochs = 10;
                cfg.r_interval = 5;
                let r = coord.run_one(&cfg, cfg.seed)?;
                accs.push(r.test_acc);
                sels.push(r.select_secs);
            }
            bh::table_row(&[
                label.into(),
                format!("{:.2}", accs[0] * 100.0),
                format!("{:.2}", accs[1] * 100.0),
                format!("{:.2}", sels[0]),
                format!("{:.2}", sels[1]),
            ]);
            sel_times.insert(label, sels[1]);
        }
        ok &= bh::shape_check(
            &format!("{ds}: PerClass selection slower than PerClassPerGradient"),
            sel_times["PerClass"] > sel_times["PerClassPerGradient"],
        );
        // at full scale PB is fastest outright (half the non-PB time in the
        // paper); at bench scale the fair comparison is against the full-P
        // PerClass variant it approximates
        ok &= bh::shape_check(
            &format!("{ds}: PerBatch selection faster than PerClass"),
            sel_times["PerBatch"] < sel_times["PerClass"],
        );
    }
    println!("\ntable11_variants: {}", if ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
