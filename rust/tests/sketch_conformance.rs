//! Conformance for the JL-sketched correlation path, pinned device-free
//! on the synthetic gradient oracle:
//!
//! - **inapplicable plan ≡ flat** — a sketch plan whose width is at
//!   least the staged column count (or a width of 0) is bit-identical to
//!   the plan-less flat path for EVERY `strategy_specs()` spec, with
//!   identical dispatch counts;
//! - **quality** — at `k = P/8` with full-width re-fit, the sketched
//!   subset's matched-gradient error stays in the flat solve's regime,
//!   and sketching adds ZERO oracle dispatches (it reads staged buffers);
//! - **determinism** — a sketched round is reproducible from
//!   `(seed, rng_tag, seed_salt)` alone;
//! - **sketch × shard composition** — per-shard solves sketch while the
//!   merge re-fit runs full width: the two-level dispatch contract
//!   `Σ_s ⌈n_s/chunk⌉ + ⌈|winners|/chunk⌉` is unchanged, the round
//!   probe records the sketch width, and `refit_secs` stays 0 (the merge
//!   solve IS the composition's re-fit).

use gradmatch::data::Dataset;
use gradmatch::engine::{SelectionEngine, SelectionRequest, ShardPlan, SketchPlan};
use gradmatch::grads::{self, SynthGrads};
use gradmatch::rng::Rng;
use gradmatch::selection::{strategy_specs, Selection};
use gradmatch::tensor::Matrix;

const CHUNK: usize = 16;
const BATCH: usize = 4;

/// Imbalanced synthetic dataset (the strategy-conformance fixture shape:
/// heavy head, long tail, every class populated).
fn imbalanced(seed: u64, classes: usize, d: usize) -> Dataset {
    let mut y: Vec<i32> = Vec::new();
    for cls in 0..classes {
        let n_c = match cls % 3 {
            0 => 37,
            1 => 11,
            _ => 4,
        };
        y.extend(std::iter::repeat(cls as i32).take(n_c));
    }
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut y);
    let n = y.len();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

/// Balanced synthetic dataset sized exactly `n` (`y = i mod classes`).
fn balanced(seed: u64, n: usize, classes: usize, d: usize) -> Dataset {
    let mut rng = Rng::new(seed);
    let y: Vec<i32> = (0..n).map(|i| (i % classes) as i32).collect();
    let x = Matrix::from_vec(n, d, (0..n * d).map(|_| rng.gaussian_f32()).collect());
    Dataset { x, y, classes }
}

fn request(
    strategy: &str,
    ground: Vec<usize>,
    budget: usize,
    shards: Option<ShardPlan>,
    sketch: Option<SketchPlan>,
) -> SelectionRequest {
    SelectionRequest {
        strategy: strategy.into(),
        budget,
        lambda: 0.5,
        eps: 1e-10,
        is_valid: false,
        seed: 42,
        rng_tag: 7,
        ground,
        shards,
        sketch,
    }
}

/// Paper-style matching error of a weighted subset against the full
/// ground gradient sum: `‖Σ wᵢgᵢ − Σ g‖ / ‖Σ g‖` (the shard-scale
/// bench's metric — weights are class-sum calibrated on both paths).
fn subset_error(store: &grads::GradientStore, sel: &Selection) -> f64 {
    let p = store.g.cols;
    let mut full = vec![0.0f64; p];
    for r in 0..store.g.rows {
        for (j, &v) in store.g.row(r).iter().enumerate() {
            full[j] += v as f64;
        }
    }
    let mut sub = vec![0.0f64; p];
    for (slot, &row) in sel.indices.iter().enumerate() {
        let w = sel.weights[slot] as f64;
        for (j, &v) in store.g.row(row).iter().enumerate() {
            sub[j] += w * v as f64;
        }
    }
    let num: f64 = full.iter().zip(&sub).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    let den: f64 = full.iter().map(|a| a * a).sum::<f64>().sqrt();
    num / den.max(1e-12)
}

#[test]
fn inapplicable_sketch_plan_is_bit_identical_to_flat_for_every_spec() {
    let (classes, h, d) = (5usize, 3usize, 6usize);
    let p = h * classes + classes;
    let train = imbalanced(91, classes, d);
    let val = imbalanced(92, classes, d);
    let n = train.len();
    let ground: Vec<usize> = (0..n).collect();
    let budget = n / 4;

    for spec in strategy_specs() {
        let mut flat_oracle = SynthGrads::with_batch(CHUNK, p, BATCH);
        let flat = {
            let engine = SelectionEngine::with_oracle(&mut flat_oracle, &train, &val, h, classes);
            engine.select(&request(spec, ground.clone(), budget, None, None)).unwrap()
        };

        // two inapplicable spellings: k = the full staged width (no
        // reduction) and k well past it — both are the identity
        let plans = [
            SketchPlan { width: p, refit: true, seed_salt: 0 },
            SketchPlan { width: 2 * p, refit: false, seed_salt: 3 },
        ];
        for plan in plans {
            let mut oracle = SynthGrads::with_batch(CHUNK, p, BATCH);
            let got = {
                let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
                engine
                    .select(&request(spec, ground.clone(), budget, None, Some(plan)))
                    .unwrap()
            };
            assert_eq!(
                got.selection, flat.selection,
                "{spec}: inapplicable sketch plan {plan:?} must be bit-identical to the flat path"
            );
            assert_eq!(
                got.stats.sketch_width, 0,
                "{spec}: an inapplicable plan must not record a sketch width"
            );
            assert_eq!(
                (oracle.grad_calls, oracle.mean_calls, oracle.gradsum_calls, oracle.eval_calls),
                (
                    flat_oracle.grad_calls,
                    flat_oracle.mean_calls,
                    flat_oracle.gradsum_calls,
                    flat_oracle.eval_calls
                ),
                "{spec}: inapplicable sketch plan {plan:?} must cost the flat path's dispatches"
            );
        }
    }
}

#[test]
fn sketched_solve_stays_in_the_flat_quality_regime() {
    // full-width staging ("gradmatch-perclass") so P is large enough for
    // a real P/8 reduction
    let (classes, h, d) = (4usize, 16usize, 6usize);
    let p = h * classes + classes; // 68
    let (n, budget) = (240usize, 48usize);
    let k = p / 8; // 8
    let train = balanced(93, n, classes, d);
    let val = balanced(94, 60, classes, d);
    let ground: Vec<usize> = (0..n).collect();

    let mut flat_oracle = SynthGrads::new(CHUNK, p);
    let flat = {
        let engine = SelectionEngine::with_oracle(&mut flat_oracle, &train, &val, h, classes);
        engine
            .select(&request("gradmatch-perclass", ground.clone(), budget, None, None))
            .unwrap()
    };

    let plan = SketchPlan { width: k, refit: true, seed_salt: 0 };
    let mut sk_oracle = SynthGrads::new(CHUNK, p);
    let sketched = {
        let engine = SelectionEngine::with_oracle(&mut sk_oracle, &train, &val, h, classes);
        engine
            .select(&request("gradmatch-perclass", ground.clone(), budget, None, Some(plan)))
            .unwrap()
    };

    // sketching reads the staged buffers — it must not add dispatches
    assert_eq!(
        (sk_oracle.grad_calls, sk_oracle.mean_calls, sk_oracle.gradsum_calls),
        (flat_oracle.grad_calls, flat_oracle.mean_calls, flat_oracle.gradsum_calls),
        "a sketched round must cost exactly the flat round's dispatches"
    );
    assert_eq!(sketched.stats.sketch_width, k, "round probe records the applied width");
    assert!(sketched.stats.sketch_secs >= 0.0 && sketched.stats.refit_secs >= 0.0);

    // selection sanity
    let sel = &sketched.selection;
    assert!(!sel.indices.is_empty() && sel.indices.len() <= budget);
    let mut uniq = sel.indices.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), sel.indices.len(), "duplicate rows selected");
    assert!(uniq.iter().all(|&i| i < n), "out-of-range row selected");
    assert!(sel.weights.iter().all(|w| w.is_finite() && *w >= 0.0));

    // quality: the sketched support is chosen from noisy k-dim proxies,
    // but the full-width re-fit re-weights it optimally — the matching
    // error must stay in the flat solve's regime, not collapse to noise
    let mut err_oracle = SynthGrads::new(CHUNK, p);
    let store = grads::per_sample_grads_with(&mut err_oracle, &train, &ground)
        .expect("per-sample gradients for the error metric");
    let err_flat = subset_error(&store, &flat.selection);
    let err_sketch = subset_error(&store, &sketched.selection);
    assert!(
        err_sketch <= 3.0 * err_flat + 0.15,
        "sketched error {err_sketch:.4} far outside the flat regime {err_flat:.4} at k={k}"
    );
    assert!(err_sketch < 1.0, "re-fit weights must beat the empty subset: {err_sketch:.4}");
}

#[test]
fn sketched_selection_is_deterministic_in_seed_and_salt() {
    let (classes, h, d) = (4usize, 16usize, 6usize);
    let p = h * classes + classes;
    let (n, budget) = (240usize, 48usize);
    let train = balanced(95, n, classes, d);
    let val = balanced(96, 60, classes, d);
    let ground: Vec<usize> = (0..n).collect();
    let plan = SketchPlan { width: p / 8, refit: true, seed_salt: 0 };

    let run = |plan: SketchPlan| {
        let mut oracle = SynthGrads::new(CHUNK, p);
        let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
        engine
            .select(&request("gradmatch-perclass", ground.clone(), budget, None, Some(plan)))
            .unwrap()
    };

    let a = run(plan);
    let b = run(plan);
    assert_eq!(
        a.selection, b.selection,
        "a sketched round must be reproducible from (seed, rng_tag, seed_salt)"
    );
    assert_eq!(a.stats.sketch_width, b.stats.sketch_width);

    // a different projection salt is a different (valid) round
    let salted = run(SketchPlan { seed_salt: 1, ..plan });
    assert_eq!(salted.stats.sketch_width, p / 8);
    assert!(!salted.selection.indices.is_empty());
    assert!(salted.selection.indices.len() <= budget);
    assert!(salted.selection.weights.iter().all(|w| w.is_finite()));
}

#[test]
fn sketch_composes_with_sharding_without_extra_dispatches() {
    // per-gradient staging ("gradmatch-rust"): staged width is h+1, so
    // the sketch must be narrower than that to apply
    let (classes, h, d) = (3usize, 12usize, 5usize);
    let p = h * classes + classes;
    let (n, budget, max_rows) = (600usize, 60usize, 150usize);
    let width = 8usize; // < h+1 = 13
    let train = balanced(97, n, classes, d);
    let val = balanced(98, 60, classes, d);
    let ground: Vec<usize> = (0..n).collect();

    let shards = ShardPlan { shards: 0, max_staged_rows: max_rows };
    let sketch = SketchPlan { width, refit: true, seed_salt: 0 };
    let mut oracle = SynthGrads::new(CHUNK, p);
    let report = {
        let engine = SelectionEngine::with_oracle(&mut oracle, &train, &val, h, classes);
        engine
            .select(&request("gradmatch-rust", ground, budget, Some(shards), Some(sketch)))
            .unwrap()
    };
    let stats = &report.stats;

    assert_eq!(stats.shards, 4, "shard count derivation is unchanged under sketching");
    assert!(stats.peak_staged_rows <= max_rows, "memory budget holds under sketching");
    assert_eq!(stats.sketch_width, width, "round probe records the shard solves' width");
    assert!(
        stats.refit_secs == 0.0,
        "sharded sketched rounds skip the per-shard re-fit — the full-width merge \
         solve IS the composition's re-fit (got {})",
        stats.refit_secs
    );

    // the two-level dispatch contract is untouched: sketching reads the
    // staged shard buffers, so acquisition stays
    // Σ_s ⌈n_s/chunk⌉ + ⌈|winners|/chunk⌉
    let shard_passes = 4 * max_rows.div_ceil(CHUNK);
    let merge_passes = stats.merge_candidates.div_ceil(CHUNK);
    assert_eq!(
        oracle.grad_calls,
        shard_passes + merge_passes,
        "sketching must add zero dispatches to the sharded contract"
    );
    assert_eq!(
        stats.stage_dispatches, oracle.grad_calls,
        "the round probe must agree with the oracle's own counter"
    );
    assert!(stats.merge_candidates > 0 && stats.merge_candidates <= 2 * budget);

    // selection sanity
    let sel = &report.selection;
    assert!(!sel.indices.is_empty() && sel.indices.len() <= budget);
    let mut uniq = sel.indices.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), sel.indices.len(), "duplicate rows selected");
    assert!(uniq.iter().all(|&i| i < n), "out-of-range row selected");
    assert!(sel.weights.iter().all(|w| w.is_finite()));
}
