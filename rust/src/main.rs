//! `gradmatch` — leader binary: train / sweep / select / inspect.

use anyhow::{anyhow, Result};

use gradmatch::cli::{usage, Cli};
use gradmatch::coordinator::{write_results, Coordinator};
use gradmatch::data::DatasetCard;
use gradmatch::jsonlite::arr;
use gradmatch::selection::{parse_strategy, strategy_specs};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") || args.is_empty() {
        println!("{}", usage());
        return;
    }
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args)?;
    match cli.command.as_str() {
        "train" => cmd_train(&cli),
        "sweep" => cmd_sweep(&cli),
        "select" => cmd_select(&cli),
        "serve" => cmd_serve(&cli),
        "inspect" => cmd_inspect(&cli),
        "list-strategies" => cmd_list_strategies(),
        other => Err(anyhow!("unknown command '{other}'\n\n{}", usage())),
    }
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let cfg = cli.experiment_config()?;
    println!(
        "train: dataset={} model={} strategy={} budget={:.0}% epochs={} R={} runs={}",
        cfg.dataset,
        cfg.model,
        cfg.strategy,
        cfg.budget_frac * 100.0,
        cfg.epochs,
        cfg.r_interval,
        cfg.runs
    );
    let mut coord = Coordinator::new(&cfg.artifacts_dir)?;
    let runs = coord.run_multi(&cfg)?;
    for r in &runs {
        println!(
            "  seed {:>3}: test-acc {:>6.2}%  train {:>7.2}s  select {:>6.2}s  energy(sim) {:.5} kWh  selections {} (engine reused {}, buffers recycled {})",
            r.seed,
            r.test_acc * 100.0,
            r.train_secs,
            r.select_secs,
            r.energy_kwh,
            r.selections,
            r.engine_reused_rounds,
            r.stage_buffer_reuses
        );
        if r.select_retries > 0
            || r.quarantined_rows > 0
            || r.degraded_rounds > 0
            || r.sync_fallback_rounds > 0
            || r.stale_rejections > 0
        {
            println!(
                "            faults: retries {}  quarantined rows {}  degraded rounds {}  sync-fallback rounds {}  stale rejections {}",
                r.select_retries,
                r.quarantined_rows,
                r.degraded_rounds,
                r.sync_fallback_rounds,
                r.stale_rejections
            );
        }
        if r.sharded_rounds > 0 {
            println!(
                "            sharded: rounds {}  peak staged rows {}  merge candidates {}",
                r.sharded_rounds, r.peak_staged_rows, r.merge_candidates
            );
        }
        if r.sketched_rounds > 0 {
            println!(
                "            sketched: rounds {}  project {:.3}s  refit {:.3}s",
                r.sketched_rounds, r.sketch_secs, r.refit_secs
            );
        }
        if r.cache_hit_rounds > 0 || r.cache_store_rounds > 0 {
            println!(
                "            cache: hits {}  stores {}  saved {:.3}s",
                r.cache_hit_rounds, r.cache_store_rounds, r.cache_hit_secs_saved
            );
        }
    }
    let name = format!(
        "train_{}_{}_{}_{}",
        cfg.dataset,
        cfg.model,
        cfg.strategy,
        (cfg.budget_frac * 100.0) as usize
    );
    let path = write_results(&cfg.out_dir, &name, &runs)?;
    println!("wrote {path}");
    Ok(())
}

fn cmd_sweep(cli: &Cli) -> Result<()> {
    let base = cli.experiment_config()?;
    let datasets = cli
        .flag_list("datasets")
        .unwrap_or_else(|| vec![base.dataset.clone()]);
    let strategies: Vec<String> = cli.flag_list("strategies").unwrap_or_else(|| {
        gradmatch::selection::paper_strategies()
            .into_iter()
            .map(str::to_string)
            .collect()
    });
    let budgets: Vec<f64> = match cli.flag_list("budgets") {
        Some(bs) => bs
            .iter()
            .map(|b| b.parse::<f64>().map_err(|e| anyhow!("budget '{b}': {e}")))
            .collect::<Result<_>>()?,
        None => vec![0.05, 0.1, 0.3],
    };
    let mut coord = Coordinator::new(&base.artifacts_dir)?;
    for ds in &datasets {
        let mut cfg = base.clone();
        cfg.dataset = ds.clone();
        if let Some(card) = DatasetCard::by_name(ds) {
            cfg.model = card.model.to_string();
        }
        println!("\n== sweep {ds} (model {}) ==", cfg.model);
        let strat_refs: Vec<&str> = strategies.iter().map(String::as_str).collect();
        let rows = coord.sweep(&cfg, &strat_refs, &budgets)?;
        println!("full-training skyline acc: {:.2}%", rows[0].full_acc * 100.0);
        for row in &rows {
            println!("  {}", row.format());
        }
        let summaries: Vec<_> = rows.into_iter().map(|r| r.summary).collect();
        write_results(&base.out_dir, &format!("sweep_{ds}"), &summaries)?;
    }
    Ok(())
}

/// One-shot selection through the engine.  `--strategies a,b,c` issues a
/// batched round: every request shares the engine's staged-gradient
/// cache, so a multi-strategy round pays ONE staging pass.  Prints an
/// array of `SelectionReport`s (selection + staging/solve observability).
fn cmd_select(cli: &Cli) -> Result<()> {
    let cfg = cli.experiment_config()?;
    let specs: Vec<String> = cli
        .flag_list("strategies")
        .unwrap_or_else(|| vec![cfg.strategy.clone()]);
    let spec_refs: Vec<&str> = specs.iter().map(String::as_str).collect();
    let mut coord = Coordinator::new(&cfg.artifacts_dir)?;
    let reports = coord.selection_round(&cfg, &spec_refs)?;
    let doc = arr(reports.iter().map(|r| r.to_json()).collect());
    println!("{}", doc.dump());
    Ok(())
}

/// Selection-as-a-service daemon (see `gradmatch::server`).  `--smoke`
/// runs the self-contained daemon+client round-trip ci.sh drives.
fn cmd_serve(cli: &Cli) -> Result<()> {
    use gradmatch::server::{serve, smoke, Bind, ServeOpts};
    if cli.flag("smoke").map(|v| v != "false").unwrap_or(false) {
        return smoke();
    }
    let bind = match (cli.flag("socket"), cli.flag("tcp")) {
        (Some(path), None) => Bind::Unix(std::path::PathBuf::from(path)),
        (None, Some(addr)) => Bind::Tcp(addr.to_string()),
        (None, None) => Bind::Unix(std::path::PathBuf::from("gradmatch.sock")),
        (Some(_), Some(_)) => {
            return Err(anyhow!("serve: pass --socket OR --tcp, not both"));
        }
    };
    let mut opts = ServeOpts::new(bind);
    opts.install_signal_handlers = true;
    let parse_flag = |name: &str, default: u64| -> Result<u64> {
        match cli.flag(name) {
            None => Ok(default),
            Some(v) => v.parse::<u64>().map_err(|e| anyhow!("--{name} '{v}': {e}")),
        }
    };
    opts.queue_cap = parse_flag("queue-cap", opts.queue_cap as u64)? as usize;
    opts.engine_cap = parse_flag("engines", opts.engine_cap as u64)? as usize;
    opts.max_conns = parse_flag("max-conns", opts.max_conns as u64)? as usize;
    opts.default_deadline_ms = parse_flag("deadline-ms", opts.default_deadline_ms)?;
    opts.read_timeout_ms = parse_flag("read-timeout-ms", opts.read_timeout_ms)?;
    opts.max_request_bytes = parse_flag("max-request-bytes", opts.max_request_bytes as u64)? as usize;
    opts.selection_cache_cap =
        parse_flag("selection-cache-cap", opts.selection_cache_cap as u64)? as usize;
    if let Some(spec) = cli.flag("fault-plan") {
        opts.fault_plan = Some(gradmatch::fault::FaultPlan::parse(spec)?);
    }
    println!(
        "serve: {:?} (queue-cap {}, engines {}, deadline {}ms{})",
        opts.bind,
        opts.queue_cap,
        opts.engine_cap,
        opts.default_deadline_ms,
        if opts.fault_plan.is_some() { ", fault injection ON" } else { "" }
    );
    let stats = serve(opts)?;
    println!(
        "serve: done — {} rounds served, {} shed, {} deadline-exceeded",
        stats.rounds_served,
        stats.shed_overloaded,
        stats.deadline_replies + stats.deadline_skipped
    );
    Ok(())
}

/// Print every strategy spec `parse_strategy` accepts, with its resolved
/// name and adaptivity (whether re-selection every R epochs is useful).
fn cmd_list_strategies() -> Result<()> {
    println!("{:<18} {:<18} {:>9}   warm variant", "spec", "resolves to", "adaptive");
    for spec in strategy_specs() {
        let (s, _) = parse_strategy(spec, 128)?;
        println!(
            "{spec:<18} {:<18} {:>9}   {spec}-warm",
            s.name(),
            if s.is_adaptive() { "yes" } else { "no" },
        );
    }
    println!("\n(-warm = κ warm-start schedule: T_f = κ·T·k/n full epochs first, §4)");
    Ok(())
}

fn cmd_inspect(cli: &Cli) -> Result<()> {
    let artifacts = cli.flag("artifacts").unwrap_or("artifacts");
    let manifest = gradmatch::runtime::Manifest::load(std::path::Path::new(artifacts))?;
    println!("artifact manifest @ {artifacts} (interchange: hlo-text)");
    let mut names: Vec<_> = manifest.models.keys().collect();
    names.sort();
    for name in names {
        let m = &manifest.models[name];
        println!(
            "  {name:<16} d={:<5} h={:<4} c={:<3} P={:<6} B={} chunk={} entries={}",
            m.d,
            m.h,
            m.c,
            m.p,
            m.batch,
            m.chunk,
            m.entries.len()
        );
    }
    println!("\ndataset cards:");
    for card in DatasetCard::all() {
        println!(
            "  {:<13} n={:<6} d={:<5} classes={:<3} model={}",
            card.name, card.n_train, card.d, card.classes, card.model
        );
    }
    Ok(())
}
