//! GRAD-MATCH: gradient-matching data subset selection for efficient training.
//!
//! Reproduction of *Killamsetty et al., "GRAD-MATCH: Gradient Matching based
//! Data Subset Selection for Efficient Deep Model Training", ICML 2021* as a
//! three-layer system:
//!
//! - **Layer 1 (Pallas, build time)** — the gradient-matching compute kernels
//!   (per-sample last-layer gradients, OMP residual correlations, pairwise
//!   gradient distances) in `python/compile/kernels/`.
//! - **Layer 2 (JAX, build time)** — the classifier forward/backward and the
//!   selection-support entry points in `python/compile/model.py`, lowered once
//!   to HLO text under `artifacts/` by `python/compile/aot.py`.
//! - **Layer 3 (this crate, run time)** — the adaptive data-selection
//!   system.  Python is never on the training path.
//!
//! # Layer-3 module map (post engine redesign)
//!
//! Selection is a service: a typed [`engine::SelectionRequest`] goes into a
//! round-scoped [`engine::SelectionEngine`] (which owns the staged-gradient
//! cache, so N strategies against one model state share ONE staging pass)
//! and a structured [`engine::SelectionReport`] comes out.
//!
//! | module | role |
//! |---------------|--------------------------------------------------------|
//! | `engine`      | SelectionRequest → SelectionEngine → SelectionReport   |
//! | `selection`   | `Strategy` impls as stateless solvers over staged views|
//! | `grads`       | gradient acquisition: `GradOracle` seam, single-pass   |
//! |               | class-sliced staging, streamed scoring                 |
//! | `omp`         | Batch-OMP (correlation recurrence, Rust + XLA backends)|
//! | `sketch`      | seeded JL projection: sketched OMP + full-width refit  |
//! | `submod`      | facility location + lazy greedy (CRAIG, FeatureFL)     |
//! | `trainer`     | Algorithm 1: weighted-SGD loop driving engine rounds   |
//! | `overlap`     | background selection worker (double-buffered subsets)  |
//! | `server`      | selection-as-a-service daemon: engine pool, bounded    |
//! |               | queue, deadlines, typed shedding, graceful drain       |
//! | `fault`       | seeded fault injection over the `GradOracle` seam      |
//! | `coordinator` | config → dataset → engine/trainer; sweeps, baselines   |
//! | `runtime`     | PJRT client + AOT'd HLO executables                    |
//! | `par`         | blocked parallel kernels + class-level task fan-out    |
//! | `data`        | synthetic dataset cards, padded chunking, imbalance    |
//! | `config`/`cli`| TOML-subset experiment configs and the `gradmatch` CLI |
//! | `jsonlite`    | dependency-free JSON for manifests/results/reports     |
//! | `bench_harness`| timing substrate + `BENCH_*.json` perf trajectory     |
//! | `metrics`/`stats`/`theory` | phase clocks, table stats, Thm. 1 bounds  |

// Math/substrate core — always built (works with --no-default-features).
pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod data;
pub mod jsonlite;
pub mod linalg;
pub mod metrics;
pub mod omp;
pub mod par;
pub mod rng;
pub mod sketch;
pub mod stats;
pub mod submod;
pub mod tensor;
pub mod testutil;
pub mod theory;

// XLA/PJRT interop layer — gated behind the (default-on) `xla` feature so
// the crate builds with no xla dependency at all.  The vendored stub makes
// these compile everywhere; real execution needs the xla_extension tree.
#[cfg(feature = "xla")]
pub mod checkpoint;
#[cfg(feature = "xla")]
pub mod coordinator;
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod fault;
#[cfg(feature = "xla")]
pub mod grads;
#[cfg(feature = "xla")]
pub mod overlap;
#[cfg(feature = "xla")]
pub mod runtime;
#[cfg(feature = "xla")]
pub mod selection;
#[cfg(feature = "xla")]
pub mod server;
#[cfg(feature = "xla")]
pub mod trainer;
