//! Figure 3f/g + Figure 4e: class-imbalance robustness.  30% (then 60%,
//! 90%) of classes are reduced by 90%; strategies match the validation
//! gradient (L = L_V).  Shape: GRAD-MATCH(-WARM) beats RANDOM under
//! imbalance, and full training degrades as imbalance grows.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;
    let mut all_ok = true;

    bh::section("Fig. 3f — imbalance scatter (30% classes reduced, synmnist-like)");
    bh::table_header(&["strategy", "acc%", "total-s"]);
    let mut accs = std::collections::HashMap::new();
    for strat in ["random", "glister", "craig-pb", "gradmatch", "gradmatch-warm", "gradmatch-pb-warm"] {
        let mut cfg = bh::bench_config("synmnist", "lenet_s");
        cfg.budget_frac = 0.30;
        cfg.epochs = 12;
        cfg.r_interval = 4;
        cfg.is_valid = true;
        cfg.strategy = strat.into();
        let run = coord.run_one(&cfg, cfg.seed)?;
        bh::table_row(&[
            strat.into(),
            format!("{:.2}", run.test_acc * 100.0),
            format!("{:.2}", run.total_secs),
        ]);
        accs.insert(strat, run.test_acc);
    }
    let best_gm = ["gradmatch", "gradmatch-warm", "gradmatch-pb-warm"]
        .iter()
        .map(|s| accs[s])
        .fold(0.0f64, f64::max);
    all_ok &= bh::shape_check(
        "3f: best GRAD-MATCH variant beats RANDOM under imbalance",
        best_gm >= accs["random"],
    );

    bh::section("Fig. 4e — varying imbalance degree (30/60/90% of classes)");
    bh::table_header(&["imbalance%", "full(imb)", "random", "gm-warm"]);
    let mut fulls = Vec::new();
    for frac in [0.3, 0.6, 0.9] {
        let mut row = vec![format!("{:.0}", frac * 100.0)];
        // full training on the imbalanced data
        let mut cfg = bh::bench_config("synmnist", "lenet_s");
        cfg.epochs = 12;
        cfg.is_valid = true;
        cfg.imbalance_frac = frac;
        cfg.strategy = "full".into();
        cfg.budget_frac = 1.0;
        let full = coord.run_one(&cfg, cfg.seed)?;
        fulls.push(full.test_acc);
        row.push(format!("{:.2}", full.test_acc * 100.0));
        for strat in ["random", "gradmatch-warm"] {
            let mut c = cfg.clone();
            c.strategy = strat.into();
            c.budget_frac = 0.30;
            c.r_interval = 4;
            let r = coord.run_one(&c, c.seed)?;
            row.push(format!("{:.2}", r.test_acc * 100.0));
            if strat == "gradmatch-warm" && frac == 0.9 {
                all_ok &= bh::shape_check(
                    "4e: at 90% imbalance gradmatch-warm is competitive with full (within 5pp or better)",
                    r.test_acc >= full.test_acc - 0.05,
                );
            }
        }
        bh::table_row(&row);
    }
    all_ok &= bh::shape_check(
        "4e: full-training accuracy degrades as imbalance grows",
        fulls[2] <= fulls[0] + 0.01,
    );
    println!("\nfig4e_imbalance: {}", if all_ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
