//! The adaptive-selection training loop (Algorithm 1).
//!
//! Every `R` epochs the strategy re-selects a weighted subset; in between,
//! weighted mini-batch SGD runs on the AOT'd `train_step` executable with
//! cosine-annealed learning rate (paper §5 setup).  Warm-start (`-warm`
//! variants) runs `T_f = κ·T·k/n` epochs of full training first; the
//! FULL-EARLYSTOP baseline is full training cut at the subset-time budget.
//!
//! Wall-clock and (simulated) energy are split into train / select / eval
//! phases so the harness can report the paper's cost accounting (selection
//! overhead *is* charged to the strategies, as in the paper).

use anyhow::Result;

use crate::data::{padded_chunks, weighted_batches, Dataset, Splits};
use crate::engine::{
    RoundStats, SelectionCache, SelectionEngine, SelectionReport, SelectionRequest,
};
use crate::metrics::{Phase, PhaseClock, PowerModel};
use crate::rng::Rng;
use crate::runtime::{ModelState, Runtime};
use crate::selection::{Selection, Strategy};

/// Training-loop options (a subset of `config::ExperimentConfig`).
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub epochs: usize,
    pub r_interval: usize,
    pub budget_frac: f64,
    pub lr0: f32,
    pub lambda: f32,
    pub eps: f32,
    /// warm-start fraction κ (only used when `warm`)
    pub kappa: f64,
    pub warm: bool,
    /// evaluate test accuracy every N epochs (0 ⇒ only at the end)
    pub eval_every: usize,
    /// match validation gradients (class-imbalance setting)
    pub is_valid: bool,
    pub seed: u64,
    /// FULL-EARLYSTOP: truncate full training to `frac` of the epochs
    pub early_stop_frac: Option<f64>,
    /// overlapped selection: when set, selection requests are served by an
    /// [`crate::overlap::AsyncSelector`] passed to [`train_overlapped`] and
    /// training never stalls on a selection round
    pub overlap: bool,
    /// staleness guardrail for overlapped rounds: a landed subset (solved
    /// against a stale snapshot) is cheap-probed against the *current*
    /// parameters, and rejected — falling back to a synchronous round —
    /// when its matched-gradient error exceeds `stale_tol` × the target
    /// gradient norm.  `<= 0` (or non-finite) disables the probe.
    pub stale_tol: f32,
    /// wedged-worker guard for overlapped rounds: when a round is *due*
    /// but the previous one is still in flight, wait at most this many
    /// milliseconds for it to land before giving up and selecting
    /// synchronously (counted into `sync_fallback_rounds`).  Before this
    /// bound a worker that never answered silently starved the run of
    /// selection rounds forever.  `0` restores the old skip-and-continue
    /// behavior.
    pub overlap_wait_ms: u64,
    /// memory budget for selection rounds: `> 0` turns on the two-level
    /// sharded OMP path with shard count auto-derived so no staged
    /// matrix exceeds this many rows (see `selection.rs`); `0` stages
    /// the whole ground set flat
    pub max_staged_rows: usize,
    /// sketched selection rounds: `> 0` JL-projects each staged class
    /// problem to this width before Batch-OMP, re-fitting weights at full
    /// width on the selected support (see `engine::SketchPlan` /
    /// `sketch.rs`); `0` solves at the full staged width
    pub sketch_width: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            epochs: 60,
            r_interval: 20,
            budget_frac: 0.1,
            lr0: 0.05,
            lambda: 0.5,
            eps: 1e-10,
            kappa: 0.5,
            warm: false,
            eval_every: 0,
            is_valid: false,
            seed: 42,
            early_stop_frac: None,
            overlap: false,
            stale_tol: 2.0,
            overlap_wait_ms: 2_000,
            max_staged_rows: 0,
            sketch_width: 0,
        }
    }
}

/// One epoch's log line (feeds Fig. 3j/k convergence plots).
#[derive(Clone, Debug)]
pub struct EpochLog {
    pub epoch: usize,
    pub mean_loss: f32,
    pub lr: f32,
    pub test_acc: Option<f32>,
    /// cumulative accounted seconds (train+select) at end of epoch
    pub cum_secs: f64,
}

/// Outcome of one training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub final_test_acc: f32,
    pub clock: PhaseClock,
    pub energy_kwh: f64,
    pub history: Vec<EpochLog>,
    /// selection rounds executed
    pub selections: usize,
    /// per-row flag: was this training row ever in a selected subset?
    pub ever_selected: Vec<bool>,
    /// strategy-reported gradient-matching residuals per selection round
    pub grad_errors: Vec<f32>,
    /// per-round engine observability (staging/solve split, dispatch
    /// counts, fan-out decisions) for every applied selection round
    pub round_stats: Vec<RoundStats>,
    /// SGD steps executed
    pub steps: usize,
    /// subset size used (samples)
    pub budget: usize,
    /// selection rounds an overlapped run had to execute synchronously
    /// (worker death, or a subset rejected by the staleness guardrail);
    /// always 0 for synchronous runs
    pub sync_fallback_rounds: usize,
    /// overlapped subsets rejected by the staleness probe
    pub stale_rejections: usize,
}

/// Masked accuracy over a dataset via the eval executable.
pub fn evaluate(rt: &Runtime, st: &ModelState, ds: &Dataset) -> Result<f32> {
    let idx: Vec<usize> = (0..ds.len()).collect();
    let mut correct = 0.0f32;
    for chunk in padded_chunks(ds, &idx, st.meta.chunk) {
        let (_, c, _, _) = rt.eval_chunk(st, &chunk.x, &chunk.y, &chunk.mask)?;
        correct += c;
    }
    Ok(correct / ds.len() as f32)
}

/// Cosine-annealed learning rate (Loshchilov & Hutter; paper §5).
pub fn cosine_lr(lr0: f32, epoch: usize, total: usize) -> f32 {
    let t = epoch as f32 / total.max(1) as f32;
    lr0 * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
}

/// Staleness probe for overlapped rounds (the ROADMAP guardrail, after
/// Balles et al.): an overlap worker solves against a snapshot several
/// epochs old, so before swapping its subset in, measure how well the
/// subset's weighted gradient combination still matches the *current*
/// model's mean gradient.  Two padded dispatches: per-sample gradients
/// of the `chunk`-capped heaviest-weighted subset rows, and the mean
/// gradient of a strided ground-set probe.  Returns whether the relative
/// matched-gradient error `‖Σ wᵢ∇ᵢ − ∇L‖ / ‖∇L‖` exceeds `tol`
/// (`tol <= 0` or non-finite disables the probe).
fn staleness_exceeded(
    rt: &Runtime,
    st: &ModelState,
    train: &Dataset,
    ground: &[usize],
    sel: &Selection,
    tol: f32,
) -> Result<bool> {
    if !(tol > 0.0) || !tol.is_finite() || sel.indices.is_empty() || ground.is_empty() {
        return Ok(false);
    }
    let cap = st.meta.chunk.max(1);
    let take = cap.min(sel.indices.len());
    let picks = crate::selection::top_k_desc(&sel.weights, take);
    let rows: Vec<usize> = picks.iter().map(|&i| sel.indices[i]).collect();
    let mut w: Vec<f32> = picks.iter().map(|&i| sel.weights[i]).collect();
    let wsum: f32 = w.iter().sum();
    if wsum <= 1e-12 {
        w = vec![1.0 / take as f32; take];
    } else {
        for v in &mut w {
            *v /= wsum;
        }
    }
    let stride = (ground.len() / cap).max(1);
    let probe: Vec<usize> = ground.iter().copied().step_by(stride).take(cap).collect();
    let store = crate::grads::per_sample_grads(rt, st, train, &rows)?;
    let target = crate::grads::mean_gradient(rt, st, train, &probe)?;
    let err = crate::grads::gradient_error(&store.g, &w, &target);
    let scale = crate::par::norm2(&target).max(1e-12);
    Ok(err / scale > tol)
}

/// Train a model with an adaptive selection strategy.
///
/// `ground` is the eligible training-row set (the imbalance transform may
/// have removed rows); `state` is consumed as the initial parameters and
/// returned trained inside the outcome's caller-visible `st` (passed by
/// value to keep runs independent).
pub fn train(
    rt: &Runtime,
    st: ModelState,
    splits: &Splits,
    ground: &[usize],
    strategy: &mut dyn Strategy,
    opts: &TrainOpts,
) -> Result<(ModelState, TrainOutcome)> {
    train_overlapped(rt, st, splits, ground, strategy, opts, None)
}

/// [`train`] with an optional background selector (`opts.overlap`): at a
/// due epoch the parameter snapshot is *submitted* and training continues
/// on the stale subset; the fresh subset is swapped in whenever it lands.
/// Worker compute is off the accounted critical path (see DESIGN.md —
/// energy accounting stays with the synchronous mode).
pub fn train_overlapped(
    rt: &Runtime,
    st: ModelState,
    splits: &Splits,
    ground: &[usize],
    strategy: &mut dyn Strategy,
    opts: &TrainOpts,
    selector: Option<&mut crate::overlap::AsyncSelector>,
) -> Result<(ModelState, TrainOutcome)> {
    train_with_cache(rt, st, splits, ground, strategy, opts, selector, None)
}

/// [`train_overlapped`] with an optional cross-arm [`SelectionCache`]
/// (plus the caller's dataset-scope fingerprint): a synchronous selection
/// round whose signature is already memoized replays the cached subset
/// *before* any model snapshot or engine exists for the round — zero
/// staging dispatches, no host-side state marshalling.  Only synchronous
/// rounds consult the cache (an overlapped worker's rounds are solved
/// off the critical path already, and their stale-probe must still run).
#[allow(clippy::too_many_arguments)]
pub fn train_with_cache(
    rt: &Runtime,
    st: ModelState,
    splits: &Splits,
    ground: &[usize],
    strategy: &mut dyn Strategy,
    opts: &TrainOpts,
    mut selector: Option<&mut crate::overlap::AsyncSelector>,
    cache: Option<(&SelectionCache, u64)>,
) -> Result<(ModelState, TrainOutcome)> {
    let n = ground.len();
    let budget = ((opts.budget_frac * n as f64).round() as usize).clamp(1, n);
    let meta = st.meta.clone();
    let mut rng = Rng::new(opts.seed ^ 0xDA7A);
    let mut clock = PhaseClock::new();
    let mut history = Vec::new();
    let mut ever_selected = vec![false; splits.train.len()];
    let mut grad_errors = Vec::new();
    let mut round_stats: Vec<RoundStats> = Vec::new();
    let mut selections = 0usize;
    let mut steps = 0usize;
    let overlap_requested = selector.is_some();
    let mut sync_fallback_rounds = 0usize;
    let mut stale_rejections = 0usize;

    // the run's round-request template: the engine re-derives the round
    // RNG from (seed, rng_tag), so only the tag changes per round — one
    // derivation shared with the overlap worker
    let mut sel_req = SelectionRequest {
        strategy: strategy.name(),
        budget,
        lambda: opts.lambda,
        eps: opts.eps,
        is_valid: opts.is_valid,
        seed: opts.seed,
        rng_tag: 0,
        ground: ground.to_vec(),
        shards: (opts.max_staged_rows > 0).then(|| crate::engine::ShardPlan {
            shards: 0,
            max_staged_rows: opts.max_staged_rows,
        }),
        sketch: (opts.sketch_width > 0).then(|| crate::engine::SketchPlan {
            width: opts.sketch_width,
            ..Default::default()
        }),
    };

    // FULL-EARLYSTOP truncation
    let epochs = match opts.early_stop_frac {
        Some(f) => ((opts.epochs as f64 * f).round() as usize).max(1),
        None => opts.epochs,
    };

    // warm-start: T_f = κ·T·(k/n) epochs of full training (§4 of the paper)
    let t_f = if opts.warm {
        ((opts.kappa * opts.epochs as f64 * budget as f64 / n as f64).round() as usize)
            .min(epochs)
    } else {
        0
    };

    // current subset (starts as a random subset for non-warm runs — matches
    // Algorithm 1's initial X^(0))
    let mut current: Selection = {
        let mut s = Selection::default();
        let picks = rng.sample_indices(n, budget);
        for j in picks {
            s.indices.push(ground[j]);
            s.weights.push(1.0);
        }
        s
    };
    let full_selection: Selection = {
        let mut s = Selection::default();
        for &i in ground {
            s.indices.push(i);
            s.weights.push(1.0);
        }
        s
    };
    let mut selected_once = false;

    // ONE engine per run: the first due round builds it, every later
    // round resets the round-scoped cache (recycling the staging
    // buffers) and installs the fresh snapshot — staged gradients are
    // only valid for the parameters they were computed against
    let mut engine: Option<SelectionEngine<'_>> = None;

    // the hot loop threads one packed-state literal through consecutive
    // fused train steps; host-side snapshots are taken only at selection
    // and evaluation boundaries (§Perf)
    let mut fs = crate::runtime::FusedState::from_state(&st)?;

    for epoch in 0..epochs {
        // --- selection (Algorithm 1 lines 2-8) -----------------------------
        let in_subset_phase = epoch >= t_f;
        let due = in_subset_phase && (epoch - t_f) % opts.r_interval == 0;
        let mut need_sync_round = false;
        let mut worker_lost = false;
        if let Some(sel_worker) = selector.as_deref_mut() {
            // overlapped mode: poll for a finished round, submit a new one.
            // A dead worker (panicked thread, failed runtime load) is
            // never fatal — the run downgrades to synchronous selection.
            //
            // When a round is DUE but the previous one is still in flight,
            // the poll becomes a deadline-bounded wait: a slow worker gets
            // `overlap_wait_ms` to land its round, a wedged one costs that
            // bound once and the round runs synchronously (its late answer,
            // if any, is picked up — and staleness-probed — by a later
            // epoch's poll).  Before this, `inflight > 0` at a due epoch
            // silently skipped the round, so a worker that never answered
            // starved the run of selection forever.
            let mut wedged = false;
            let landed = if due && sel_worker.inflight > 0 && opts.overlap_wait_ms > 0 {
                match clock.time(Phase::Select, || {
                    sel_worker
                        .recv_timeout(std::time::Duration::from_millis(opts.overlap_wait_ms))
                }) {
                    Ok(None) => {
                        wedged = true;
                        Ok(None)
                    }
                    other => other,
                }
            } else {
                sel_worker.try_recv()
            };
            match landed {
                Ok(Some(report)) => {
                    let SelectionReport { selection: sel, stats, .. } = report;
                    if !sel.indices.is_empty() {
                        // staleness guardrail: the subset was solved
                        // against a snapshot several epochs old — reject
                        // it (and select synchronously) when it no longer
                        // matches the current model's gradient
                        let st_now = fs.to_state()?;
                        let stale = clock.time(Phase::Select, || {
                            staleness_exceeded(
                                rt,
                                &st_now,
                                &splits.train,
                                ground,
                                &sel,
                                opts.stale_tol,
                            )
                        })?;
                        if stale {
                            stale_rejections += 1;
                            eprintln!(
                                "overlap: epoch {epoch}: stale subset rejected \
                                 (matched-gradient error above tol {}); selecting synchronously",
                                opts.stale_tol
                            );
                            need_sync_round = true;
                        } else {
                            round_stats.push(stats);
                            if let Some(e) = sel.grad_error {
                                grad_errors.push(e);
                            }
                            for &i in &sel.indices {
                                ever_selected[i] = true;
                            }
                            current = sel;
                            selected_once = true;
                            selections += 1;
                        }
                    }
                }
                Ok(None) => {}
                Err(e) => {
                    eprintln!(
                        "overlap: epoch {epoch}: selector worker lost ({e:#}); \
                         falling back to synchronous selection"
                    );
                    worker_lost = true;
                }
            }
            if wedged {
                eprintln!(
                    "overlap: epoch {epoch}: selection round due but the worker's \
                     previous round has not landed within {}ms; selecting synchronously",
                    opts.overlap_wait_ms
                );
                need_sync_round = true;
            }
            if !worker_lost && !need_sync_round && due && sel_worker.inflight == 0 {
                if let Err(e) = sel_worker.request(fs.to_state()?, 1000 + epoch as u64) {
                    eprintln!(
                        "overlap: epoch {epoch}: selection submit failed ({e:#}); \
                         falling back to synchronous selection"
                    );
                    worker_lost = true;
                }
            }
        }
        if worker_lost {
            selector = None;
            need_sync_round = due && (strategy.is_adaptive() || !selected_once);
        }
        if (selector.is_none() && due && (strategy.is_adaptive() || !selected_once))
            || need_sync_round
        {
            sel_req.rng_tag = 1000 + epoch as u64;
            // the cache consult happens BEFORE the snapshot: a hit round
            // never marshals host-side state and never builds an engine
            let report = clock.time(Phase::Select, || {
                let fs = &mut fs;
                let engine = &mut engine;
                let strategy = &mut *strategy;
                let sel_req = &sel_req;
                let solve = move || {
                    let st_snap = fs.to_state()?;
                    if engine.is_none() {
                        *engine =
                            Some(SelectionEngine::new(rt, st_snap, &splits.train, &splits.val));
                    } else {
                        engine.as_mut().unwrap().reset_round(Some(st_snap));
                    }
                    engine.as_ref().unwrap().select_with(strategy, sel_req)
                };
                match cache {
                    Some((c, scope)) => c.round(scope, sel_req, solve),
                    None => solve(),
                }
            })?;
            let SelectionReport { selection: sel, stats, .. } = report;
            if !sel.indices.is_empty() {
                round_stats.push(stats);
                if let Some(e) = sel.grad_error {
                    grad_errors.push(e);
                }
                for &i in &sel.indices {
                    ever_selected[i] = true;
                }
                current = sel;
                selected_once = true;
                selections += 1;
            }
            if overlap_requested {
                sync_fallback_rounds += 1;
            }
        }

        let active = if in_subset_phase { &current } else { &full_selection };
        if !in_subset_phase {
            for &i in &active.indices {
                ever_selected[i] = true;
            }
        }

        // degenerate guard: all weights zero ⇒ fall back to uniform
        let wsum: f32 = active.weights.iter().sum();
        let weights: Vec<f32> = if wsum <= 1e-12 {
            vec![1.0; active.indices.len()]
        } else {
            active.weights.clone()
        };

        // --- weighted mini-batch SGD (Algorithm 1 line 9) -------------------
        let lr = cosine_lr(opts.lr0, epoch, opts.epochs);
        let mut epoch_rng = rng.split(2000 + epoch as u64);
        let batches = weighted_batches(
            &splits.train,
            &active.indices,
            &weights,
            meta.batch,
            &mut epoch_rng,
        );
        let mut loss_acc = 0.0f64;
        let mut nb = 0usize;
        clock.time(Phase::Train, || -> Result<()> {
            for b in &batches {
                let (loss, _) = rt.train_step_fused(&mut fs, &b.x, &b.y, &b.w, lr)?;
                loss_acc += loss as f64;
                nb += 1;
                steps += 1;
            }
            Ok(())
        })?;

        // --- evaluation ------------------------------------------------------
        let test_acc = if opts.eval_every > 0
            && (epoch % opts.eval_every == opts.eval_every - 1 || epoch + 1 == epochs)
        {
            let st_snap = fs.to_state()?;
            Some(clock.time(Phase::Eval, || evaluate(rt, &st_snap, &splits.test))?)
        } else {
            None
        };

        history.push(EpochLog {
            epoch,
            mean_loss: (loss_acc / nb.max(1) as f64) as f32,
            lr,
            test_acc,
            cum_secs: clock.secs(Phase::Train) + clock.secs(Phase::Select),
        });
    }

    let st = fs.to_state()?;
    let final_test_acc = match history.last().and_then(|h| h.test_acc) {
        Some(a) => a,
        None => clock.time(Phase::Eval, || evaluate(rt, &st, &splits.test))?,
    };
    let energy_kwh = clock.energy_kwh(&PowerModel::default());
    Ok((
        st,
        TrainOutcome {
            final_test_acc,
            clock,
            energy_kwh,
            history,
            selections,
            ever_selected,
            grad_errors,
            round_stats,
            steps,
            budget,
            sync_fallback_rounds,
            stale_rejections,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_lr_endpoints_and_monotonicity() {
        let lr0 = 0.1f32;
        assert!((cosine_lr(lr0, 0, 100) - lr0).abs() < 1e-6);
        assert!(cosine_lr(lr0, 100, 100) < 1e-6);
        let mut prev = f32::INFINITY;
        for t in 0..=100 {
            let lr = cosine_lr(lr0, t, 100);
            assert!(lr <= prev + 1e-7);
            prev = lr;
        }
    }

    #[test]
    fn cosine_lr_half_point() {
        assert!((cosine_lr(0.1, 50, 100) - 0.05).abs() < 1e-6);
    }

    #[test]
    fn train_opts_defaults_match_paper() {
        let o = TrainOpts::default();
        assert_eq!(o.r_interval, 20);
        assert!((o.lambda - 0.5).abs() < 1e-6);
        assert!((o.kappa - 0.5).abs() < 1e-6);
        assert!((o.stale_tol - 2.0).abs() < 1e-6, "staleness guardrail on by default");
        assert_eq!(o.overlap_wait_ms, 2_000, "wedged-worker guard on by default");
    }
}
