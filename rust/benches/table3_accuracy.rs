//! Tables 3/4/5: top-1 test accuracy and model training time per
//! (dataset, strategy, budget) — the paper's main data-selection tables,
//! miniature.  Table 4 block uses MNIST-like budgets (1/3/5/10%); Table 3
//! block uses 5/10/20/30%; Table 5 (ImageNet-like) runs only the
//! strategies the paper could scale (GRAD-MATCH variants + CRAIG-PB +
//! RANDOM) on the larger card.

use gradmatch::bench_harness as bh;
use gradmatch::coordinator::Coordinator;

fn block(
    coord: &mut Coordinator,
    title: &str,
    dataset: &str,
    model: &str,
    n_train: usize,
    strategies: &[&str],
    budgets: &[f64],
) -> anyhow::Result<bool> {
    bh::section(title);
    let mut cfg = bh::bench_config(dataset, model);
    cfg.n_train = n_train;
    cfg.epochs = 10;
    cfg.r_interval = 5;
    let full = coord.full_baseline(&cfg, cfg.seed)?;
    println!(
        "FULL (skyline): acc {:.2}%  time {:.2}s",
        full.test_acc * 100.0,
        full.total_secs
    );
    let mut header = vec!["strategy".to_string()];
    for &b in budgets {
        header.push(format!("acc@{:.0}%", b * 100.0));
    }
    for &b in budgets {
        header.push(format!("time@{:.0}%", b * 100.0));
    }
    bh::table_header(&header.iter().map(String::as_str).collect::<Vec<_>>());
    let mut gm_acc_30 = 0.0f64;
    let mut rnd_acc_30 = 0.0f64;
    for strat in strategies {
        let mut row = vec![strat.to_string()];
        let mut times = Vec::new();
        for &b in budgets {
            let mut c = cfg.clone();
            c.strategy = strat.to_string();
            c.budget_frac = b;
            let r = coord.run_one(&c, c.seed)?;
            row.push(format!("{:.2}", r.test_acc * 100.0));
            times.push(format!("{:.2}s", r.total_secs));
            if (b - budgets[budgets.len() - 1]).abs() < 1e-9 {
                if *strat == "gradmatch-pb-warm" {
                    gm_acc_30 = r.test_acc;
                }
                if *strat == "random" {
                    rnd_acc_30 = r.test_acc;
                }
            }
        }
        row.extend(times);
        bh::table_row(&row);
    }
    Ok(gm_acc_30 >= rnd_acc_30)
}

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new(&bh::artifacts_dir())?;
    let everything = [
        "random",
        "glister",
        "craig",
        "craig-pb",
        "gradmatch",
        "gradmatch-pb",
        "gradmatch-pb-warm",
    ];
    let scalable = ["random", "craig-pb", "gradmatch", "gradmatch-pb", "gradmatch-pb-warm"];

    let mut all_ok = true;
    all_ok &= block(
        &mut coord,
        "Table 4 — synmnist (MNIST-like budgets)",
        "synmnist",
        "lenet_s",
        1500,
        &everything,
        &[0.01, 0.03, 0.05, 0.10],
    )?;
    all_ok &= block(
        &mut coord,
        "Table 3 — syncifar100",
        "syncifar100",
        "resnet_s",
        1200,
        &everything,
        &[0.05, 0.10, 0.20, 0.30],
    )?;
    all_ok &= block(
        &mut coord,
        "Table 5 — synimagenet (scalable strategies only, as in the paper)",
        "synimagenet",
        "resnet_s",
        3000,
        &scalable,
        &[0.05, 0.10, 0.30],
    )?;
    bh::shape_check("tables: gradmatch-pb-warm >= random at the top budget on all blocks", all_ok);
    println!("\ntable3_accuracy: {}", if all_ok { "ALL SHAPE CHECKS PASS" } else { "SOME SHAPE CHECKS FAILED" });
    Ok(())
}
